fn main() {
    for seed in 0..5000u64 {
        let g = relic_smt::graph::kronecker_graph(&relic_smt::graph::KroneckerParams::gap(5, 4, seed));
        if g.num_edges() == 157 {
            println!("seed {} -> 157 edges", seed);
            if seed > 100 { break; }
        }
    }
}
