"""AOT lowering: JAX graph kernels -> HLO *text* artifacts for the Rust
PJRT runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts [--sizes 32,64]

Outputs, per kernel K and size N:
    artifacts/K_nN.hlo.txt
plus artifacts/manifest.json describing every artifact's entry point and
input shapes (consumed by rust/src/runtime/manifest.rs).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import export_registry


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str, sizes: list[int]) -> dict:
    manifest: dict = {"format": "hlo-text", "return_tuple": True, "entries": []}
    os.makedirs(out_dir, exist_ok=True)
    for n in sizes:
        for name, (fn, specs) in export_registry(n).items():
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{name}_n{n}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "kernel": name,
                    "n": n,
                    "file": fname,
                    "inputs": [list(s.shape) for s in specs],
                    "outputs": 1,
                }
            )
            print(f"  wrote {fname} ({len(text)} chars, inputs "
                  f"{[tuple(s.shape) for s in specs]})")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(manifest['entries'])} entries)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="32",
                    help="comma-separated graph sizes to export")
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    lower_all(args.out_dir, sizes)


if __name__ == "__main__":
    main()
