"""Pure-jnp oracles for the Pallas semiring kernels.

These are the correctness ground truth: pytest/hypothesis sweep shapes and
semirings and assert the Pallas kernels match these to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def matvec_ref(a, x, semiring: str = "plus_times"):
    a = jnp.asarray(a, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    if semiring == "plus_times":
        return a @ x
    if semiring == "min_plus":
        return jnp.min(a + x[None, :], axis=1)
    if semiring == "or_and":
        return jnp.max(jnp.minimum(a, x[None, :]), axis=1)
    raise ValueError(semiring)


def matmul_ref(a, b, semiring: str = "plus_times"):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if semiring == "plus_times":
        return a @ b
    if semiring == "min_plus":
        return jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    if semiring == "or_and":
        return jnp.max(jnp.minimum(a[:, :, None], b[None, :, :]), axis=1)
    raise ValueError(semiring)


def triangle_count_ref(a):
    """6 * #triangles for symmetric 0/1 adjacency with zero diagonal."""
    a = jnp.asarray(a, jnp.float32)
    return jnp.sum((a @ a) * a)
