"""L1 — Pallas semiring matrix kernels.

The paper's workload side is graph analytics (GAP kernels). For the PJRT
offload path we express the graph operators in GraphBLAS style: a single
blocked matvec/matmul kernel template instantiated over three semirings

    plus_times : y_i = sum_j  a_ij * x_j          (PageRank, BC)
    min_plus   : y_i = min_j (a_ij + x_j)         (SSSP, CC label prop)
    or_and     : y_i = max_j min(a_ij, x_j)       (BFS frontier expansion)

plus a fused triangle-count kernel  tc = sum( (A @ A) * A ).

TPU adaptation (DESIGN.md §Hardware-Adaptation): blocks are BlockSpec
tiles sized for VMEM; the (+,*) instantiation uses `jnp.dot` so it lowers
onto the MXU systolic array; (min,+) and (or,and) are VPU element-wise +
reduce with the identical HBM<->VMEM schedule. `interpret=True` always —
the CPU PJRT plugin cannot execute Mosaic custom-calls (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Semiring registry -----------------------------------------------------------

#: Additive identities per semiring (the "zero" of the reduction).
IDENTITY = {
    "plus_times": 0.0,
    "min_plus": jnp.inf,
    # True (max, min) tropical semiring: the max-reduce identity is -inf
    # (0 would clamp negative inputs; for {0,1} graph masks the result is
    # identical, but the kernel stays correct on arbitrary reals).
    "or_and": -jnp.inf,
}

SEMIRINGS = tuple(IDENTITY)


def _combine_reduce(semiring: str, a_blk, x_blk):
    """One (bm, bk) x (bk,) block contribution: reduce_j combine(a_ij, x_j).

    Returns a (bm,) partial result for this k-block.
    """
    if semiring == "plus_times":
        # MXU-eligible on real TPU hardware.
        return jnp.dot(a_blk, x_blk, preferred_element_type=jnp.float32)
    if semiring == "min_plus":
        return jnp.min(a_blk + x_blk[None, :], axis=1)
    if semiring == "or_and":
        # Boolean graphs encoded as {0.0, 1.0}: AND == min, OR == max.
        return jnp.max(jnp.minimum(a_blk, x_blk[None, :]), axis=1)
    raise ValueError(f"unknown semiring {semiring!r}")


def _merge(semiring: str, acc, part):
    """Merge a new k-block partial into the accumulator (the semiring 'add')."""
    if semiring == "plus_times":
        return acc + part
    if semiring == "min_plus":
        return jnp.minimum(acc, part)
    if semiring == "or_and":
        return jnp.maximum(acc, part)
    raise ValueError(f"unknown semiring {semiring!r}")


# Matvec kernel ----------------------------------------------------------------


def _matvec_kernel(a_ref, x_ref, o_ref, *, semiring: str, k_blocks: int):
    """Grid = (m_blocks, k_blocks); o block is revisited for every k."""
    k = pl.program_id(1)
    part = _combine_reduce(semiring, a_ref[...], x_ref[...])

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, IDENTITY[semiring])

    o_ref[...] = _merge(semiring, o_ref[...], part)


def _pad_to(v: int, block: int) -> int:
    return (v + block - 1) // block * block


@functools.partial(jax.jit, static_argnames=("semiring", "block_m", "block_k"))
def semiring_matvec(a, x, *, semiring: str = "plus_times",
                    block_m: int = 32, block_k: int = 32):
    """y_i = reduce_j combine(a_ij, x_j) over the given semiring.

    `a` is (n, m) float32, `x` is (m,) float32. Arbitrary n/m: inputs are
    padded with the semiring's annihilator so padding never contributes.
    """
    a = jnp.asarray(a, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    n, m = a.shape
    bm = min(block_m, _pad_to(n, 8))
    bk = min(block_k, _pad_to(m, 8))
    np_, mp = _pad_to(n, bm), _pad_to(m, bk)

    # The annihilator for `combine` (so padded columns reduce to identity):
    #   plus_times: 0 * x = 0;  min_plus: inf + x = inf;
    #   or_and: min(-inf, x) = -inf (the max-identity).
    pad_a = IDENTITY[semiring]
    a_p = jnp.pad(a, ((0, np_ - n), (0, mp - m)), constant_values=pad_a)
    # x padding value is irrelevant given annihilator in A, but keep it inert.
    pad_x = jnp.inf if semiring == "min_plus" else 0.0
    x_p = jnp.pad(x, (0, mp - m), constant_values=pad_x)

    k_blocks = mp // bk
    out = pl.pallas_call(
        functools.partial(_matvec_kernel, semiring=semiring, k_blocks=k_blocks),
        grid=(np_ // bm, k_blocks),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(a_p, x_p)
    return out[:n]


# Matmul kernel (used by triangle counting and BC stage batching) ---------------


def _matmul_kernel(a_ref, b_ref, o_ref, *, semiring: str):
    k = pl.program_id(2)
    if semiring == "plus_times":
        part = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    elif semiring == "min_plus":
        part = jnp.min(a_ref[...][:, :, None] + b_ref[...][None, :, :], axis=1)
    elif semiring == "or_and":
        part = jnp.max(
            jnp.minimum(a_ref[...][:, :, None], b_ref[...][None, :, :]), axis=1
        )
    else:  # pragma: no cover - registry guards this
        raise ValueError(semiring)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, IDENTITY[semiring])

    o_ref[...] = _merge(semiring, o_ref[...], part)


@functools.partial(jax.jit, static_argnames=("semiring", "block"))
def semiring_matmul(a, b, *, semiring: str = "plus_times", block: int = 32):
    """C = A (combine/reduce) B over the given semiring; A (n,k), B (k,m)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    n, k = a.shape
    k2, m = b.shape
    assert k == k2, (k, k2)
    bs = min(block, _pad_to(max(n, k, m), 8))
    np_, kp, mp = _pad_to(n, bs), _pad_to(k, bs), _pad_to(m, bs)
    pad = IDENTITY[semiring]
    a_p = jnp.pad(a, ((0, np_ - n), (0, kp - k)), constant_values=pad)
    b_p = jnp.pad(b, ((0, kp - k), (0, mp - m)), constant_values=pad)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, semiring=semiring),
        grid=(np_ // bs, mp // bs, kp // bs),
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, k)),
            pl.BlockSpec((bs, bs), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:n, :m]


# Fused triangle-count kernel ----------------------------------------------------


def _tc_kernel(a_ik_ref, a_kj_ref, a_ij_ref, o_ref):
    """Partial sums of (A@A) * A per (i, j) output block, accumulated over k."""
    k = pl.program_id(2)
    c = jnp.dot(a_ik_ref[...], a_kj_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(c * a_ij_ref[...])


@functools.partial(jax.jit, static_argnames=("block",))
def triangle_count_fused(a, *, block: int = 32):
    """6 * (#triangles) = sum((A @ A) * A) for a symmetric 0/1 adjacency.

    Fused: the (A@A) block is multiplied by the A block and reduced inside
    the kernel, so the n^2 intermediate never round-trips through HBM.
    """
    a = jnp.asarray(a, jnp.float32)
    n = a.shape[0]
    bs = min(block, _pad_to(n, 8))
    np_ = _pad_to(n, bs)
    a_p = jnp.pad(a, ((0, np_ - n), (0, np_ - n)))
    g = np_ // bs
    partials = pl.pallas_call(
        _tc_kernel,
        grid=(g, g, g),
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, k)),
            pl.BlockSpec((bs, bs), lambda i, j, k: (k, j)),
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g, g), jnp.float32),
        interpret=True,
    )(a_p, a_p, a_p)
    return jnp.sum(partials)
