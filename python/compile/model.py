"""L2 — GraphBLAS-style GAP graph kernels in JAX, built on the L1 Pallas
semiring kernels.

These are the *offload-path* formulations of the paper's six GAP benchmark
kernels (BC, BFS, CC, PR, SSSP, TC): graph traversal as semiring linear
algebra over a dense adjacency representation (the paper's input graphs
are tiny — 32 nodes — so dense is the right layout for the MXU).

Every public function here is a pure, shape-static JAX function; they are
lowered once by `aot.py` to HLO text and executed from the Rust runtime
(`rust/src/runtime/`) on the PJRT CPU client. Python never runs at request
time.

Conventions
-----------
* `a`    — symmetric {0,1} adjacency matrix, float32, zero diagonal.
* `w`    — weight matrix, float32, `inf` where no edge, zero diagonal.
* `w0`   — {0, inf} matrix: 0 on edges *and* the diagonal, inf elsewhere
           (min-plus identity-preserving adjacency for label propagation).
* `src`  — one-hot float32 source-vertex vector.
* unreachable vertices get depth/dist `inf`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.semiring import semiring_matvec, triangle_count_fused

INF = jnp.inf


# -- PageRank -------------------------------------------------------------------


def pr_step(m, r, *, damping: float = 0.85):
    """One PageRank power iteration: r' = d * (M @ r) + (1 - d) / n.

    `m` is the column-normalized transition matrix transposed into
    row-major gather form, i.e. m[i, j] = a[j, i] / degree(j).
    """
    n = r.shape[0]
    contrib = semiring_matvec(m, r, semiring="plus_times")
    return damping * contrib + (1.0 - damping) / n


def pagerank(m, r0, *, iters: int = 20, damping: float = 0.85):
    """`iters` PageRank power iterations from initial distribution `r0`."""

    def body(_, r):
        return pr_step(m, r, damping=damping)

    return (jax.lax.fori_loop(0, iters, body, r0),)


# -- BFS ------------------------------------------------------------------------


def bfs(a, src):
    """Level-synchronous BFS; returns float32 depths (`inf` = unreachable).

    Frontier expansion is the (or, and) semiring matvec: next = A^T ∨.∧ f.
    """
    n = a.shape[0]
    depth0 = jnp.where(src > 0.0, 0.0, INF)

    def body(l, state):
        depth, frontier = state
        nxt = semiring_matvec(a, frontier, semiring="or_and")
        newly = (nxt > 0.0) & jnp.isinf(depth)
        depth = jnp.where(newly, jnp.float32(l + 1), depth)
        return depth, newly.astype(jnp.float32)

    depth, _ = jax.lax.fori_loop(0, n - 1, body, (depth0, src))
    return (depth,)


# -- SSSP (Bellman-Ford over the (min, +) semiring) ------------------------------


def sssp(w, src):
    """Single-source shortest paths: n-1 rounds of d' = min(d, W^T min.+ d)."""
    n = w.shape[0]
    dist0 = jnp.where(src > 0.0, 0.0, INF)

    def body(_, dist):
        relax = semiring_matvec(w, dist, semiring="min_plus")
        return jnp.minimum(dist, relax)

    return (jax.lax.fori_loop(0, n - 1, body, dist0),)


# -- Connected components (min label propagation) --------------------------------


def connected_components(w0):
    """Label propagation: l' = min(l, W0 min.+ l) until fixpoint (n rounds).

    Equivalent component labelling to Shiloach-Vishkin (the paper's CC
    variant): every vertex ends with the minimum vertex id of its component.
    """
    n = w0.shape[0]
    labels0 = jnp.arange(n, dtype=jnp.float32)

    def body(_, labels):
        prop = semiring_matvec(w0, labels, semiring="min_plus")
        return jnp.minimum(labels, prop)

    return (jax.lax.fori_loop(0, n, body, labels0),)


# -- Triangle counting -----------------------------------------------------------


def triangle_count(a):
    """#triangles = sum((A @ A) ⊙ A) / 6, fused in one Pallas kernel."""
    return (triangle_count_fused(a) / 6.0,)


# -- Betweenness centrality (Brandes, level-synchronous linear-algebra form) ------


def _bc_single_source(a, src):
    """Brandes dependency accumulation for one source, all as matvecs."""
    n = a.shape[0]
    depth0 = jnp.where(src > 0.0, 0.0, INF)
    sigma0 = src  # path counts

    def fwd(l, state):
        depth, sigma = state
        f = jnp.where(depth == jnp.float32(l), sigma, 0.0)
        t = semiring_matvec(a, f, semiring="plus_times")
        newly = (t > 0.0) & jnp.isinf(depth)
        depth = jnp.where(newly, jnp.float32(l + 1), depth)
        sigma = sigma + jnp.where(depth == jnp.float32(l + 1), t, 0.0)
        return depth, sigma

    depth, sigma = jax.lax.fori_loop(0, n - 1, fwd, (depth0, sigma0))

    safe_sigma = jnp.where(sigma > 0.0, sigma, 1.0)

    def bwd(i, delta):
        l = jnp.float32(n - 1) - i  # levels n-1 .. 1
        coef = jnp.where(depth == l, (1.0 + delta) / safe_sigma, 0.0)
        contrib = semiring_matvec(a, coef, semiring="plus_times")
        upd = jnp.where(depth == l - 1.0, sigma * contrib, 0.0)
        return delta + upd

    delta = jax.lax.fori_loop(0, n - 1, bwd, jnp.zeros(n, jnp.float32))
    # The source accumulates spurious dependency; zero it out.
    return jnp.where(src > 0.0, 0.0, delta)


def betweenness_centrality(a):
    """Exact BC over all sources (unnormalized; each pair counted twice for
    undirected graphs, matching GAP's convention of halving at the end)."""
    n = a.shape[0]

    def body(s, acc):
        src = (jnp.arange(n) == s).astype(jnp.float32)
        return acc + _bc_single_source(a, src)

    bc = jax.lax.fori_loop(0, n, body, jnp.zeros(n, jnp.float32))
    return (bc / 2.0,)


# -- Export registry (consumed by aot.py and the Rust manifest) -------------------


def _specs(n: int, *shapes):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]


def export_registry(n: int):
    """name -> (fn, example arg specs). All fns return tuples (see aot.py)."""
    return {
        "pagerank": (
            functools.partial(pagerank, iters=20, damping=0.85),
            _specs(n, (n, n), (n,)),
        ),
        "bfs": (bfs, _specs(n, (n, n), (n,))),
        "sssp": (sssp, _specs(n, (n, n), (n,))),
        "cc": (connected_components, _specs(n, (n, n))),
        "tc": (triangle_count, _specs(n, (n, n))),
        "bc": (betweenness_centrality, _specs(n, (n, n))),
    }
