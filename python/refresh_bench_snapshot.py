#!/usr/bin/env python3
"""Refresh a committed BENCH_* snapshot file from a nightly artifact.

Usage:
    python3 python/refresh_bench_snapshot.py NIGHTLY_JSON SNAPSHOT_JSON

NIGHTLY_JSON is a sweep artifact as written by `repro ... --out`
(a JSON array of row objects, e.g. `bench-results/cross_shard.json`).
SNAPSHOT_JSON is the committed snapshot wrapper (e.g.
`BENCH_cross_shard.json`): an object carrying provenance metadata
(`artifact`, `produced_by`, `row_schema`, `status`) around a `rows`
array. The script replaces `rows` with the artifact's rows and rewrites
`status` to record the refresh, leaving every other metadata field
untouched — so the first real nightly run turns the schema-only
placeholder into a filled table without anyone hand-editing JSON.

Rows are lightly sanity-checked against `row_schema` when the snapshot
carries one: a nightly row missing a schema-documented field is
reported and the refresh aborts, because a silently narrowed snapshot
would make future diffs lie.

Exit status: 0 on a successful refresh, 1 on any problem (missing or
malformed input, schema mismatch). bench.yml runs this after the whale
sweep and uploads the refreshed file alongside the artifacts;
committing it back to the repo stays a human decision.

Stdlib-only by design: the CI image and the dev container carry no
third-party Python packages.
"""

import json
import sys
from datetime import datetime, timezone
from pathlib import Path


def fail(msg):
    print(f"error: {msg}", file=sys.stderr)
    return 1


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        return fail("usage: refresh_bench_snapshot.py NIGHTLY_JSON SNAPSHOT_JSON")
    nightly_path, snapshot_path = Path(argv[0]), Path(argv[1])

    try:
        rows = json.loads(nightly_path.read_text())
    except (OSError, json.JSONDecodeError) as err:
        return fail(f"cannot read {nightly_path}: {err}")
    if not isinstance(rows, list) or not all(isinstance(r, dict) for r in rows):
        return fail(f"{nightly_path} is not a JSON array of row objects")
    if not rows:
        return fail(f"{nightly_path} has no rows; refusing to blank the snapshot")

    try:
        snapshot = json.loads(snapshot_path.read_text())
    except (OSError, json.JSONDecodeError) as err:
        return fail(f"cannot read {snapshot_path}: {err}")
    if not isinstance(snapshot, dict) or "rows" not in snapshot:
        return fail(f"{snapshot_path} is not a snapshot wrapper (no 'rows' field)")

    schema = snapshot.get("row_schema")
    if isinstance(schema, dict):
        for i, row in enumerate(rows):
            missing = [f for f in schema if f not in row]
            if missing:
                return fail(
                    f"{nightly_path} row {i} is missing schema field(s) "
                    f"{missing}; snapshot not refreshed"
                )

    stamp = datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ")
    snapshot["rows"] = rows
    snapshot["status"] = (
        f"snapshot of {len(rows)} row(s) refreshed {stamp} from "
        f"{nightly_path.name}; re-refresh from any later nightly artifact "
        f"with python/refresh_bench_snapshot.py"
    )
    snapshot_path.write_text(json.dumps(snapshot, indent=2) + "\n")
    print(f"{snapshot_path}: {len(rows)} row(s) from {nightly_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
