"""AOT path: lowering to HLO text must succeed and produce parseable,
non-trivial modules with the manifest contract aot.py promises."""

import json

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_lower_all_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    manifest = aot.lower_all(str(out), [8])
    assert len(manifest["entries"]) == len(model.export_registry(8))
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk["format"] == "hlo-text"
    assert on_disk["return_tuple"] is True
    for entry in on_disk["entries"]:
        path = out / entry["file"]
        text = path.read_text()
        assert text.startswith("HloModule"), entry["file"]
        # Every artifact mentions its parameter shapes.
        for shape in entry["inputs"]:
            token = f"f32[{','.join(str(d) for d in shape)}]"
            assert token in text, f"{entry['file']} missing {token}"


def test_lowered_kernels_contain_loops_not_constants(tmp_path):
    # Guard against accidental constant folding of the whole kernel:
    # the exported modules must keep their while loops.
    out = tmp_path / "a"
    aot.lower_all(str(out), [8])
    pr = (out / "pagerank_n8.hlo.txt").read_text()
    assert "while" in pr, "pagerank should lower to a while loop"
