"""L1 correctness: Pallas semiring kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-multiples of the block size, the
degenerate 1x1, and the padded edge just past a block boundary), block
sizes, and all three semirings; assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.semiring import (
    SEMIRINGS,
    semiring_matmul,
    semiring_matvec,
    triangle_count_fused,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _mat(rng, n, m, lo=-4.0, hi=4.0):
    return (rng.random((n, m)) * (hi - lo) + lo).astype(np.float32)


def _tol(semiring):
    # plus_times accumulates; others are exact selections.
    return dict(atol=1e-4, rtol=1e-4) if semiring == "plus_times" else dict(atol=0)


@pytest.mark.parametrize("semiring", SEMIRINGS)
@settings(**SETTINGS)
@given(
    n=st.integers(1, 70),
    m=st.integers(1, 70),
    block=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(semiring, n, m, block, seed):
    rng = np.random.default_rng(seed)
    a = _mat(rng, n, m)
    x = _mat(rng, 1, m)[0]
    got = semiring_matvec(a, x, semiring=semiring, block_m=block, block_k=block)
    want = ref.matvec_ref(a, x, semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(semiring))


@pytest.mark.parametrize("semiring", SEMIRINGS)
@settings(**SETTINGS)
@given(
    n=st.integers(1, 40),
    k=st.integers(1, 40),
    m=st.integers(1, 40),
    block=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(semiring, n, k, m, block, seed):
    rng = np.random.default_rng(seed)
    a = _mat(rng, n, k)
    b = _mat(rng, k, m)
    got = semiring_matmul(a, b, semiring=semiring, block=block)
    want = ref.matmul_ref(a, b, semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(semiring))


@pytest.mark.parametrize("semiring", SEMIRINGS)
def test_matvec_with_inf_no_edge(semiring):
    """min_plus graphs carry inf entries; the kernel must not poison others."""
    a = np.array([[0.0, np.inf], [1.0, 0.0]], np.float32)
    x = np.array([3.0, 5.0], np.float32)
    got = semiring_matvec(a, x, semiring=semiring)
    want = ref.matvec_ref(a, x, semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(n=st.integers(1, 48), p=st.floats(0.0, 0.9), seed=st.integers(0, 2**31 - 1))
def test_triangle_count_fused(n, p, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < p).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    got = float(triangle_count_fused(a))
    want = float(ref.triangle_count_ref(a))
    assert got == pytest.approx(want), (got, want)
    assert want % 6 == 0  # sanity on the oracle itself


def test_triangle_count_known():
    # K4 has 4 triangles.
    a = (np.ones((4, 4)) - np.eye(4)).astype(np.float32)
    assert float(triangle_count_fused(a)) == 24.0


@pytest.mark.parametrize("semiring", SEMIRINGS)
def test_matvec_identity_sizes(semiring):
    """1x1 and exactly-one-block shapes (no padding path)."""
    for n in (1, 32):
        a = np.ones((n, n), np.float32)
        x = np.arange(n, dtype=np.float32)
        got = semiring_matvec(a, x, semiring=semiring)
        want = ref.matvec_ref(a, x, semiring)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
