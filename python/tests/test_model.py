"""L2 correctness: the JAX graph kernels vs NumPy graph oracles."""

import heapq

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

SETTINGS = dict(max_examples=15, deadline=None)


def random_graph(rng, n, p):
    a = (rng.random((n, n)) < p).astype(np.float32)
    a = np.triu(a, 1)
    return a + a.T


def graph_strategy():
    return st.tuples(st.integers(2, 20), st.floats(0.05, 0.7), st.integers(0, 2**31 - 1))


# -- oracles ----------------------------------------------------------------


def bfs_oracle(a, src):
    n = a.shape[0]
    depth = np.full(n, np.inf)
    depth[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in range(n):
                if a[u, v] > 0 and np.isinf(depth[v]):
                    depth[v] = d + 1
                    nxt.append(v)
        frontier = nxt
        d += 1
    return depth


def dijkstra_oracle(w, src):
    n = w.shape[0]
    dist = np.full(n, np.inf)
    dist[src] = 0.0
    heap = [(0.0, src)]
    while heap:
        d, u = heapq.heappop(heap)
        if d > dist[u]:
            continue
        for v in range(n):
            if u != v and np.isfinite(w[u, v]):
                nd = d + w[u, v]
                if nd < dist[v] - 1e-9:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
    return dist


def cc_oracle(a):
    n = a.shape[0]
    label = np.arange(n)
    for _ in range(n):
        changed = False
        for u in range(n):
            for v in range(n):
                if (u == v or a[u, v] > 0) and label[v] < label[u]:
                    label[u] = label[v]
                    changed = True
        if not changed:
            break
    return label.astype(np.float32)


def brandes_oracle(a):
    n = a.shape[0]
    bc = np.zeros(n)
    for s in range(n):
        depth = bfs_oracle(a, s)
        # path counts
        sigma = np.zeros(n)
        sigma[s] = 1
        order = sorted(range(n), key=lambda v: depth[v] if np.isfinite(depth[v]) else 1e18)
        for v in order:
            if not np.isfinite(depth[v]) or depth[v] == 0:
                continue
            sigma[v] = sum(
                sigma[u] for u in range(n) if a[u, v] > 0 and depth[u] == depth[v] - 1
            )
        delta = np.zeros(n)
        for v in reversed(order):
            if not np.isfinite(depth[v]):
                continue
            for u in range(n):
                if a[u, v] > 0 and depth[u] == depth[v] - 1 and sigma[v] > 0:
                    delta[u] += sigma[u] / sigma[v] * (1 + delta[v])
        delta[s] = 0
        bc += delta
    return bc / 2.0


# -- tests --------------------------------------------------------------------


@settings(**SETTINGS)
@given(graph_strategy())
def test_bfs_matches_oracle(params):
    n, p, seed = params
    rng = np.random.default_rng(seed)
    a = random_graph(rng, n, p)
    depth, = model.bfs(a, np.eye(n, dtype=np.float32)[0])
    np.testing.assert_allclose(np.asarray(depth), bfs_oracle(a, 0))


@settings(**SETTINGS)
@given(graph_strategy())
def test_sssp_matches_dijkstra(params):
    n, p, seed = params
    rng = np.random.default_rng(seed)
    a = random_graph(rng, n, p)
    w = np.where(a > 0, (rng.integers(1, 256, (n, n))).astype(np.float32), np.inf)
    w = np.minimum(w, w.T)
    np.fill_diagonal(w, 0.0)
    dist, = model.sssp(w.astype(np.float32), np.eye(n, dtype=np.float32)[0])
    np.testing.assert_allclose(np.asarray(dist), dijkstra_oracle(w, 0), rtol=1e-6)


@settings(**SETTINGS)
@given(graph_strategy())
def test_cc_matches_oracle(params):
    n, p, seed = params
    rng = np.random.default_rng(seed)
    a = random_graph(rng, n, p)
    w0 = np.where(a > 0, 0.0, np.inf).astype(np.float32)
    np.fill_diagonal(w0, 0.0)
    labels, = model.connected_components(w0)
    np.testing.assert_allclose(np.asarray(labels), cc_oracle(a))


@settings(**SETTINGS)
@given(graph_strategy())
def test_tc_matches_trace_formula(params):
    n, p, seed = params
    rng = np.random.default_rng(seed)
    a = random_graph(rng, n, p)
    count, = model.triangle_count(a)
    want = np.trace(a @ a @ a) / 6.0
    assert float(count) == pytest.approx(want)


@settings(**SETTINGS)
@given(graph_strategy())
def test_pagerank_sums_to_one_and_matches_power_iteration(params):
    n, p, seed = params
    rng = np.random.default_rng(seed)
    a = random_graph(rng, n, p)
    deg = a.sum(axis=1)
    m = (a / np.maximum(deg, 1.0)[None, :]).astype(np.float32)
    r0 = np.full(n, 1.0 / n, np.float32)
    r, = model.pagerank(m, r0, iters=20, damping=0.85)
    # NumPy power iteration oracle.
    want = r0.astype(np.float64)
    for _ in range(20):
        want = 0.85 * (m.astype(np.float64) @ want) + 0.15 / n
    np.testing.assert_allclose(np.asarray(r), want, rtol=1e-4, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(st.tuples(st.integers(3, 10), st.floats(0.2, 0.7), st.integers(0, 2**31 - 1)))
def test_bc_matches_brandes_oracle(params):
    n, p, seed = params
    rng = np.random.default_rng(seed)
    a = random_graph(rng, n, p)
    bc, = model.betweenness_centrality(a)
    np.testing.assert_allclose(np.asarray(bc), brandes_oracle(a), rtol=1e-4, atol=1e-4)


def test_export_registry_covers_all_kernels():
    reg = model.export_registry(8)
    assert set(reg) == {"pagerank", "bfs", "sssp", "cc", "tc", "bc"}
    for name, (fn, specs) in reg.items():
        out = fn(*[np.zeros(s.shape, np.float32) for s in specs])
        assert isinstance(out, tuple) and len(out) == 1, name
