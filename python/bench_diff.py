#!/usr/bin/env python3
"""Diff two nightly BENCH_* JSON artifact directories and flag perf
regressions.

Usage:
    python3 python/bench_diff.py OLD_DIR NEW_DIR [--threshold 10]

OLD_DIR / NEW_DIR are two `bench-results/` trees as uploaded by
`.github/workflows/bench.yml` (the files may sit at any depth — `gh run
download` nests them under the artifact name; the first match by file
name wins). Rows are matched *structurally* by key fields, so JSON
arrays that changed order still diff correctly:

    pool_scaling.json   keyed by (shards)          throughput_rps, speedup
    admission.json      keyed by (mode, offered)   throughput_rps
    intra.json          keyed by (kernel)          pair_speedup,
                                                   parallel_for_speedup
    cross_shard.json    keyed by (kernel,          speedup_vs_pair,
                                  max_borrow)      speedup_vs_serial
    chaos.json          keyed by (seed, round)     recovered_ratio
    plan.json           keyed by (config)          speedup_vs_baseline
    stream.json         keyed by (scenario,        updates_per_sec
                                  batch)

Every metric is higher-is-better. A metric that drops by more than
--threshold percent (default 10) counts as a regression; the script
prints one line per compared metric and exits non-zero when any
regression was found. Missing files, unmatched rows, or zero baselines
are reported and skipped — a partial artifact must not fake a pass on
data it does not have, but also must not fail the diff outright
(bench.yml runs this as a soft-fail step; see ARCHITECTURE.md §CI).

Stdlib-only by design: the CI image and the dev container carry no
third-party Python packages.
"""

import argparse
import json
import sys
from pathlib import Path

# file name -> (key fields, higher-is-better metric fields)
SPECS = {
    "pool_scaling.json": (("shards",), ("throughput_rps", "speedup")),
    "admission.json": (("mode", "offered"), ("throughput_rps",)),
    "intra.json": (("kernel",), ("pair_speedup", "parallel_for_speedup")),
    "cross_shard.json": (
        ("kernel", "max_borrow"),
        ("speedup_vs_pair", "speedup_vs_serial"),
    ),
    # recovered_ratio is ok/offered per soak round; the in-sweep gates
    # pin it at 1.0 with replay on, so any drop is a hard signal, not
    # runner noise.
    "chaos.json": (("seed", "round"), ("recovered_ratio",)),
    # One row per plan source (baseline / forced statics / tuner); the
    # baseline row's speedup is pinned at 1.0 by construction, so only
    # the other rows trend.
    "plan.json": (("config",), ("speedup_vs_baseline",)),
    # One row per edge-stream scenario; accepted updates/sec through the
    # pinned parse -> analytics -> emit pipeline is the trend series
    # (the correctness gates inside the sweep are hard, so a row that
    # exists at all already passed its bitwise oracles).
    "stream.json": (("scenario", "batch"), ("updates_per_sec",)),
}


def find_file(root, name):
    """First file called `name` anywhere under `root`, or None."""
    direct = root / name
    if direct.is_file():
        return direct
    matches = sorted(root.rglob(name))
    return matches[0] if matches else None


def load_rows(path):
    """Parse a JSON array of objects; None (with a note) on anything else."""
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as err:
        print(f"note: cannot read {path}: {err}", file=sys.stderr)
        return None
    if not isinstance(data, list) or not all(isinstance(r, dict) for r in data):
        print(f"note: {path} is not a JSON array of objects", file=sys.stderr)
        return None
    return data


def index_rows(rows, key_fields):
    """Map key-tuple -> row. Rows missing a key field are skipped."""
    indexed = {}
    for row in rows:
        try:
            key = tuple(row[f] for f in key_fields)
        except KeyError:
            continue
        indexed[key] = row
    return indexed


def diff_file(name, old_path, new_path, threshold):
    """Compare one artifact file; return the number of regressions."""
    key_fields, metrics = SPECS[name]
    old_rows = load_rows(old_path)
    new_rows = load_rows(new_path)
    if old_rows is None or new_rows is None:
        return 0
    old_by_key = index_rows(old_rows, key_fields)
    new_by_key = index_rows(new_rows, key_fields)
    regressions = 0
    for key in sorted(old_by_key, key=repr):
        if key not in new_by_key:
            print(f"note: {name}: row {key} missing from the new run", file=sys.stderr)
            continue
        old_row, new_row = old_by_key[key], new_by_key[key]
        label = ", ".join(f"{f}={v}" for f, v in zip(key_fields, key))
        for metric in metrics:
            old_val, new_val = old_row.get(metric), new_row.get(metric)
            if not isinstance(old_val, (int, float)) or not isinstance(
                new_val, (int, float)
            ):
                continue
            if old_val <= 0:
                print(f"note: {name} [{label}] {metric}: zero baseline, skipped",
                      file=sys.stderr)
                continue
            change_pct = (new_val - old_val) / old_val * 100.0
            verdict = "ok"
            if change_pct < -threshold:
                verdict = "REGRESSION"
                regressions += 1
            print(
                f"{name} [{label}] {metric}: "
                f"{old_val:.3g} -> {new_val:.3g} ({change_pct:+.1f}%) {verdict}"
            )
    return regressions


def main(argv=None):
    parser = argparse.ArgumentParser(
        description="diff two nightly bench JSON artifact directories"
    )
    parser.add_argument("old_dir", type=Path, help="baseline bench-results tree")
    parser.add_argument("new_dir", type=Path, help="candidate bench-results tree")
    parser.add_argument(
        "--threshold",
        type=float,
        default=10.0,
        help="regression threshold in percent (default: 10)",
    )
    args = parser.parse_args(argv)

    for d in (args.old_dir, args.new_dir):
        if not d.is_dir():
            print(f"note: {d} is not a directory; nothing to diff", file=sys.stderr)
            return 0

    regressions = 0
    compared = 0
    for name in SPECS:
        old_path = find_file(args.old_dir, name)
        new_path = find_file(args.new_dir, name)
        if old_path is None or new_path is None:
            missing = "old" if old_path is None else "new"
            print(f"note: {name} absent from the {missing} run, skipped",
                  file=sys.stderr)
            continue
        compared += 1
        regressions += diff_file(name, old_path, new_path, args.threshold)

    if compared == 0:
        print("note: no comparable artifact files found", file=sys.stderr)
        return 0
    if regressions:
        print(f"{regressions} metric(s) regressed beyond {args.threshold}%")
        return 1
    print(f"no regression beyond {args.threshold}% across {compared} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
