//! Microbench of Relic's core data structure: the lock-free SPSC queue.
//! Single-threaded push/pop throughput, ping-pong across two threads,
//! and a comparison against a mutex-guarded deque (the GNU-style team
//! queue) — quantifying why the paper builds on an SPSC ring.
//!
//! Run: `cargo bench --bench spsc_queue`

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use relic_smt::relic::SpscQueue;
use relic_smt::runtimes::common::TeamQueue;

fn main() {
    common::section("single-threaded push+pop (queue mechanics only)");
    // Batches of 64 per timed iteration to push clock/preemption noise
    // below the signal.
    let q: SpscQueue<u64> = SpscQueue::new(128);
    common::bench("spsc/push+pop-x64", 100_000, 2_000, || {
        for i in 0..64u64 {
            let _ = q.push(i);
            std::hint::black_box(q.pop());
        }
    });

    let tq: TeamQueue<u64> = TeamQueue::new();
    common::bench("mutex-deque/push+pop-x64", 20_000, 1_000, || {
        for i in 0..64u64 {
            tq.push(i);
            std::hint::black_box(tq.try_pop());
        }
    });

    // On 1-CPU hosts the threads time-share; yield instead of spinning
    // so the bench completes quickly (absolute numbers are only
    // meaningful on multi-core/SMT hosts).
    common::section("cross-thread ping-pong (100k items)");
    for &cap in &[16usize, 128, 1024] {
        let q: Arc<SpscQueue<u64>> = Arc::new(SpscQueue::new(cap));
        let stop = Arc::new(AtomicBool::new(false));
        let consumer = {
            let q = Arc::clone(&q);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut sum = 0u64;
                while !stop.load(Ordering::Acquire) {
                    if let Some(v) = q.pop() {
                        sum += v;
                    } else {
                        std::thread::yield_now();
                    }
                }
                while let Some(v) = q.pop() {
                    sum += v;
                }
                sum
            })
        };
        let n = 100_000u64;
        let t0 = std::time::Instant::now();
        for i in 0..n {
            let mut v = i;
            loop {
                match q.push(v) {
                    Ok(()) => break,
                    Err(back) => {
                        v = back;
                        std::thread::yield_now();
                    }
                }
            }
        }
        stop.store(true, Ordering::Release);
        let sum = consumer.join().unwrap();
        let dt = t0.elapsed();
        assert_eq!(sum, n * (n - 1) / 2);
        println!(
            "spsc/x-thread/cap{cap:<5} {:>10.1} ns/item ({n} items in {dt:?})",
            dt.as_nanos() as f64 / n as f64
        );
    }
}
