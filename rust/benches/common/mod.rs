//! Shared mini-bench harness (no criterion in the offline environment):
//! warmup + timed repetitions with mean/min/max reporting, plus the
//! simulator-backed figure helpers every bench target uses.

// Each bench binary compiles its own copy of this module and uses a
// subset of it; the unused remainder is not dead code of the crate.
#![allow(dead_code)]

use std::time::Instant;

/// Time `f` `iters` times after `warmup`; print a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, iters: u64, warmup: u64, mut f: F) {
    for _ in 0..warmup {
        f();
    }
    let mut best = u128::MAX;
    let mut worst = 0u128;
    let t0 = Instant::now();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let ns = t.elapsed().as_nanos();
        best = best.min(ns);
        worst = worst.max(ns);
    }
    let total = t0.elapsed().as_nanos();
    println!(
        "{name:<44} {:>12.1} ns/iter (min {:>10} max {:>10}, {iters} iters)",
        total as f64 / iters as f64,
        best,
        worst
    );
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
