//! Bench target for **Figure 3**: Relic's speedups over serial on the
//! seven paper kernels (simulated), plus wall-clock microbenches of the
//! native Relic hot paths (submit/wait and pair dispatch).
//!
//! Run: `cargo bench --bench fig3_relic`

mod common;

use std::sync::atomic::{AtomicU64, Ordering};

use relic_smt::bench::{figures, Workload};
use relic_smt::relic::Relic;
use relic_smt::smtsim::CoreConfig;

fn main() {
    let cfg = CoreConfig::default();

    common::section("Figure 3 (simulated SMT core) — Relic speedup over serial");
    let cells = figures::fig3(&cfg);
    println!("{}", figures::render_matrix(&cells));

    common::section("Relic native hot paths (wall-clock, this host)");
    let relic = Relic::new();
    static SINK: AtomicU64 = AtomicU64::new(0);
    fn tiny(arg: usize) {
        SINK.fetch_add(arg as u64, Ordering::Relaxed);
    }

    // submit+wait round trip for a trivial task (framework overhead;
    // on 1-CPU hosts this is scheduling-quantum bound — see the
    // submit-only bench below for the producer-side cost).
    common::bench("relic/submit+wait/empty-task", 2_000, 100, || {
        relic.submit(tiny, 1).expect("queue");
        relic.wait();
    });

    // Producer-side submit cost in isolation: park the assistant, time
    // only the 64-submission bursts (drain excluded from the clock).
    {
        let rounds = 20_000u32;
        relic.sleep_hint();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let mut timed = std::time::Duration::ZERO;
        for _ in 0..rounds {
            let t0 = std::time::Instant::now();
            for i in 0..64 {
                relic.submit(tiny, i).expect("queue");
            }
            timed += t0.elapsed();
            relic.wake_up_hint();
            relic.wait();
            relic.sleep_hint();
        }
        relic.wake_up_hint();
        println!(
            "{:<44} {:>12.2} ns/submit (assistant parked, {} bursts of 64)",
            "relic/submit-only",
            timed.as_nanos() as f64 / (rounds as f64 * 64.0),
            rounds
        );
    }

    // pair() with both sides doing one CC instance (the paper protocol).
    let w = Workload::new("cc");
    let sink = AtomicU64::new(0);
    common::bench("relic/pair/cc-instance-each", 2_000, 200, || {
        let task = || {
            sink.fetch_add(w.run_native(), Ordering::Relaxed);
        };
        relic.pair(&task, &task);
    });

    // run_batch amortization: 64 tiny closures per call.
    let tasks: Vec<_> = (0..64usize)
        .map(|i| {
            let sink = &sink;
            move || {
                sink.fetch_add(i as u64, Ordering::Relaxed);
            }
        })
        .collect();
    common::bench("relic/run_batch/64-tiny-tasks", 2_000, 200, || {
        relic.run_batch(&tasks);
    });
    std::hint::black_box(sink.load(Ordering::Relaxed));
}
