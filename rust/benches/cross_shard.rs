//! Cross-shard cooperative-parallelism benchmark: one whale request
//! borrowing idle SMT pair-shards through the lease broker.
//!
//! Reuses the `repro whale` sweep (`figures::whale_sweep`): for PR and
//! BC on a Kronecker graph, measure serial, single-pair fork-join (the
//! 2-thread ceiling), and the engine at borrow caps {0, B}. Every
//! engine response is asserted bitwise equal to the serial checksum —
//! the bench doubles as the cross-shard determinism gate, and the
//! `max_borrow = 0` rows are the degeneracy anchor (no broker at all).
//!
//! Run: `cargo bench --bench cross_shard [-- --shards N --max-borrow B
//! --scale S --reps R --no-pin]`
//! The headline claim (`vs pair > 1` at borrow > 0) needs >= 2 idle
//! physical core pairs; elsewhere the checksum gate still runs.

mod common;

use relic_smt::bench::figures;
use relic_smt::cli::Args;
use relic_smt::coordinator::EngineConfig;
use relic_smt::relic::{affinity, pool, PoolConfig};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let shards = args.get_u64("shards", 2).max(1) as usize;
    let scale = args.get_u64("scale", 10) as u32;
    let reps = args.get_u64("reps", 3);
    let cap = args.get_u64("max-borrow", (shards - 1) as u64) as usize;
    let pin = !args.flag("no-pin");

    println!("host: {}", affinity::topology_summary());
    let pairs = pool::physical_core_pairs();
    println!("physical core pairs: {pairs:?}");
    if pairs.len() < shards {
        println!(
            "WARNING: fewer detected core pairs than shards — borrowed shards \
             share cores with the owner and the vs-pair speedup flattens."
        );
    }

    common::section("whale-scaling: serial vs pair vs borrowing engine");
    let mut borrows = vec![0usize];
    if cap > 0 {
        borrows.push(cap);
    }
    let template = EngineConfig {
        pool: PoolConfig { pin, ..PoolConfig::default() },
        ..EngineConfig::default()
    };
    let rows = figures::whale_sweep(&template, shards, &borrows, scale, reps);
    print!("{}", figures::render_whale(&rows));
}
