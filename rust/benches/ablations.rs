//! Ablation bench target (DESIGN.md §6 A1–A3):
//!   A1 SPSC queue capacity sweep,
//!   A2 waiting-mechanism sweep (spin / spin+pause / hybrid / park),
//!   A3 SMT fetch-policy sensitivity.
//!
//! Run: `cargo bench --bench ablations`

mod common;

use relic_smt::bench::{harness::geomean, Workload};
use relic_smt::smtsim::CoreConfig;

fn main() {
    let cfg = CoreConfig::default();

    common::section("A2 — waiting mechanism (Relic assistant), per kernel");
    let rows = relic_smt::bench::ablation::waiting_mechanism(&cfg);
    println!("{}", relic_smt::bench::ablation::render(&rows, ""));
    // Geomean per setting.
    for setting in ["spin", "spin+pause", "hybrid", "park"] {
        let vals: Vec<f64> = rows
            .iter()
            .filter(|r| r.setting == setting)
            .map(|r| r.speedup)
            .collect();
        println!("geomean {:<12} {:.3}", setting, geomean(vals));
    }

    common::section("A1 — SPSC queue capacity (batch of 16 CC tasks)");
    let rows = relic_smt::bench::ablation::queue_capacity(&cfg, &[2, 4, 8, 16, 32, 64, 128]);
    println!("{}", relic_smt::bench::ablation::render(&rows, ""));

    common::section("A3 — SMT fetch policy");
    let rows = relic_smt::bench::ablation::fetch_policy(&cfg);
    println!("{}", relic_smt::bench::ablation::render(&rows, ""));

    common::section("native SPSC queue capacity (wall-clock run_batch, this host)");
    for cap in [8usize, 32, 128, 512] {
        let relic = relic_smt::relic::Relic::with_config(relic_smt::relic::RelicConfig {
            queue_capacity: cap,
            ..Default::default()
        });
        let w = Workload::new("cc");
        let sink = std::sync::atomic::AtomicU64::new(0);
        let tasks: Vec<_> = (0..16)
            .map(|_| {
                let (w, sink) = (&w, &sink);
                move || {
                    sink.fetch_add(w.run_native(), std::sync::atomic::Ordering::Relaxed);
                }
            })
            .collect();
        common::bench(&format!("relic/run_batch16-cc/cap{cap}"), 1_000, 100, || {
            relic.run_batch(&tasks);
        });
    }
}
