//! Intra-kernel fork-join benchmark: for every paper workload, compare
//!
//! * **serial** — one instance on the main thread;
//! * **pair** — the paper's protocol, two whole instances co-scheduled
//!   on the SMT pair via `Relic::pair` (throughput: needs two requests);
//! * **parallel_for** — one instance with its hot loops split across
//!   the pair through `Relic::scope` (latency: one request finishes
//!   faster — the coordinator's odd-leftover scenario).
//!
//! Plus a document-batch row for the JSON parser, whose single-document
//! parse is a sequential dependence chain.
//!
//! Run: `cargo bench --bench parallel_for [-- --iters N]`
//! Meaningful numbers need a host with an SMT sibling pair; elsewhere
//! the checksum assertions still make it a correctness smoke test.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};

use relic_smt::bench::figures;
use relic_smt::bench::measure;
use relic_smt::cli::Args;
use relic_smt::json;
use relic_smt::relic::{affinity, Par, Relic, RelicConfig, Schedule};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let iters = args.get_u64("iters", 2_000);
    let warmup = args.get_u64("warmup", 200);

    println!("host: {}", affinity::topology_summary());
    let pair = affinity::smt_sibling_pair();
    if pair.is_none() {
        println!("WARNING: no SMT siblings — speedups below are not meaningful on this host.");
    }
    if let Some((main_cpu, _)) = pair {
        affinity::pin_to_cpu(main_cpu);
    }
    let relic = Relic::with_config(RelicConfig {
        assistant_cpu: pair.map(|p| p.1),
        ..Default::default()
    });

    // The measurement protocol lives in figures::intra_kernel (shared
    // with `repro intra`); it also asserts every parallel checksum
    // equals its serial one, so this doubles as a correctness gate.
    // Static is this bench's subject (PR 1's split); the schedule
    // ablation lives in `cargo bench --bench schedule`.
    common::section("per-kernel: serial vs pair vs parallel_for");
    let rows = figures::intra_kernel(&relic, Schedule::Static, iters, warmup);
    print!("{}", figures::render_intra(&rows));

    common::section("json document-batch splitting (8 widgets/iteration)");
    let docs: Vec<&[u8]> = vec![json::WIDGET; 8];
    let sink = AtomicU64::new(0);
    let serial = measure(iters, warmup, || {
        for d in &docs {
            sink.fetch_add(
                json::parse(d).expect("widget parses").node_count() as u64,
                Ordering::Relaxed,
            );
        }
    });
    let par = Par::Relic(&relic);
    let batched = measure(iters, warmup, || {
        for r in json::parse_batch_par(&docs, &par) {
            sink.fetch_add(r.expect("widget parses").node_count() as u64, Ordering::Relaxed);
        }
    });
    std::hint::black_box(sink.load(Ordering::Relaxed));
    println!(
        "json-x8 {:>14.1} ns serial, {:>10.1} ns split ({:.3}x)",
        serial.mean_ns,
        batched.mean_ns,
        serial.mean_ns / batched.mean_ns
    );

    println!("\nrelic: {}", relic.stats().report());
}
