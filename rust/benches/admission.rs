//! Admission benchmark: open-loop arrival sweep over the engine's
//! three front doors — blocking `submit` (PR 2's counted
//! backpressure), non-blocking `try_submit` (QueueFull bounces are
//! dropped, open-loop style), and `submit_or_park` (producer sleeps on
//! the shard's drain signal) — plus shed-rate vs offered load when a
//! deadline and shed policy are set.
//!
//! The default channel capacity is deliberately small (8) so the
//! high-load rows actually exercise full channels; every completed
//! response is checksum-verified against the single-pair kernels
//! inside `figures::admission_sweep`, so the run doubles as a
//! correctness smoke test for all three paths.
//!
//! Run: `cargo bench --bench admission [-- --offered 32,128,512
//! --reps R --shards N --channel-capacity C --deadline-ms D
//! --shed never|past-deadline|load-factor[:F] --service-estimate-us U
//! --ema-alpha A --edf --no-pin]`
//!
//! `--ema-alpha A` turns on the measured per-shard service-time EMA
//! (the static `--service-estimate-us` knob becomes its seed/floor);
//! `--edf` spreads the deadlines, serves each batch
//! earliest-deadline-first, and prints the FIFO baseline's miss count
//! alongside (see EXPERIMENTS.md §Routing-and-EDF).
//! Meaningful throughput numbers need one idle physical core per
//! shard; elsewhere the verdict reconciliation still gates.

mod common;

use relic_smt::bench::figures;
use relic_smt::cli::Args;
use relic_smt::coordinator::{AdmissionConfig, EngineConfig, ShedPolicy};
use relic_smt::relic::{affinity, PoolConfig};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let offered = args.sweep_list("offered", &[32, 128, 512]).expect("--offered");
    let reps = args.get_u64("reps", 3);
    let shards = args.get_u64("shards", 0) as usize; // 0 = auto
    let capacity = args.get_u64("channel-capacity", 8).max(1) as usize;
    let deadline_ms = args.get_u64("deadline-ms", 0);
    let pin = !args.flag("no-pin");
    let shed_name = args.get("shed").unwrap_or("never");
    let shed = ShedPolicy::parse(shed_name)
        .expect("--shed never|past-deadline|load-factor[:F]");
    let ema_alpha = args.get_f64("ema-alpha", 0.0).clamp(0.0, 1.0);
    let edf = args.flag("edf");

    println!("host: {}", affinity::topology_summary());
    common::section(&format!(
        "open-loop admission sweep (capacity {capacity}, shed {shed_name}, \
         deadline {deadline_ms} ms, ema alpha {ema_alpha}, edf {})",
        if edf { "on" } else { "off" },
    ));
    let template = EngineConfig {
        pool: PoolConfig {
            shards: if shards == 0 { None } else { Some(shards) },
            pin,
            channel_capacity: capacity,
            ..PoolConfig::default()
        },
        admission: AdmissionConfig {
            shed,
            service_estimate_ns: args.get_u64("service-estimate-us", 0).saturating_mul(1_000),
            ema_alpha,
            edf,
        },
        ..EngineConfig::default()
    };
    let deadline = if deadline_ms > 0 {
        Some(std::time::Duration::from_millis(deadline_ms))
    } else {
        None
    };
    let rows = figures::admission_sweep(&template, &offered, deadline, reps);
    print!("{}", figures::render_admission(&rows));

    common::section("shed rate vs offered load");
    for r in &rows {
        let total = r.offered as u64 * r.reps;
        println!(
            "{:<10} offered {:>6}: shed {:>5.1}%, bounced {:>5.1}%, parked {:>4}",
            r.mode,
            r.offered,
            100.0 * r.shed as f64 / total.max(1) as f64,
            100.0 * r.rejected as f64 / total.max(1) as f64,
            r.parked,
        );
    }
}
