//! Schedule ablation: Static vs Dynamic vs EdgeBalanced chunk
//! assignment for every GAP kernel on a *skewed* Kronecker graph — the
//! input class where PR 1's static split load-imbalances (the thread
//! that draws the hub vertices finishes last while its sibling idles).
//!
//! Every parallel measurement first asserts its checksum equals the
//! serial kernel's, so the run doubles as a determinism gate for all
//! three schedules on a non-toy graph.
//!
//! Run: `cargo bench --bench schedule
//!       [-- --iters N --warmup N --scale S --edge-factor K --seed X]`
//! Meaningful speedups need a host with an SMT sibling pair; expected
//! there: Dynamic and EdgeBalanced beat Static on at least tc and bc
//! (the hub-dominated kernels) — record the table in EXPERIMENTS.md
//! §Scheduling.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};

use relic_smt::bench::measure;
use relic_smt::cli::Args;
use relic_smt::coordinator::{run_native_kernel, run_native_kernel_par, GraphKernel};
use relic_smt::graph::kronecker::{kronecker_graph, KroneckerParams};
use relic_smt::relic::{affinity, Par, Relic, RelicConfig, Schedule};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let iters = args.get_u64("iters", 200);
    let warmup = args.get_u64("warmup", 20);
    let scale = args.get_u64("scale", 10) as u32;
    let edge_factor = args.get_u64("edge-factor", 8) as u32;
    let seed = args.get_u64("seed", 7);

    println!("host: {}", affinity::topology_summary());
    let pair = affinity::smt_sibling_pair();
    if pair.is_none() {
        println!("WARNING: no SMT siblings — speedups below are not meaningful on this host.");
    }
    if let Some((main_cpu, _)) = pair {
        affinity::pin_to_cpu(main_cpu);
    }
    let relic = Relic::with_config(RelicConfig {
        assistant_cpu: pair.map(|p| p.1),
        ..Default::default()
    });

    let g = kronecker_graph(&KroneckerParams::gap(scale, edge_factor, seed));
    let n = g.num_vertices();
    let avg = g.num_directed_edges() as f64 / n.max(1) as f64;
    let max_deg = (0..n as u32).map(|v| g.degree(v)).max().unwrap_or(0);
    println!(
        "graph: scale {scale}, {} vertices, {} undirected edges, \
         max degree {} ({:.1}x the average {:.1})",
        n,
        g.num_edges(),
        max_deg,
        max_deg as f64 / avg.max(1e-9),
        avg
    );

    common::section("per-kernel schedule ablation (speedup vs serial)");
    println!(
        "{:<8}{:>12}{:>10}{:>10}{:>15}",
        "kernel", "serial µs", "static", "dynamic", "edge-balanced"
    );
    let sink = AtomicU64::new(0);
    for kernel in GraphKernel::all() {
        let want = run_native_kernel(kernel, &g, 0);
        let serial = measure(iters, warmup, || {
            sink.fetch_add(run_native_kernel(kernel, &g, 0), Ordering::Relaxed);
        });
        let mut speedups = [0.0f64; 3];
        for (si, schedule) in Schedule::all().into_iter().enumerate() {
            let par = Par::Relic(&relic).with_schedule(schedule);
            assert_eq!(
                run_native_kernel_par(kernel, &g, 0, &par),
                want,
                "{kernel:?} checksum diverges from serial under {}",
                schedule.name()
            );
            let timed = measure(iters, warmup, || {
                sink.fetch_add(run_native_kernel_par(kernel, &g, 0, &par), Ordering::Relaxed);
            });
            speedups[si] = serial.mean_ns / timed.mean_ns;
        }
        println!(
            "{:<8}{:>12.2}{:>9.3}x{:>9.3}x{:>14.3}x",
            format!("{kernel:?}").to_lowercase(),
            serial.mean_ns / 1000.0,
            speedups[0],
            speedups[1],
            speedups[2]
        );
    }
    std::hint::black_box(sink.load(Ordering::Relaxed));

    println!("\nrelic: {}", relic.stats().report());
}
