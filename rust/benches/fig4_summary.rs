//! Bench target for **Figure 4** (average speedups without negative
//! outliers) and the §V in-text geomeans — the paper's headline
//! comparison, with the paper's numbers printed beside ours.
//!
//! Run: `cargo bench --bench fig4_summary`

mod common;

use relic_smt::bench::figures;
use relic_smt::smtsim::CoreConfig;

fn main() {
    let cfg = CoreConfig::default();
    let f1 = figures::fig1(&cfg);
    let f3 = figures::fig3(&cfg);

    common::section("Figure 4 — average speedup w/o negative outliers");
    let rows = figures::fig4(&f1, &f3);
    println!("{}", figures::render_summary(&rows, ""));

    common::section("§V geomeans (with degradations)");
    println!("{}", figures::render_summary(&figures::section5_geomeans(&f1), ""));

    common::section("headline: Relic's relative gain over each baseline");
    let relic = rows.iter().find(|r| r.runtime == "relic").unwrap().value;
    let paper_gain = [
        ("llvm-openmp", 19.1),
        ("gnu-openmp", 31.0),
        ("intel-openmp", 20.2),
        ("x-openmp", 33.2),
        ("onetbb", 30.1),
        ("taskflow", 23.0),
        ("opencilk", 21.4),
    ];
    println!("{:<16}{:>10}{:>12}", "baseline", "ours %", "paper %");
    for (name, paper) in paper_gain {
        let ours = rows
            .iter()
            .find(|r| r.runtime == name)
            .map(|r| (relic / r.value - 1.0) * 100.0)
            .unwrap();
        println!("{name:<16}{ours:>10.1}{paper:>12.1}");
    }
}
