//! Bench target for **Figure 1**: speedups over serial for the seven
//! baseline frameworks across the seven paper kernels.
//!
//! Two sections:
//! 1. *Simulated* (authoritative on non-SMT hosts): prints the full
//!    matrix with the paper's reported cells beside ours.
//! 2. *Wall-clock* mechanism microbenches: the native runtime models'
//!    `run_pair` dispatch cost on this host (meaningful relative to
//!    each other even without SMT).
//!
//! Run: `cargo bench --bench fig1_frameworks`

mod common;

use relic_smt::bench::{figures, Workload};
use relic_smt::runtimes;
use relic_smt::smtsim::CoreConfig;

fn main() {
    let cfg = CoreConfig::default();

    common::section("Figure 1 (simulated SMT core) — speedup over serial");
    let cells = figures::fig1(&cfg);
    println!("{}", figures::render_matrix(&cells));
    println!(
        "{}",
        figures::render_summary(
            &figures::section5_geomeans(&cells),
            "§V geomeans (with degradations)"
        )
    );

    common::section("native runtime dispatch cost (wall-clock, this host)");
    let w = Workload::new("cc"); // finest kernel: overhead-dominated
    for name in runtimes::FRAMEWORK_NAMES {
        let mut rt = runtimes::by_name(name, None).unwrap();
        let sink = std::sync::atomic::AtomicU64::new(0);
        common::bench(&format!("run_pair/{name}/cc"), 2_000, 200, || {
            rt.run_pair(
                &|| {
                    sink.fetch_add(w.run_native(), std::sync::atomic::Ordering::Relaxed);
                },
                &|| {
                    sink.fetch_add(w.run_native(), std::sync::atomic::Ordering::Relaxed);
                },
            );
        });
        std::hint::black_box(sink.load(std::sync::atomic::Ordering::Relaxed));
    }
}
