//! Bench target for the **§IV in-text granularity table**: serial task
//! time of each benchmark kernel — simulated (calibrated) µs beside the
//! paper's values, plus native wall-clock µs on this host for
//! reference.
//!
//! Run: `cargo bench --bench granularity`

mod common;

use relic_smt::bench::{figures, Workload};
use relic_smt::smtsim::CoreConfig;

fn main() {
    let cfg = CoreConfig::default();

    common::section("§IV serial task granularities — simulated vs paper");
    println!("{}", figures::render_granularity(&figures::granularity(&cfg)));

    common::section("native kernels on this host (wall-clock, not the paper's testbed)");
    for w in Workload::all() {
        let sink = std::sync::atomic::AtomicU64::new(0);
        common::bench(&format!("native/{}", w.name), 20_000, 2_000, || {
            sink.fetch_add(w.run_native(), std::sync::atomic::Ordering::Relaxed);
        });
        std::hint::black_box(sink.load(std::sync::atomic::Ordering::Relaxed));
    }
}
