//! Pool-throughput benchmark: batch throughput of the sharded engine
//! (`RelicPool` of pinned pair-shards) across shard counts.
//!
//! For each shard count the same mixed-kernel request batch (on the
//! paper graph) runs through `Engine::submit`/`Engine::drain`; the
//! sweep verifies every response checksum against the single-pair
//! kernels, so this doubles as the pool-vs-single-pair equivalence
//! check. A preamble times the parallel Kronecker generator
//! (`kronecker_graph_par`, `--scale S` to grow it) over this process's
//! own Relic pair and asserts it bit-identical to the serial one.
//!
//! Run: `cargo bench --bench pool_throughput [-- --shards 1,2,4
//! --requests N --reps R --scale S --no-pin]`
//! Meaningful scaling needs one idle physical core per shard; elsewhere
//! the checksum assertions still make it a correctness smoke test.

mod common;

use relic_smt::bench::figures;
use relic_smt::cli::Args;
use relic_smt::coordinator::EngineConfig;
use relic_smt::graph::kronecker::{kronecker_graph, kronecker_graph_par, KroneckerParams};
use relic_smt::graph::kronecker::{PAPER_EDGE_FACTOR, PAPER_SEED};
use relic_smt::relic::{affinity, pool, Par, PoolConfig, Relic};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let requests = args.get_u64("requests", 96) as usize;
    let reps = args.get_u64("reps", 3);
    let scale = args.get_u64("scale", 5) as u32;
    let pin = !args.flag("no-pin");
    let shard_counts = args.sweep_list("shards", &[1, 2, 4]).expect("--shards");

    println!("host: {}", affinity::topology_summary());
    let pairs = pool::physical_core_pairs();
    println!("physical core pairs: {pairs:?}");
    if pairs.len() < *shard_counts.iter().max().unwrap_or(&1) {
        println!(
            "WARNING: sweep asks for more shards than detected core pairs — \
             the surplus shards run unpinned and scaling flattens."
        );
    }

    common::section("parallel Kronecker generation (satellite check)");
    let params = KroneckerParams::gap(scale, PAPER_EDGE_FACTOR, PAPER_SEED);
    let relic = Relic::new();
    let t0 = std::time::Instant::now();
    let serial = kronecker_graph(&params);
    let t_serial = t0.elapsed();
    let t0 = std::time::Instant::now();
    let parallel = kronecker_graph_par(&params, &Par::Relic(&relic));
    let t_par = t0.elapsed();
    assert_eq!(serial, parallel, "parallel generator must be bit-identical");
    println!(
        "scale {scale}: {} vertices / {} edges; serial {t_serial:?}, \
         parallel {t_par:?} (bit-identical)",
        serial.num_vertices(),
        serial.num_edges()
    );
    drop(relic);

    common::section("batch throughput vs shard count");
    let template = EngineConfig {
        pool: PoolConfig { pin, ..PoolConfig::default() },
        ..EngineConfig::default()
    };
    let rows = figures::pool_scaling(&template, &shard_counts, requests, reps);
    print!("{}", figures::render_pool_scaling(&rows));
}
