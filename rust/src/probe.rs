//! Execution probes: one kernel implementation, two backends.
//!
//! The benchmark kernels ([`crate::graph`], [`crate::json`]) are written
//! once, generic over a [`Probe`]. With [`NoProbe`] every hook is an
//! inlined no-op and the kernel compiles to its plain native form (used
//! for wall-clock measurement and by the public API). With
//! `smtsim::TraceProbe` the same code path records an operation trace
//! that the SMT core simulator replays cycle-by-cycle (used to
//! regenerate the paper's figures on non-SMT hosts — DESIGN.md §2).
//!
//! Address convention: hooks receive *logical* byte addresses, usually
//! `base + index * size_of::<T>()`, so traces are deterministic across
//! runs and independent of the host allocator.

/// Observation hooks called by instrumented kernels.
///
/// All methods have no-op defaults so probes may observe only what they
/// need. Implementations must be cheap: hooks sit in kernel inner loops.
pub trait Probe {
    /// A data load of one machine word (or less) at logical address `addr`.
    #[inline(always)]
    fn load(&mut self, addr: u64) {
        let _ = addr;
    }

    /// A *dependent* load: the address was produced by a preceding load
    /// (pointer chasing — BFS queue/visited, Brandes traversal). These
    /// cannot be prefetched or overlapped by the OoO window, and SMT
    /// partitioning of the load buffers makes them slower again when a
    /// sibling thread is active.
    #[inline(always)]
    fn load_dep(&mut self, addr: u64) {
        self.load(addr);
    }

    /// A data store at logical address `addr`.
    #[inline(always)]
    fn store(&mut self, addr: u64) {
        let _ = addr;
    }

    /// `n` ALU micro-ops of plain computation.
    #[inline(always)]
    fn compute(&mut self, n: u32) {
        let _ = n;
    }

    /// `n` *dependent* floating-point micro-ops (a latency chain the
    /// out-of-order window cannot hide — e.g. PageRank's running sums).
    #[inline(always)]
    fn compute_fp(&mut self, n: u32) {
        let _ = n;
    }

    /// A conditional branch; `predictable` hints whether a real branch
    /// predictor would usually get it right (loop back-edges: yes;
    /// data-dependent comparisons: no).
    #[inline(always)]
    fn branch(&mut self, predictable: bool) {
        let _ = predictable;
    }

    /// A lock-prefixed read-modify-write (CAS, fetch_add…) on `addr`.
    #[inline(always)]
    fn atomic_rmw(&mut self, addr: u64) {
        let _ = addr;
    }
}

/// The zero-cost probe: every hook inlines to nothing.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoProbe;

impl Probe for NoProbe {}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counting {
        loads: u64,
        stores: u64,
        uops: u64,
    }
    impl Probe for Counting {
        fn load(&mut self, _: u64) {
            self.loads += 1;
        }
        fn store(&mut self, _: u64) {
            self.stores += 1;
        }
        fn compute(&mut self, n: u32) {
            self.uops += n as u64;
        }
    }

    #[test]
    fn default_hooks_are_noops() {
        let mut p = NoProbe;
        p.load(1);
        p.store(2);
        p.compute(3);
        p.branch(true);
        p.atomic_rmw(4);
    }

    #[test]
    fn custom_probe_observes() {
        let mut p = Counting { loads: 0, stores: 0, uops: 0 };
        p.load(0);
        p.load(8);
        p.store(16);
        p.compute(5);
        assert_eq!((p.loads, p.stores, p.uops), (2, 1, 5));
    }
}
