//! Taskflow model (v3.7 `executor.async(...)`, the paper's usage).
//!
//! Mechanism reproduced:
//! * `async` allocates a shared-state node (an `std::packaged_task`-like
//!   object + topology node — modeled as an `Arc` pair: one allocation,
//!   one refcount);
//! * the executor's **notifier** (Dekker-style two-phase commit): an
//!   idle worker first *announces* itself as a waiter, re-checks the
//!   queues, and only then sleeps on its condvar; a submitter checks the
//!   waiter count and wakes one — cheap when workers are active, one
//!   futex trip when they've just parked;
//! * a short bounded spin precedes the announce (Taskflow's
//!   `executor::_explore_task` loop).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::relic::affinity::pin_to_cpu;

use super::common::{ErasedTask, StopFlag, TeamQueue};
use super::TaskRuntime;

/// Shared-state node for one async task (`tf::AsyncTask` analogue).
struct Node {
    task: ErasedTask,
    _refcount_pad: [u64; 6],
}

struct Executor {
    queue: TeamQueue<Arc<Node>>,
    /// Two-phase notifier state: number of announced waiters.
    waiters: AtomicU32,
    notify_mu: Mutex<()>,
    notify_cv: Condvar,
    completed: AtomicU32,
    stop: StopFlag,
}

impl Executor {
    /// Submitter side of the notifier.
    fn notify_one(&self) {
        if self.waiters.load(Ordering::Acquire) > 0 {
            let _g = self.notify_mu.lock().unwrap();
            self.notify_cv.notify_one();
        }
    }

    /// Worker side: two-phase commit to sleep.
    fn wait_for_work(&self) {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        // Phase 2: re-check after announcing (the Dekker handshake).
        let recheck = {
            let g = self.queue.try_pop();
            g
        };
        if let Some(node) = recheck {
            self.waiters.fetch_sub(1, Ordering::SeqCst);
            // SAFETY: producers wait before dropping referents.
            unsafe { node.task.call() };
            self.completed.fetch_add(1, Ordering::Release);
            return;
        }
        if self.stop.stopped() {
            self.waiters.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let g = self.notify_mu.lock().unwrap();
        let _g = self
            .notify_cv
            .wait_timeout(g, std::time::Duration::from_millis(10))
            .unwrap();
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Taskflow executor model (1 worker — the paper's 2-thread setup).
pub struct Taskflow {
    exec: Arc<Executor>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// Bounded exploration spins before the notifier announce.
const EXPLORE_SPINS: u32 = 128;

impl Taskflow {
    pub fn new(worker_cpu: Option<usize>) -> Self {
        let exec = Arc::new(Executor {
            queue: TeamQueue::new(),
            waiters: AtomicU32::new(0),
            notify_mu: Mutex::new(()),
            notify_cv: Condvar::new(),
            completed: AtomicU32::new(0),
            stop: StopFlag::new(),
        });
        let worker = {
            let exec = Arc::clone(&exec);
            std::thread::Builder::new()
                .name("taskflow-worker".into())
                .spawn(move || {
                    if let Some(cpu) = worker_cpu {
                        pin_to_cpu(cpu);
                    }
                    while !exec.stop.stopped() {
                        // _explore_task: bounded spin over the queues.
                        let mut found = false;
                        for _ in 0..EXPLORE_SPINS {
                            if let Some(node) = exec.queue.try_pop() {
                                // SAFETY: producer waits before returning.
                                unsafe { node.task.call() };
                                exec.completed.fetch_add(1, Ordering::Release);
                                found = true;
                                break;
                            }
                            std::hint::spin_loop();
                        }
                        if !found {
                            exec.wait_for_work();
                        }
                    }
                })
                .expect("spawn taskflow worker")
        };
        Taskflow { exec, worker: Some(worker) }
    }
}

impl TaskRuntime for Taskflow {
    fn name(&self) -> &'static str {
        "taskflow"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        let before = self.exec.completed.load(Ordering::Acquire);
        // executor.async(b): allocate the shared-state node, enqueue,
        // poke the notifier.
        // SAFETY: the wait loop below precedes `b`'s end of scope.
        let node = Arc::new(Node { task: unsafe { ErasedTask::new(b) }, _refcount_pad: [0; 6] });
        self.exec.queue.push(Arc::clone(&node));
        self.exec.notify_one();
        a();
        // future.wait(): the caller is *not* a worker in Taskflow's async
        // model, so it spins on the shared state rather than helping —
        // unless the task is still unclaimed, in which case executing it
        // inline models `executor.corun_until`.
        while self.exec.completed.load(Ordering::Acquire) == before {
            if let Some(node) = self.exec.queue.try_pop() {
                // SAFETY: as above.
                unsafe { node.task.call() };
                self.exec.completed.fetch_add(1, Ordering::Release);
                break;
            }
            std::hint::spin_loop();
        }
    }
}

impl Drop for Taskflow {
    fn drop(&mut self) {
        self.exec.stop.stop();
        let _g = self.exec.notify_mu.lock().unwrap();
        self.exec.notify_cv.notify_all();
        drop(_g);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn completes_across_park_episodes() {
        let mut rt = Taskflow::new(None);
        let hits = AtomicUsize::new(0);
        for i in 0..400 {
            if i % 40 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            rt.run_pair(&|| {}, &|| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 400);
    }

    #[test]
    fn notifier_waiter_count_returns_to_zero() {
        let rt = Taskflow::new(None);
        std::thread::sleep(std::time::Duration::from_millis(5));
        // Worker may be parked (waiters=1) or spinning (waiters=0);
        // after drop it must be 0.
        let exec = Arc::clone(&rt.exec);
        drop(rt);
        assert_eq!(exec.waiters.load(Ordering::SeqCst), 0);
    }
}
