//! X-OpenMP model (Nookala, Chard, Raicu — "eXtreme fine-grained tasking
//! using lock-less work stealing", FGCS 2024).
//!
//! Mechanism reproduced:
//! * per-thread bounded lock-less deques ([`WsDeque`]); task submission
//!   is an atomic-free push to the submitter's own deque;
//! * no task allocation — descriptors are plain two-word entries
//!   (X-OpenMP pre-allocates task slots);
//! * idle workers *aggressively spin*, stealing directly from the other
//!   thread's deque with CAS (no sleeping, no backoff);
//! * `taskwait` spins, executing local work first, then stealing back.
//!
//! The paper measures X-OpenMP *below* plain LLVM OpenMP on SMT
//! (−6.7% geomean, Fig. 1): constant CAS-stealing between two logical
//! threads of one core keeps the line in contention — an effect the
//! simulator's cache model reproduces (DESIGN.md §4.3).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::relic::affinity::pin_to_cpu;

use super::common::{ErasedTask, StopFlag, WsDeque};
use super::TaskRuntime;

struct Shared {
    /// Main thread's deque (the worker steals from it).
    main_deque: WsDeque<ErasedTask>,
    completed: AtomicU32,
    stop: StopFlag,
}

/// X-OpenMP model.
pub struct XOpenMp {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl XOpenMp {
    pub fn new(worker_cpu: Option<usize>) -> Self {
        let shared = Arc::new(Shared {
            main_deque: WsDeque::new(256),
            completed: AtomicU32::new(0),
            stop: StopFlag::new(),
        });
        let worker = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("xomp-worker".into())
                .spawn(move || {
                    if let Some(cpu) = worker_cpu {
                        pin_to_cpu(cpu);
                    }
                    // Aggressive lock-less stealing loop — X-OpenMP
                    // workers never sleep.
                    while !shared.stop.stopped() {
                        if let Some(t) = shared.main_deque.steal() {
                            // SAFETY: run_pair waits before returning.
                            unsafe { t.call() };
                            shared.completed.fetch_add(1, Ordering::Release);
                        }
                        // No pause: X-OpenMP trades sibling resources for
                        // steal latency (see module docs).
                    }
                })
                .expect("spawn xomp worker")
        };
        XOpenMp { shared, worker: Some(worker) }
    }
}

impl TaskRuntime for XOpenMp {
    fn name(&self) -> &'static str {
        "x-openmp"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        let before = self.shared.completed.load(Ordering::Acquire);
        // Lock-less push to the local deque; no allocation.
        // SAFETY: taskwait below precedes `b`'s end of scope.
        let pushed = self.shared.main_deque.push(unsafe { ErasedTask::new(b) });
        a();
        if !pushed {
            // Deque full (cannot happen at depth 1, kept for safety).
            b();
            return;
        }
        // taskwait: execute local work first, then wait for the thief.
        while self.shared.completed.load(Ordering::Acquire) == before {
            if let Some(t) = self.shared.main_deque.pop() {
                // SAFETY: as above.
                unsafe { t.call() };
                self.shared.completed.fetch_add(1, Ordering::Release);
                break;
            }
            std::hint::spin_loop();
        }
    }
}

impl Drop for XOpenMp {
    fn drop(&mut self) {
        self.shared.stop.stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn completes_all_pairs_exactly_once() {
        let mut rt = XOpenMp::new(None);
        let b_runs = AtomicUsize::new(0);
        for _ in 0..2000 {
            rt.run_pair(&|| {}, &|| {
                b_runs.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(b_runs.load(Ordering::Relaxed), 2000);
    }
}
