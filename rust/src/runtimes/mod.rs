//! Models of the seven baseline task-parallel frameworks the paper
//! evaluates (§III): LLVM OpenMP, GNU OpenMP, Intel OpenMP, X-OpenMP,
//! oneTBB, Taskflow, and OpenCilk — plus the serial baseline.
//!
//! Why models: the originals are C/C++ runtimes that are not available
//! (nor meaningfully measurable) in this environment. At the paper's
//! regime — **two worker threads on one SMT core running 0.4–6.4 µs
//! tasks** — framework performance is dominated by the task submit /
//! dispatch / wait path, so each model reproduces precisely that
//! mechanism of its original (see per-module docs and DESIGN.md §4.2):
//!
//! | model | submission | worker waiting | per-task cost |
//! |---|---|---|---|
//! | [`llvm_omp`] | locked team deque, task descriptor alloc | spin (KMP_BLOCKTIME) | alloc + mutex |
//! | [`gnu_omp`] | mutex + condvar team queue | futex sleep | alloc + mutex + wake syscall |
//! | [`intel_omp`] | LLVM path + heavier bookkeeping | spin | 2 allocs + mutex |
//! | [`x_omp`] | lock-less per-thread deque (CAS) | aggressive spin | CAS ops, no alloc |
//! | [`onetbb`] | arena + task_group alloc | exp-backoff spin, then park | alloc + CAS + backoff |
//! | [`taskflow`] | executor + shared-state alloc | two-phase notifier park | Arc alloc + notifier |
//! | [`opencilk`] | THE-protocol child-first deque | steal loop w/ victim lock | fence, no alloc |
//!
//! Every model implements [`TaskRuntime`]; the benchmark harness drives
//! them identically (the paper's two-instance protocol) in wall-clock
//! mode, and `smtsim::overhead` carries the matching operation-level
//! profiles for simulator mode.

pub mod common;
pub mod gnu_omp;
pub mod intel_omp;
pub mod llvm_omp;
pub mod onetbb;
pub mod opencilk;
pub mod serial;
pub mod taskflow;
pub mod x_omp;

/// A shared-memory task runtime restricted to the paper's setup: one
/// main thread + one worker thread (the two logical threads of an SMT
/// core).
pub trait TaskRuntime: Send {
    /// Framework name as used in the paper's figures.
    fn name(&self) -> &'static str;

    /// Run `a` and `b` as two parallel tasks and return when both are
    /// complete (the paper's §IV benchmark protocol). `a` may execute on
    /// the calling thread.
    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync));
}

/// Names in the paper's figure order (serial baseline excluded).
pub const FRAMEWORK_NAMES: [&str; 7] = [
    "llvm-openmp",
    "gnu-openmp",
    "intel-openmp",
    "x-openmp",
    "onetbb",
    "taskflow",
    "opencilk",
];

/// Instantiate a framework model by figure name; `worker_cpu` pins the
/// worker thread (pass the SMT sibling of the main thread's CPU).
pub fn by_name(name: &str, worker_cpu: Option<usize>) -> Option<Box<dyn TaskRuntime>> {
    Some(match name {
        "llvm-openmp" => Box::new(llvm_omp::LlvmOpenMp::new(worker_cpu)),
        "gnu-openmp" => Box::new(gnu_omp::GnuOpenMp::new(worker_cpu)),
        "intel-openmp" => Box::new(intel_omp::IntelOpenMp::new(worker_cpu)),
        "x-openmp" => Box::new(x_omp::XOpenMp::new(worker_cpu)),
        "onetbb" => Box::new(onetbb::OneTbb::new(worker_cpu)),
        "taskflow" => Box::new(taskflow::Taskflow::new(worker_cpu)),
        "opencilk" => Box::new(opencilk::OpenCilk::new(worker_cpu)),
        "serial" => Box::new(serial::Serial),
        _ => return None,
    })
}

/// All seven baseline models (paper Fig. 1 order).
pub fn all_frameworks(worker_cpu: Option<usize>) -> Vec<Box<dyn TaskRuntime>> {
    FRAMEWORK_NAMES
        .iter()
        .map(|n| by_name(n, worker_cpu).expect("registered"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Every runtime must run both closures exactly once per run_pair,
    /// across repeated invocations (the 1e5-iteration protocol relies on
    /// reusing the runtime).
    #[test]
    fn every_runtime_runs_both_tasks_repeatedly() {
        for name in FRAMEWORK_NAMES.iter().chain(["serial"].iter()) {
            let mut rt = by_name(name, None).unwrap();
            let a = AtomicUsize::new(0);
            let b = AtomicUsize::new(0);
            let iters = 300;
            for _ in 0..iters {
                rt.run_pair(
                    &|| {
                        a.fetch_add(1, Ordering::Relaxed);
                    },
                    &|| {
                        b.fetch_add(1, Ordering::Relaxed);
                    },
                );
            }
            assert_eq!(a.load(Ordering::Relaxed), iters, "{name} task a");
            assert_eq!(b.load(Ordering::Relaxed), iters, "{name} task b");
        }
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("fastflow", None).is_none());
    }

    #[test]
    fn all_frameworks_is_complete() {
        let rts = all_frameworks(None);
        assert_eq!(rts.len(), 7);
        let names: Vec<_> = rts.iter().map(|r| r.name()).collect();
        assert_eq!(names, FRAMEWORK_NAMES.to_vec());
    }
}
