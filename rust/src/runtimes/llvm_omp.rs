//! LLVM OpenMP model (`#pragma omp task` + `taskwait`).
//!
//! Mechanism reproduced (libomp's fine-grained task path):
//! * `__kmpc_omp_task_alloc`: every task is a heap-allocated descriptor;
//! * tasks go to a per-team deque protected by a lock (libomp's bounded
//!   deques use `kmp_lock` around push/pop at 2 threads);
//! * the idle worker *spins* — `KMP_BLOCKTIME` defaults to 200 ms, far
//!   beyond µs-scale tasks, so the worker never sleeps in this regime
//!   (the reason LLVM OpenMP is the best baseline in Fig. 1);
//! * `taskwait` is a task scheduling point: the main thread executes
//!   queued tasks while waiting.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::relic::affinity::pin_to_cpu;

use super::common::{ErasedTask, StopFlag, TeamQueue};
use super::TaskRuntime;

/// Heap task descriptor, standing in for `kmp_task_t` (+ taskdata).
struct TaskDesc {
    task: ErasedTask,
    /// Completion epoch the descriptor belongs to.
    epoch: u32,
    /// Padding to a realistic descriptor size (libomp's task +
    /// taskdata headers are ~192 bytes).
    _pad: [u64; 16],
}

struct Team {
    deque: TeamQueue<Box<TaskDesc>>,
    completed: AtomicU32,
    stop: StopFlag,
}

/// LLVM OpenMP (`libomp`) model.
pub struct LlvmOpenMp {
    team: Arc<Team>,
    worker: Option<std::thread::JoinHandle<()>>,
    epoch: u32,
}

impl LlvmOpenMp {
    pub fn new(worker_cpu: Option<usize>) -> Self {
        let team = Arc::new(Team {
            deque: TeamQueue::new(),
            completed: AtomicU32::new(0),
            stop: StopFlag::new(),
        });
        let worker = {
            let team = Arc::clone(&team);
            std::thread::Builder::new()
                .name("llvm-omp-worker".into())
                .spawn(move || {
                    if let Some(cpu) = worker_cpu {
                        pin_to_cpu(cpu);
                    }
                    // Idle loop: spin-poll the team deque (KMP_BLOCKTIME
                    // keeps libomp workers active at this granularity).
                    while !team.stop.stopped() {
                        if let Some(desc) = team.deque.try_pop() {
                            // SAFETY: run_pair waits before returning.
                            unsafe { desc.task.call() };
                            team.completed.fetch_add(1, Ordering::Release);
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                })
                .expect("spawn llvm-omp worker")
        };
        LlvmOpenMp { team, worker: Some(worker), epoch: 0 }
    }
}

impl TaskRuntime for LlvmOpenMp {
    fn name(&self) -> &'static str {
        "llvm-openmp"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        self.epoch += 1;
        let before = self.team.completed.load(Ordering::Acquire);
        // #pragma omp task: allocate descriptor, enqueue.
        // SAFETY: we taskwait below before `b` goes out of scope.
        let desc = Box::new(TaskDesc {
            task: unsafe { ErasedTask::new(b) },
            epoch: self.epoch,
            _pad: [0; 16],
        });
        self.team.deque.push(desc);
        // Undeferred sibling work on the encountering thread.
        a();
        // #pragma omp taskwait — a scheduling point: help execute.
        while self.team.completed.load(Ordering::Acquire) == before {
            if let Some(desc) = self.team.deque.try_pop() {
                debug_assert_eq!(desc.epoch, self.epoch);
                // SAFETY: as above.
                unsafe { desc.task.call() };
                self.team.completed.fetch_add(1, Ordering::Release);
                break;
            }
            std::hint::spin_loop();
        }
    }
}

impl Drop for LlvmOpenMp {
    fn drop(&mut self) {
        self.team.stop.stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn taskwait_helps_when_worker_is_slow() {
        // Even with the worker descheduled (1-CPU hosts), taskwait's
        // help-execution guarantees forward progress.
        let mut rt = LlvmOpenMp::new(None);
        let hits = AtomicUsize::new(0);
        for _ in 0..1000 {
            rt.run_pair(&|| {}, &|| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn tasks_not_double_executed() {
        let mut rt = LlvmOpenMp::new(None);
        let b_runs = AtomicUsize::new(0);
        for _ in 0..2000 {
            rt.run_pair(&|| {}, &|| {
                b_runs.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(b_runs.load(Ordering::Relaxed), 2000);
    }
}
