//! Intel oneAPI Threading Building Blocks model (`task_group::run` /
//! `wait`, the API the paper uses with oneTBB 2021.11).
//!
//! Mechanism reproduced:
//! * `task_group::run` allocates a small task object and pushes it to
//!   the submitting thread's arena slot (modeled: boxed task + locked
//!   deque — at 2 threads TBB's mailbox/deque path degenerates to one
//!   producer, one consumer);
//! * idle workers scan with **exponential backoff** (`machine_pause`
//!   sequences doubling up to a limit), then commit to sleep in the
//!   market — each parked episode costs a futex round trip;
//! * `task_group::wait` participates in scheduling (help-execution).
//!
//! The paper measures oneTBB slightly *below* serial on geomean (−1.9%):
//! arena entry/exit and backoff latency eat the µs-scale wins.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::relic::affinity::pin_to_cpu;

use super::common::{ErasedTask, StopFlag, TeamQueue};
use super::TaskRuntime;

struct TbbTask {
    task: ErasedTask,
    /// `tbb::detail::d1::task` + function-task wrapper footprint.
    _pad: [u64; 8],
}

struct Arena {
    deque: TeamQueue<Box<TbbTask>>,
    completed: AtomicU32,
    stop: StopFlag,
}

/// oneTBB `task_group` model.
pub struct OneTbb {
    arena: Arc<Arena>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// Backoff limit in pause-iterations (TBB's `max_spin_count` analogue).
const BACKOFF_LIMIT: u32 = 16;

impl OneTbb {
    pub fn new(worker_cpu: Option<usize>) -> Self {
        let arena = Arc::new(Arena {
            deque: TeamQueue::new(),
            completed: AtomicU32::new(0),
            stop: StopFlag::new(),
        });
        let worker = {
            let arena = Arc::clone(&arena);
            std::thread::Builder::new()
                .name("tbb-worker".into())
                .spawn(move || {
                    if let Some(cpu) = worker_cpu {
                        pin_to_cpu(cpu);
                    }
                    let mut backoff = 1u32;
                    while !arena.stop.stopped() {
                        if let Some(t) = arena.deque.try_pop() {
                            backoff = 1;
                            // SAFETY: run_pair waits before returning.
                            unsafe { t.task.call() };
                            arena.completed.fetch_add(1, Ordering::Release);
                            continue;
                        }
                        if backoff <= BACKOFF_LIMIT {
                            // Exponential pause backoff.
                            for _ in 0..backoff {
                                std::hint::spin_loop();
                            }
                            backoff *= 2;
                        } else {
                            // Commit to sleep in the market; a submit's
                            // notify wakes us (futex round trip).
                            if let Some(t) =
                                arena.deque.pop_wait(Duration::from_millis(10))
                            {
                                backoff = 1;
                                // SAFETY: as above.
                                unsafe { t.task.call() };
                                arena.completed.fetch_add(1, Ordering::Release);
                            }
                        }
                    }
                })
                .expect("spawn tbb worker")
        };
        OneTbb { arena, worker: Some(worker) }
    }
}

impl TaskRuntime for OneTbb {
    fn name(&self) -> &'static str {
        "onetbb"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        let before = self.arena.completed.load(Ordering::Acquire);
        // task_group::run — allocate and enqueue (notify in case the
        // worker committed to sleep).
        // SAFETY: wait below precedes `b`'s end of scope.
        let t = Box::new(TbbTask { task: unsafe { ErasedTask::new(b) }, _pad: [0; 8] });
        self.arena.deque.push_notify(t);
        a();
        // task_group::wait — help-execute while waiting.
        while self.arena.completed.load(Ordering::Acquire) == before {
            if let Some(t) = self.arena.deque.try_pop() {
                // SAFETY: as above.
                unsafe { t.task.call() };
                self.arena.completed.fetch_add(1, Ordering::Release);
                break;
            }
            std::hint::spin_loop();
        }
    }
}

impl Drop for OneTbb {
    fn drop(&mut self) {
        self.arena.stop.stop();
        self.arena.deque.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn completes_with_sleepy_worker() {
        let mut rt = OneTbb::new(None);
        let hits = AtomicUsize::new(0);
        for i in 0..500 {
            if i % 50 == 0 {
                // Let the worker fall through backoff into sleep.
                std::thread::sleep(Duration::from_millis(1));
            }
            rt.run_pair(&|| {}, &|| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 500);
    }
}
