//! Shared machinery for the baseline runtime models: erased task
//! references, completion latches, and a lockable work deque.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Condvar, Mutex};

/// A type-erased reference to a `Fn() + Sync` closure with the lifetime
/// erased so it can sit in a runtime's queue while the worker picks it
/// up.
///
/// # Safety contract
/// The creator must guarantee the referent outlives the task's
/// execution; every `run_pair` implementation joins/waits before
/// returning, which upholds this.
#[derive(Clone, Copy)]
pub struct ErasedTask {
    f: *const (dyn Fn() + Sync + 'static),
}

unsafe impl Send for ErasedTask {}
// SAFETY: the referent is `Sync` by construction; sharing the raw
// pointer adds no capability beyond `call`, whose safety contract covers
// cross-thread use.
unsafe impl Sync for ErasedTask {}

impl ErasedTask {
    /// Erase the lifetime of `f`.
    ///
    /// # Safety
    /// Caller must ensure `f` outlives every [`call`](Self::call).
    pub unsafe fn new(f: &(dyn Fn() + Sync)) -> Self {
        // SAFETY: lifetime erasure only; validity is the caller's contract.
        let f: *const (dyn Fn() + Sync) = f;
        ErasedTask { f: std::mem::transmute(f) }
    }

    /// Invoke the closure.
    ///
    /// # Safety
    /// The referent must still be alive (see [`new`](Self::new)).
    pub unsafe fn call(&self) {
        (*self.f)()
    }
}

/// Countdown latch: workers `count_down`, the owner `wait`s by spinning
/// with `pause` (all baseline frameworks spin in their join path at this
/// task granularity).
pub struct Latch {
    remaining: AtomicU32,
}

impl Latch {
    pub fn new(count: u32) -> Self {
        Latch { remaining: AtomicU32::new(count) }
    }

    #[inline]
    pub fn count_down(&self) {
        self.remaining.fetch_sub(1, Ordering::Release);
    }

    #[inline]
    pub fn done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }

    #[inline]
    pub fn wait_spin(&self) {
        while !self.done() {
            std::hint::spin_loop();
        }
    }
}

/// Worker stop flag shared between a runtime handle and its worker.
pub struct StopFlag(AtomicBool);

impl StopFlag {
    pub fn new() -> Self {
        StopFlag(AtomicBool::new(false))
    }
    #[inline]
    pub fn stop(&self) {
        self.0.store(true, Ordering::Release);
    }
    #[inline]
    pub fn stopped(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

impl Default for StopFlag {
    fn default() -> Self {
        Self::new()
    }
}

/// A mutex-guarded deque with an associated condvar — the classic
/// "team queue" shape used by GNU libgomp and (without the condvar
/// sleeping) by the lock-based dispatch paths of other runtimes.
pub struct TeamQueue<T> {
    inner: Mutex<std::collections::VecDeque<T>>,
    cv: Condvar,
}

impl<T> TeamQueue<T> {
    pub fn new() -> Self {
        TeamQueue { inner: Mutex::new(std::collections::VecDeque::new()), cv: Condvar::new() }
    }

    /// Push and notify one sleeper.
    pub fn push_notify(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
        self.cv.notify_one();
    }

    /// Push without notifying (spin-polled queues).
    pub fn push(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    /// Blocking pop with a timeout; returns `None` on timeout (callers
    /// re-check their stop flags).
    pub fn pop_wait(&self, timeout: std::time::Duration) -> Option<T> {
        let guard = self.inner.lock().unwrap();
        let (mut guard, _res) = self
            .cv
            .wait_timeout_while(guard, timeout, |q| q.is_empty())
            .unwrap();
        guard.pop_front()
    }

    /// Wake all sleepers (used on shutdown).
    pub fn notify_all(&self) {
        self.cv.notify_all();
    }
}

impl<T> Default for TeamQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// A bounded Chase-Lev work-stealing deque over small copyable slots.
///
/// Owner thread pushes/pops the bottom; thief threads steal the top via
/// CAS — the lock-less structure X-OpenMP builds its runtime around and
/// OpenCilk uses (with the THE protocol) for continuations. Capacity is
/// fixed (both originals use bounded deques on the fine-grained path);
/// `push` fails when full.
pub struct WsDeque<T: Copy> {
    buf: Box<[std::cell::UnsafeCell<std::mem::MaybeUninit<T>>]>,
    mask: u64,
    top: std::sync::atomic::AtomicU64,
    bottom: std::sync::atomic::AtomicU64,
}

// SAFETY: cross-thread access is mediated by the top/bottom protocol.
unsafe impl<T: Copy + Send> Sync for WsDeque<T> {}
unsafe impl<T: Copy + Send> Send for WsDeque<T> {}

impl<T: Copy> WsDeque<T> {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        WsDeque {
            buf: (0..cap)
                .map(|_| std::cell::UnsafeCell::new(std::mem::MaybeUninit::uninit()))
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            mask: cap as u64 - 1,
            top: std::sync::atomic::AtomicU64::new(0),
            bottom: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Owner: push at the bottom. Returns false when full.
    pub fn push(&self, value: T) -> bool {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        if b.wrapping_sub(t) > self.mask {
            return false;
        }
        // SAFETY: slot (b & mask) is not visible to thieves until the
        // bottom store below.
        unsafe { (*self.buf[(b & self.mask) as usize].get()).write(value) };
        self.bottom.store(b.wrapping_add(1), Ordering::Release);
        true
    }

    /// Owner: pop from the bottom (LIFO).
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed).wrapping_sub(1);
        self.bottom.store(b, Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t > b || b.wrapping_sub(t) > self.mask {
            // Empty: restore.
            self.bottom.store(b.wrapping_add(1), Ordering::Relaxed);
            return None;
        }
        // SAFETY: index protocol guarantees the slot was published.
        let value = unsafe { (*self.buf[(b & self.mask) as usize].get()).assume_init() };
        if t == b {
            // Last element: race against thieves for it.
            let won = self
                .top
                .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
                .is_ok();
            self.bottom.store(b.wrapping_add(1), Ordering::Relaxed);
            return won.then_some(value);
        }
        Some(value)
    }

    /// Thief: steal from the top (FIFO).
    pub fn steal(&self) -> Option<T> {
        let t = self.top.load(Ordering::Acquire);
        std::sync::atomic::fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return None;
        }
        // SAFETY: slot published before bottom advanced past it.
        let value = unsafe { (*self.buf[(t & self.mask) as usize].get()).assume_init() };
        self.top
            .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
            .then_some(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn latch_counts_down() {
        let l = Latch::new(2);
        assert!(!l.done());
        l.count_down();
        assert!(!l.done());
        l.count_down();
        assert!(l.done());
        l.wait_spin(); // returns immediately
    }

    #[test]
    fn erased_task_calls_through() {
        let hits = AtomicUsize::new(0);
        let f = || {
            hits.fetch_add(1, Ordering::SeqCst);
        };
        // SAFETY: called before `f` drops.
        let t = unsafe { ErasedTask::new(&f) };
        unsafe { t.call() };
        unsafe { t.call() };
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn team_queue_cross_thread() {
        let q = Arc::new(TeamQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while got.len() < 3 {
                if let Some(v) = q2.pop_wait(std::time::Duration::from_millis(50)) {
                    got.push(v);
                }
            }
            got
        });
        for i in 0..3 {
            q.push_notify(i);
        }
        assert_eq!(h.join().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn pop_wait_times_out_when_empty() {
        let q: TeamQueue<u32> = TeamQueue::new();
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_wait(std::time::Duration::from_millis(5)), None);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(4));
    }

    #[test]
    fn wsdeque_lifo_owner_fifo_thief() {
        let d: WsDeque<u64> = WsDeque::new(8);
        assert!(d.push(1));
        assert!(d.push(2));
        assert!(d.push(3));
        assert_eq!(d.steal(), Some(1)); // thief takes oldest
        assert_eq!(d.pop(), Some(3)); // owner takes newest
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.pop(), None);
        assert_eq!(d.steal(), None);
    }

    #[test]
    fn wsdeque_rejects_overflow() {
        let d: WsDeque<u64> = WsDeque::new(2);
        assert!(d.push(1));
        assert!(d.push(2));
        assert!(!d.push(3));
        assert_eq!(d.steal(), Some(1));
        assert!(d.push(3));
    }

    #[test]
    fn wsdeque_cross_thread_no_loss_no_dup() {
        let d: Arc<WsDeque<u64>> = Arc::new(WsDeque::new(256));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let thief = {
            let d = Arc::clone(&d);
            let seen = Arc::clone(&seen);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while !stop.load(Ordering::Acquire) {
                    if let Some(v) = d.steal() {
                        got.push(v);
                    }
                }
                while let Some(v) = d.steal() {
                    got.push(v);
                }
                seen.lock().unwrap().extend(got);
            })
        };
        let mut owner_got = Vec::new();
        let n = 10_000u64;
        let mut next = 1u64;
        while next <= n {
            if d.push(next) {
                next += 1;
            }
            if next % 3 == 0 {
                if let Some(v) = d.pop() {
                    owner_got.push(v);
                }
            }
        }
        while let Some(v) = d.pop() {
            owner_got.push(v);
        }
        stop.store(true, Ordering::Release);
        thief.join().unwrap();
        let mut all = seen.lock().unwrap().clone();
        all.extend(owner_got);
        all.sort_unstable();
        let expect: Vec<u64> = (1..=n).collect();
        assert_eq!(all, expect, "every pushed item must appear exactly once");
    }
}
