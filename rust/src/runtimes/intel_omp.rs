//! Intel OpenMP model (from the oneAPI toolkit).
//!
//! Intel's OpenMP runtime shares ancestry with LLVM's `libomp` (Intel
//! upstreamed it), so the mechanism matches [`super::llvm_omp`] —
//! locked team deque, spinning worker (KMP_BLOCKTIME), taskwait
//! help-execution — with measurably heavier per-task bookkeeping
//! (ITT/stats hooks, hierarchical scheduling structures): the paper
//! measures it slightly behind LLVM OpenMP (11.3% vs 13.9% geomean,
//! §V). The model adds the second descriptor allocation and the extra
//! bookkeeping stores that account for that gap.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::relic::affinity::pin_to_cpu;

use super::common::{ErasedTask, StopFlag, TeamQueue};
use super::TaskRuntime;

struct TaskData {
    /// Bookkeeping block (`kmp_taskdata_t` is ~256 bytes and is a
    /// *separate* allocation from the task payload in libomp/iomp).
    flags: u64,
    _pad: [u64; 24],
}

struct TaskDesc {
    task: ErasedTask,
    /// Kept alive to model iomp's separate taskdata allocation.
    #[allow(dead_code)]
    data: Box<TaskData>,
    _pad: [u64; 8],
}

struct Team {
    deque: TeamQueue<Box<TaskDesc>>,
    completed: AtomicU32,
    stop: StopFlag,
}

/// Intel OpenMP (oneAPI `libiomp5`) model.
pub struct IntelOpenMp {
    team: Arc<Team>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl IntelOpenMp {
    pub fn new(worker_cpu: Option<usize>) -> Self {
        let team = Arc::new(Team {
            deque: TeamQueue::new(),
            completed: AtomicU32::new(0),
            stop: StopFlag::new(),
        });
        let worker = {
            let team = Arc::clone(&team);
            std::thread::Builder::new()
                .name("iomp-worker".into())
                .spawn(move || {
                    if let Some(cpu) = worker_cpu {
                        pin_to_cpu(cpu);
                    }
                    while !team.stop.stopped() {
                        if let Some(desc) = team.deque.try_pop() {
                            // SAFETY: run_pair waits before returning.
                            unsafe { desc.task.call() };
                            team.completed.fetch_add(1, Ordering::Release);
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                })
                .expect("spawn iomp worker")
        };
        IntelOpenMp { team, worker: Some(worker) }
    }
}

impl TaskRuntime for IntelOpenMp {
    fn name(&self) -> &'static str {
        "intel-openmp"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        let before = self.team.completed.load(Ordering::Acquire);
        // Two allocations (task + taskdata) and extra bookkeeping stores.
        let mut data = Box::new(TaskData { flags: 0, _pad: [0; 24] });
        data.flags = 0x13; // tiedness/final/priority bits
        data._pad[0] = before as u64; // stats hook
        // SAFETY: taskwait below precedes `b`'s end of scope.
        let desc = Box::new(TaskDesc { task: unsafe { ErasedTask::new(b) }, data, _pad: [0; 8] });
        self.team.deque.push(desc);
        a();
        while self.team.completed.load(Ordering::Acquire) == before {
            if let Some(desc) = self.team.deque.try_pop() {
                // SAFETY: as above.
                unsafe { desc.task.call() };
                self.team.completed.fetch_add(1, Ordering::Release);
                break;
            }
            std::hint::spin_loop();
        }
    }
}

impl Drop for IntelOpenMp {
    fn drop(&mut self) {
        self.team.stop.stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn completes_all_pairs() {
        let mut rt = IntelOpenMp::new(None);
        let hits = AtomicUsize::new(0);
        for _ in 0..1000 {
            rt.run_pair(
                &|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                },
                &|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                },
            );
        }
        assert_eq!(hits.load(Ordering::Relaxed), 2000);
    }
}
