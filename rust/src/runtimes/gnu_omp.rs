//! GNU OpenMP (libgomp) model.
//!
//! Mechanism reproduced (libgomp's task path, the heaviest of the
//! OpenMP implementations — the paper measures a 17.7% geomean
//! *slowdown* with it, Fig. 1):
//! * one central team task queue guarded by the team mutex
//!   (`task_lock`); every `GOMP_task` takes the lock, allocates the
//!   task, links it into the priority queues, and signals;
//! * idle workers block on a condvar/futex (`gomp_team_barrier_wait`) —
//!   each fine-grained task pays a futex wake + scheduler hop;
//! * `taskwait` also takes the team lock, and the waiting thread can
//!   execute queued children while the worker is still waking — the
//!   model preserves that help-first behavior.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::relic::affinity::pin_to_cpu;

use super::common::{ErasedTask, StopFlag, TeamQueue};
use super::TaskRuntime;

struct GompTask {
    task: ErasedTask,
    /// libgomp's `struct gomp_task` header is large (~320 bytes).
    _pad: [u64; 24],
}

struct Team {
    queue: TeamQueue<Box<GompTask>>,
    completed: AtomicU32,
    stop: StopFlag,
}

/// GNU OpenMP (libgomp) model.
pub struct GnuOpenMp {
    team: Arc<Team>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl GnuOpenMp {
    pub fn new(worker_cpu: Option<usize>) -> Self {
        let team = Arc::new(Team {
            queue: TeamQueue::new(),
            completed: AtomicU32::new(0),
            stop: StopFlag::new(),
        });
        let worker = {
            let team = Arc::clone(&team);
            std::thread::Builder::new()
                .name("gomp-worker".into())
                .spawn(move || {
                    if let Some(cpu) = worker_cpu {
                        pin_to_cpu(cpu);
                    }
                    while !team.stop.stopped() {
                        // Sleep on the condvar — libgomp's barrier wait.
                        if let Some(t) = team.queue.pop_wait(Duration::from_millis(20))
                        {
                            // SAFETY: run_pair waits before returning.
                            unsafe { t.task.call() };
                            team.completed.fetch_add(1, Ordering::Release);
                        }
                    }
                })
                .expect("spawn gomp worker")
        };
        GnuOpenMp { team, worker: Some(worker) }
    }
}

impl TaskRuntime for GnuOpenMp {
    fn name(&self) -> &'static str {
        "gnu-openmp"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        let before = self.team.completed.load(Ordering::Acquire);
        // GOMP_task: lock, allocate, enqueue, futex-wake the worker.
        // SAFETY: taskwait below precedes `b`'s end of scope.
        let t = Box::new(GompTask { task: unsafe { ErasedTask::new(b) }, _pad: [0; 24] });
        self.team.queue.push_notify(t);
        a();
        // GOMP_taskwait: help-execute if the task is still queued,
        // otherwise wait for the worker to finish it.
        while self.team.completed.load(Ordering::Acquire) == before {
            if let Some(t) = self.team.queue.try_pop() {
                // SAFETY: as above.
                unsafe { t.task.call() };
                self.team.completed.fetch_add(1, Ordering::Release);
                break;
            }
            std::thread::yield_now();
        }
    }
}

impl Drop for GnuOpenMp {
    fn drop(&mut self) {
        self.team.stop.stop();
        self.team.queue.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn completes_all_pairs() {
        let mut rt = GnuOpenMp::new(None);
        let hits = AtomicUsize::new(0);
        for _ in 0..500 {
            rt.run_pair(&|| {}, &|| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn drop_terminates_promptly() {
        let t0 = std::time::Instant::now();
        drop(GnuOpenMp::new(None));
        assert!(t0.elapsed() < Duration::from_secs(2));
    }
}
