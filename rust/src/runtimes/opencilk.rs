//! OpenCilk model (`cilk_spawn` / `cilk_sync`, OpenCilk 2.1).
//!
//! Mechanism reproduced — the Cilk work-first principle with the THE
//! protocol:
//! * `cilk_spawn b()` makes the *continuation* (everything after the
//!   spawn up to `cilk_sync`) stealable and executes the spawned child
//!   immediately on the spawning thread (child-first execution);
//! * the spawn fast path is nearly free: push a frame onto the local
//!   deque tail — no lock, no allocation (Cilk's "work-first" pays on
//!   the steal, not the spawn);
//! * a thief steals the continuation from the deque head, locking the
//!   victim deque (THE protocol's `E` step);
//! * `cilk_sync` runs the slow path only if the continuation was stolen:
//!   the child's thread waits on the full-frame latch.
//!
//! In `run_pair(a, b)` terms: `cilk_spawn b(); a(); cilk_sync;` — the
//! main thread runs `b` first, the worker steals and runs `a`; if the
//! steal loses the race, main pops the continuation and runs `a` itself
//! (exactly Cilk's serial semantics).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::relic::affinity::pin_to_cpu;

use super::common::{ErasedTask, StopFlag, WsDeque};
use super::TaskRuntime;

struct Shared {
    /// Main thread's deque of stealable continuations.
    deque: WsDeque<ErasedTask>,
    /// Continuations completed by the thief (full-frame latch analogue).
    stolen_done: AtomicU32,
    stop: StopFlag,
}

/// OpenCilk model.
pub struct OpenCilk {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl OpenCilk {
    pub fn new(worker_cpu: Option<usize>) -> Self {
        let shared = Arc::new(Shared {
            deque: WsDeque::new(64),
            stolen_done: AtomicU32::new(0),
            stop: StopFlag::new(),
        });
        let worker = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("cilk-worker".into())
                .spawn(move || {
                    if let Some(cpu) = worker_cpu {
                        pin_to_cpu(cpu);
                    }
                    // Random-victim stealing degenerates to one victim at
                    // two threads; spin with pause between attempts.
                    while !shared.stop.stopped() {
                        if let Some(cont) = shared.deque.steal() {
                            // SAFETY: cilk_sync below waits before the
                            // referent's scope ends.
                            unsafe { cont.call() };
                            shared.stolen_done.fetch_add(1, Ordering::Release);
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                })
                .expect("spawn cilk worker")
        };
        OpenCilk { shared, worker: Some(worker) }
    }
}

impl TaskRuntime for OpenCilk {
    fn name(&self) -> &'static str {
        "opencilk"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        let before = self.shared.stolen_done.load(Ordering::Acquire);
        // cilk_spawn b(): continuation (a) becomes stealable; child (b)
        // runs immediately on this thread.
        // SAFETY: we sync before returning, so `a` outlives its task.
        let pushed = self.shared.deque.push(unsafe { ErasedTask::new(a) });
        b();
        if !pushed {
            // Deque full cannot happen at spawn depth 1; serial fallback.
            a();
            return;
        }
        // cilk_sync: fast path — pop our own continuation back (not
        // stolen) and run it; slow path — wait for the thief's latch.
        match self.shared.deque.pop() {
            Some(cont) => {
                // SAFETY: as above.
                unsafe { cont.call() };
            }
            None => {
                // Stolen (or mid-steal): wait for completion.
                while self.shared.stolen_done.load(Ordering::Acquire) == before {
                    std::hint::spin_loop();
                }
            }
        }
    }
}

impl Drop for OpenCilk {
    fn drop(&mut self) {
        self.shared.stop.stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn child_runs_before_continuation_on_fast_path() {
        // With no worker contention the serial order is b-then-a
        // (child-first), matching Cilk's serial elision semantics.
        let mut rt = OpenCilk::new(None);
        let b_first = AtomicUsize::new(0);
        let order_ok = AtomicUsize::new(0);
        rt.run_pair(
            &|| {
                // a: b must have started or finished already unless stolen.
                order_ok.fetch_add(1, Ordering::SeqCst);
            },
            &|| {
                b_first.fetch_add(1, Ordering::SeqCst);
            },
        );
        assert_eq!(b_first.load(Ordering::SeqCst), 1);
        assert_eq!(order_ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn continuation_runs_exactly_once_under_contention() {
        let mut rt = OpenCilk::new(None);
        let a_runs = AtomicUsize::new(0);
        for _ in 0..5000 {
            rt.run_pair(
                &|| {
                    a_runs.fetch_add(1, Ordering::Relaxed);
                },
                &|| {},
            );
        }
        assert_eq!(a_runs.load(Ordering::Relaxed), 5000);
    }
}
