//! The serial baseline: both task instances execute back-to-back on the
//! calling thread (paper §IV: "In the serial mode, we run two instances
//! of a graph kernel in a single thread"). Speedups in every figure are
//! relative to this.

use super::TaskRuntime;

/// Serial executor (the denominator of every speedup in Figures 1/3/4).
pub struct Serial;

impl TaskRuntime for Serial {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn run_pair(&mut self, a: &(dyn Fn() + Sync), b: &(dyn Fn() + Sync)) {
        a();
        b();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn runs_in_order_on_calling_thread() {
        let order = AtomicU32::new(0);
        let caller = std::thread::current().id();
        let t_a = std::sync::Mutex::new(None);
        let t_b = std::sync::Mutex::new(None);
        Serial.run_pair(
            &|| {
                assert_eq!(order.load(Ordering::SeqCst), 0);
                order.store(1, Ordering::SeqCst);
                *t_a.lock().unwrap() = Some(std::thread::current().id());
            },
            &|| {
                assert_eq!(order.load(Ordering::SeqCst), 1);
                order.store(2, Ordering::SeqCst);
                *t_b.lock().unwrap() = Some(std::thread::current().id());
            },
        );
        assert_eq!(order.load(Ordering::SeqCst), 2);
        assert_eq!(t_a.lock().unwrap().unwrap(), caller);
        assert_eq!(t_b.lock().unwrap().unwrap(), caller);
    }
}
