//! Minimal command-line parser (the offline environment carries no
//! `clap`): subcommand + `--key value` / `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Args {
    /// First non-flag token (e.g. `fig1`).
    pub command: Option<String>,
    /// Positional arguments after the command.
    pub positional: Vec<String>,
    /// `--key value` options and `--flag` booleans (value = "").
    options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let value = match iter.peek() {
                    Some(next) if !next.starts_with("--") => iter.next().unwrap(),
                    _ => String::new(),
                };
                args.options.insert(key.to_string(), value);
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process's command line.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Integer option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Float option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean flag (present without value, or `--key true`).
    pub fn flag(&self, key: &str) -> bool {
        match self.options.get(key) {
            Some(v) => v.is_empty() || v == "true" || v == "1",
            None => false,
        }
    }

    /// Parse `--key` as a sweep list of positive counts (cpulist
    /// syntax: `"1,2,4"`, ranges like `"1-4"`); `default` when the
    /// option is absent or empty. Errors on an unparsable value rather
    /// than silently sweeping nothing. Shared by the `repro pool`
    /// command and the pool-throughput bench.
    pub fn sweep_list(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(key) {
            Some(list) if !list.is_empty() => {
                let counts = crate::relic::affinity::parse_cpulist(list);
                anyhow::ensure!(!counts.is_empty(), "cannot parse --{key} {list:?}");
                Ok(counts.into_iter().map(|c| c.max(1)).collect())
            }
            _ => Ok(default.to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_options_positionals() {
        let a = parse("fig1 --mode sim --iters 500 extra --verbose");
        assert_eq!(a.command.as_deref(), Some("fig1"));
        assert_eq!(a.get("mode"), Some("sim"));
        assert_eq!(a.get_u64("iters", 0), 500);
        assert_eq!(a.positional, vec!["extra"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --fast --out dir");
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("dir"));
    }

    #[test]
    fn defaults_kick_in() {
        let a = parse("x");
        assert_eq!(a.get_u64("n", 7), 7);
        assert_eq!(a.get_f64("r", 1.5), 1.5);
    }

    #[test]
    fn sweep_list_parses_defaults_and_rejects_garbage() {
        let a = parse("pool --shards 1,2,4");
        assert_eq!(a.sweep_list("shards", &[8]).unwrap(), vec![1, 2, 4]);
        let a = parse("pool --shards 1-3");
        assert_eq!(a.sweep_list("shards", &[8]).unwrap(), vec![1, 2, 3]);
        let a = parse("pool");
        assert_eq!(a.sweep_list("shards", &[1, 2]).unwrap(), vec![1, 2]);
        // Zero clamps to one (a zero-shard pool cannot exist).
        let a = parse("pool --shards 0,2");
        assert_eq!(a.sweep_list("shards", &[1]).unwrap(), vec![1, 2]);
        let a = parse("pool --shards nope");
        assert!(a.sweep_list("shards", &[1]).is_err());
    }
}
