//! `repro` — the experiment CLI.
//!
//! ```text
//! repro fig1         regenerate Fig. 1 (7 frameworks x 7 kernels)
//! repro fig3         regenerate Fig. 3 (Relic)
//! repro fig4         regenerate Fig. 4 + the §V geomeans
//! repro granularity  regenerate the §IV task-granularity table
//! repro sweep --kernel tc   speedup vs task-size crossover sweep
//! repro ablation --sweep waiting|queue-capacity|fetch-policy
//! repro wallclock    wall-clock mode (needs an SMT host for meaning)
//! repro intra        serial vs pair vs parallel_for per kernel (wall-clock;
//!                    --schedule static|dynamic|edge-balanced picks the
//!                    fork-join chunk assignment, --config reads [relic])
//! repro serve        run the hybrid analytics service demo
//!                    (--shards N runs the sharded engine; N=0 → auto;
//!                    --deadline-ms D stamps deadlines, --shed POLICY
//!                    sheds requests that cannot meet them,
//!                    --ema-alpha A measures per-shard service times,
//!                    --edf serves batches earliest-deadline-first,
//!                    --supervisor / --no-supervisor arms the shard
//!                    watchdog, --fault-* flags inject one scripted
//!                    failure for recovery drills, --max-borrow B lets
//!                    whale requests borrow up to B idle pair-shards,
//!                    --offer-depth D still offers shards with ≤ D
//!                    queued requests, --replay re-submits typed
//!                    failures of idempotent kernels at least once,
//!                    --health-json prints the health report after the
//!                    batch, --plan SPEC forces one execution plan on
//!                    every native request, --tuner turns on the online
//!                    per-(kernel, shape) plan tuner, --stream runs the
//!                    [stream] edge pipeline after the batch and folds
//!                    its counters into the engine report — sharded
//!                    engine only)
//! repro pool         pool-scaling sweep: throughput vs shard count,
//!                    with pool-vs-single-pair checksum verification
//!                    (--shards 1,2,4 --requests N --reps R)
//! repro admission    admission sweep: blocking vs try_submit vs
//!                    submit_or_park across offered loads, with
//!                    shed/park/miss accounting (--offered 16,64,256
//!                    --deadline-ms D --shed POLICY --reps R);
//!                    --edf spreads deadlines, serves each engine
//!                    batch earliest-deadline-first and prints the
//!                    FIFO-baseline miss column next to EDF's;
//!                    --ema-alpha A adds the measured-EMA column
//! repro faults       fault-recovery sweep: one scripted failure per
//!                    scenario (panic, stall, kill, drop, all-down)
//!                    against a supervised engine, asserting the
//!                    no-drop invariant and per-scenario recovery
//!                    counters (--requests N --shards N)
//! repro chaos        deterministic chaos soak: seeded random multi-fault
//!                    schedules (panic + stall + kill + drop interleaved)
//!                    against a supervised engine with at-least-once
//!                    replay, gated on no-drop, checksum-equal-to-serial
//!                    and replay-book reconciliation (--seed S --rounds R
//!                    --requests N --shards N; --no-replay soaks the
//!                    typed-failure path instead)
//! repro health       build the engine, warm it with one request per
//!                    kernel, and print the serialized health report;
//!                    exits nonzero unless the engine is live and ready
//! repro whale        whale-scaling sweep: one oversized request
//!                    borrowing idle pair-shards via the lease broker,
//!                    vs the serial and single-pair baselines, with a
//!                    bitwise checksum gate (--shards N --max-borrow B
//!                    --scale S --reps R; borrow 0 is always measured
//!                    as the degeneracy anchor)
//! repro plan         plan-ablation sweep: mixed-kernel rounds under the
//!                    pre-plan baseline, each forced static plan, and
//!                    the online tuner, with the tuner's resolved
//!                    per-(kernel, shape) assignments printed and a
//!                    bitwise checksum gate on every response
//!                    (--shards N --scale S --reps R; --tuner-epsilon,
//!                    --tuner-seed, --tuner-min-samples and --calibrate
//!                    shape the tuner row)
//! repro stream       streaming-pipeline sweep: parse → analytics →
//!                    emit stages over seeded power-law and uniform
//!                    edge streams, every incremental kernel hard-gated
//!                    bitwise against its full-recompute oracle and the
//!                    [stream]-off engine checked response-for-response
//!                    against a plain one (--scale S --batch B
//!                    --batches N --seed S --recompute-interval K
//!                    --queue-capacity Q; --shards N sizes the off-leg
//!                    engines)
//! repro selftest     PJRT artifact round-trip check
//! ```
//!
//! Common options: `--out results` writes figure JSON/text files;
//! `--iters N` (wallclock); `--artifacts DIR`; `--config FILE` loads
//! `[pool]`/`[admission]`/`[supervisor]`/`[fault]`/`[relic]`/
//! `[reliability]`/`[plan]`/`[tuner]`/`[stream]` settings for serve/
//! pool/admission/faults/chaos/health/whale/plan/stream (CLI flags
//! override); `--no-pin` disables CPU pinning.

use std::path::Path;

use relic_smt::bench::{self, figures};
use relic_smt::bench::ablation;
use relic_smt::cli::Args;
use relic_smt::config::{
    check_plan_conflict, AdmissionSettings, FaultSettings, PlanSettings, PoolSettings,
    RawConfig, RelicSettings, ReliabilitySettings, StreamSettings, SupervisorSettings,
    TunerSettings,
};
use relic_smt::coordinator::{
    stream, Coordinator, Deadline, EdgeDist, Engine, EngineConfig, GraphKernel, Request,
    Router, RouterConfig, ShedPolicy,
};
use relic_smt::graph::kronecker::paper_graph;
use relic_smt::relic::affinity;
use relic_smt::runtime::{GraphExecutor, Manifest};
use relic_smt::runtimes;
use relic_smt::smtsim::CoreConfig;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(err) => {
            eprintln!("error: {err:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> anyhow::Result<()> {
    let cfg = CoreConfig::default();
    match args.command.as_deref() {
        Some("fig1") => {
            let cells = figures::fig1(&cfg);
            println!("Figure 1 — speedups over serial (simulated SMT core)\n");
            println!("{}", figures::render_matrix(&cells));
            if args.flag("summary") {
                let rows = figures::section5_geomeans(&cells);
                println!("{}", figures::render_summary(&rows, "§V geomeans (with degradations)"));
            }
            write_out(args, "fig1.json", &figures::cells_to_json(&cells))?;
            write_out(
                args,
                "fig1.svg",
                &relic_smt::bench::svg::grouped_bars("Figure 1 — baseline frameworks", &cells),
            )?;
        }
        Some("fig3") => {
            let cells = figures::fig3(&cfg);
            println!("Figure 3 — Relic speedups over serial (simulated SMT core)\n");
            println!("{}", figures::render_matrix(&cells));
            write_out(args, "fig3.json", &figures::cells_to_json(&cells))?;
            write_out(
                args,
                "fig3.svg",
                &relic_smt::bench::svg::grouped_bars("Figure 3 — Relic", &cells),
            )?;
        }
        Some("fig4") => {
            let f1 = figures::fig1(&cfg);
            let f3 = figures::fig3(&cfg);
            let rows = figures::fig4(&f1, &f3);
            println!(
                "{}",
                figures::render_summary(
                    &rows,
                    "Figure 4 — average speedup w/o negative outliers"
                )
            );
            let geo = figures::section5_geomeans(&f1);
            println!("{}", figures::render_summary(&geo, "§V geomeans (with degradations)"));
            write_out(
                args,
                "fig4.svg",
                &relic_smt::bench::svg::summary_bars(
                    "Figure 4 — average speedup w/o negative outliers",
                    &rows,
                ),
            )?;
        }
        Some("sweep") => {
            // Granularity sweep (DESIGN.md: the crossover experiment).
            let kernel = args.get("kernel").unwrap_or("tc");
            let points = relic_smt::bench::sweep::granularity_sweep(
                kernel,
                &relic_smt::bench::sweep::DEFAULT_MICROS,
                &cfg,
            );
            println!("granularity sweep — kernel '{kernel}', speedup vs task size
");
            println!("{}", relic_smt::bench::sweep::render(&points));
            for rt in relic_smt::smtsim::model_names() {
                match relic_smt::bench::sweep::breakeven_micros(&points, rt, 1.0) {
                    Some(us) => println!("{rt:<14} breaks even at {us} µs"),
                    None => println!("{rt:<14} never breaks even in range"),
                }
            }
        }
        Some("granularity") => {
            let rows = figures::granularity(&cfg);
            println!("§IV serial task granularities (calibrated simulation)\n");
            println!("{}", figures::render_granularity(&rows));
        }
        Some("ablation") => {
            match args.get("sweep").unwrap_or("waiting") {
                "waiting" => {
                    let rows = ablation::waiting_mechanism(&cfg);
                    println!("{}", ablation::render(&rows, "A2 — waiting mechanism (Relic)"));
                }
                "queue-capacity" => {
                    let rows = ablation::queue_capacity(&cfg, &[2, 4, 8, 16, 32, 64, 128]);
                    println!("{}", ablation::render(&rows, "A1 — SPSC queue capacity"));
                }
                "fetch-policy" => {
                    let rows = ablation::fetch_policy(&cfg);
                    println!("{}", ablation::render(&rows, "A3 — SMT fetch policy"));
                }
                other => anyhow::bail!("unknown sweep {other}"),
            }
        }
        Some("wallclock") => {
            println!("host: {}", affinity::topology_summary());
            if affinity::smt_sibling_pair().is_none() {
                println!(
                    "WARNING: no SMT siblings — wall-clock numbers are not meaningful \
                     here; sim mode (fig1/fig3/fig4) is authoritative.\n"
                );
            }
            let iters = args.get_u64("iters", 2_000);
            let warmup = args.get_u64("warmup", 100);
            let pair = affinity::smt_sibling_pair();
            if let Some((main_cpu, _)) = pair {
                affinity::pin_to_cpu(main_cpu);
            }
            println!("{:<10}{:<14}{:>10}", "kernel", "runtime", "speedup");
            for w in bench::Workload::all() {
                for name in runtimes::FRAMEWORK_NAMES {
                    let mut rt = runtimes::by_name(name, pair.map(|p| p.1)).unwrap();
                    let s = bench::wallclock_speedup(rt.as_mut(), &w, iters, warmup);
                    println!("{:<10}{:<14}{:>10.3}", w.name, name, s);
                }
                // Relic via its native implementation.
                let relic = relic_smt::relic::Relic::with_config(
                    relic_smt::relic::RelicConfig {
                        assistant_cpu: pair.map(|p| p.1),
                        ..Default::default()
                    },
                );
                let sink = std::sync::atomic::AtomicU64::new(0);
                let task = || {
                    sink.fetch_add(w.run_native(), std::sync::atomic::Ordering::Relaxed);
                };
                let serial = bench::measure(iters, warmup, || {
                    task();
                    task();
                });
                let par = bench::measure(iters, warmup, || relic.pair(&task, &task));
                println!("{:<10}{:<14}{:>10.3}", w.name, "relic", serial.mean_ns / par.mean_ns);
            }
        }
        Some("intra") => {
            println!("host: {}", affinity::topology_summary());
            let pair = affinity::smt_sibling_pair();
            if pair.is_none() {
                println!(
                    "WARNING: no SMT siblings — wall-clock numbers are not \
                     meaningful here.\n"
                );
            }
            if let Some((main_cpu, _)) = pair {
                affinity::pin_to_cpu(main_cpu);
            }
            let settings = relic_settings(args)?;
            let schedule = settings.schedule;
            let mut relic_config = settings.to_relic_config();
            relic_config.assistant_cpu = pair.map(|p| p.1);
            let relic = relic_smt::relic::Relic::with_config(relic_config);
            let iters = args.get_u64("iters", 2_000);
            let warmup = args.get_u64("warmup", 100);
            let rows = figures::intra_kernel(&relic, schedule, iters, warmup);
            println!(
                "intra-kernel fork-join vs request pairing (wall-clock, {} schedule)\n",
                schedule.name()
            );
            println!("{}", figures::render_intra(&rows));
            println!("relic: {}", relic.stats().report());
            write_out(args, "intra.json", &figures::intra_rows_to_json(&rows))?;
        }
        Some("serve") => {
            let n_req = args.get_u64("requests", 64) as usize;
            let admission = admission_settings(args)?;
            let deadline = admission.deadline();
            let kernels = GraphKernel::all();
            let requests: Vec<Request> = (0..n_req)
                .map(|i| Request {
                    id: i as u64,
                    kernel: kernels[i % kernels.len()],
                    graph: paper_graph(),
                    source: (i % 32) as u32,
                    deadline: match deadline {
                        Some(d) => Deadline::within(d),
                        None => Deadline::none(),
                    },
                })
                .collect();
            if let Some(shards_arg) = args.get("shards") {
                // Sharded engine: one pinned Relic pair per shard, all
                // requests native (PJRT offload stays on the
                // single-pair path below).
                anyhow::ensure!(
                    shards_arg.is_empty() || shards_arg.parse::<usize>().is_ok(),
                    "serve --shards takes a single integer (got {shards_arg:?}); \
                     sweeps belong to `repro pool`"
                );
                let settings = pool_settings(args)?;
                let supervisor = supervisor_settings(args)?;
                let fault = fault_settings(args)?;
                let relic = relic_settings(args)?;
                let reliability = reliability_settings(args)?;
                let plan = plan_settings(args)?;
                let tuner = tuner_settings(args)?;
                let streaming = stream_settings(args)?;
                check_plan_conflict(&tuner, &plan)?;
                let mut engine_cfg =
                    EngineConfig::from_settings(&settings, &admission, &supervisor);
                engine_cfg.pool.fault = fault.plan();
                engine_cfg.max_borrow = relic.max_borrow;
                engine_cfg.reliability = reliability.to_config();
                engine_cfg.plan = plan.to_plan();
                engine_cfg.tuner = tuner.to_config();
                let mut engine = Engine::new(engine_cfg);
                println!(
                    "host: {}; engine: {} shards; shed policy {}; deadline {:?}; \
                     ema alpha {}; edf {}; supervisor {}; max borrow {}; replay {}{}",
                    affinity::topology_summary(),
                    engine.shard_count(),
                    admission.shed,
                    deadline,
                    admission.ema_alpha,
                    if admission.edf { "on" } else { "off" },
                    if engine.supervisor_enabled() { "on" } else { "off" },
                    relic.max_borrow,
                    if reliability.replay { "on" } else { "off" },
                    if fault.is_empty() { "" } else { "; fault injection armed" },
                );
                let t0 = std::time::Instant::now();
                let offered = requests.len();
                let responses = engine.process_batch(requests);
                let dt = t0.elapsed();
                println!(
                    "processed {} of {offered} requests in {dt:?} \
                     (the difference, if any, was shed — see below)",
                    responses.len()
                );
                if streaming.enabled {
                    // The edge-stream pipeline runs beside the request
                    // path; its counters fold into the report below.
                    // With `[stream]` off this block never executes and
                    // the report is byte-identical to a plain engine's.
                    let scfg = streaming.to_config();
                    let docs = stream::encode_stream(EdgeDist::PowerLaw, &scfg);
                    let (srep, _state) = stream::run_pipeline(&scfg, docs);
                    println!(
                        "stream leg: {} documents through the pipeline in {:.1} ms \
                         (pinned: {})",
                        srep.batches_in, srep.elapsed_ms, srep.pinned,
                    );
                    engine.set_stream(Some(srep.snapshot()));
                }
                println!("{}", engine.report());
                if args.flag("health-json") {
                    println!("{}", engine.health().to_json());
                }
                if engine.exit_requested() {
                    anyhow::bail!(
                        "restart budget exhausted with on_budget_exhausted = \
                         drain_and_exit; in-flight work was flushed with typed \
                         verdicts — exiting nonzero as configured"
                    );
                }
            } else {
                let artifacts = args.get("artifacts").unwrap_or("artifacts");
                let executor = GraphExecutor::new(Path::new(artifacts)).ok();
                let manifest = Manifest::load(Path::new(artifacts)).ok();
                if executor.is_none() {
                    println!("(no artifacts at {artifacts}; all requests run natively)");
                }
                let router = Router::new(RouterConfig::default(), manifest.as_ref());
                let mut coord = Coordinator::with_parts(router, executor);
                coord.set_edf(admission.edf);
                // The single-pair path has no Engine to arm the
                // estimator, so --ema-alpha is honored here directly.
                let adm = admission.to_config();
                coord.metrics.service_estimator.configure(adm.ema_alpha, adm.service_estimate_ns);
                let t_warm = std::time::Instant::now();
                coord.warmup();
                println!("executable warmup: {:?}", t_warm.elapsed());
                let t0 = std::time::Instant::now();
                let responses = coord.process_batch(requests);
                let dt = t0.elapsed();
                println!("processed {} requests in {:?}", responses.len(), dt);
                println!("{}", coord.report());
            }
        }
        Some("pool") => {
            let settings = pool_settings(args)?;
            let shard_counts = args.sweep_list("shards", &[1, 2, 4])?;
            let requests = args.get_u64("requests", 96) as usize;
            let reps = args.get_u64("reps", 3);
            println!("host: {}", affinity::topology_summary());
            let template = EngineConfig::from_settings(
                &settings,
                &admission_settings(args)?,
                &supervisor_settings(args)?,
            );
            println!(
                "pool-scaling sweep: shard counts {shard_counts:?}, \
                 {requests} requests, {reps} reps\n"
            );
            let rows = figures::pool_scaling(&template, &shard_counts, requests, reps);
            println!("{}", figures::render_pool_scaling(&rows));
            write_out(args, "pool_scaling.json", &figures::pool_rows_to_json(&rows))?;
        }
        Some("admission") => {
            let settings = pool_settings(args)?;
            let admission = admission_settings(args)?;
            let supervisor = supervisor_settings(args)?;
            let offered = args.sweep_list("offered", &[16, 64, 256])?;
            let reps = args.get_u64("reps", 3);
            println!("host: {}", affinity::topology_summary());
            let template = EngineConfig::from_settings(&settings, &admission, &supervisor);
            println!(
                "admission sweep: offered loads {offered:?}, {reps} reps, shed policy {}, \
                 deadline {:?}, ema alpha {}, edf {}, {} shard(s)\n",
                admission.shed,
                admission.deadline(),
                admission.ema_alpha,
                if admission.edf { "on (FIFO baseline alongside)" } else { "off" },
                settings
                    .shard_count_hint()
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| "auto".into()),
            );
            let rows = figures::admission_sweep(&template, &offered, admission.deadline(), reps);
            println!("{}", figures::render_admission(&rows));
            write_out(args, "admission.json", &figures::admission_rows_to_json(&rows))?;
        }
        Some("faults") => {
            let settings = pool_settings(args)?;
            let admission = admission_settings(args)?;
            let supervisor = supervisor_settings(args)?;
            let requests = args.get_u64("requests", 48) as usize;
            println!("host: {}", affinity::topology_summary());
            let template = EngineConfig::from_settings(&settings, &admission, &supervisor);
            println!(
                "fault-recovery sweep: {requests} requests per scenario, {} shard(s), \
                 supervisor forced on\n",
                settings
                    .shard_count_hint()
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| "auto".into()),
            );
            let rows = figures::fault_sweep(&template, requests);
            println!("{}", figures::render_faults(&rows));
            write_out(args, "faults.json", &figures::fault_rows_to_json(&rows))?;
        }
        Some("chaos") => {
            let settings = pool_settings(args)?;
            let admission = admission_settings(args)?;
            let supervisor = supervisor_settings(args)?;
            let reliability = reliability_settings(args)?;
            let seed = args.get_u64("seed", 1);
            let rounds = args.get_u64("rounds", 3) as usize;
            let requests = args.get_u64("requests", 96) as usize;
            // The soak defaults replay ON — recovering every injected
            // failure is what it exists to prove. `--no-replay` soaks
            // the typed-failure surfacing path instead.
            let replay = !args.flag("no-replay");
            println!("host: {}", affinity::topology_summary());
            let mut template = EngineConfig::from_settings(&settings, &admission, &supervisor);
            template.reliability = reliability.to_config();
            println!(
                "chaos soak: seed {seed}, {rounds} round(s), {requests} requests/round, \
                 {} shard(s), replay {}\n",
                settings
                    .shard_count_hint()
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| "auto (2)".into()),
                if replay { "on" } else { "off" },
            );
            let rows = figures::chaos_soak(&template, seed, rounds, requests, replay);
            println!("{}", figures::render_chaos(&rows));
            write_out(args, "chaos.json", &figures::chaos_rows_to_json(&rows))?;
        }
        Some("health") => {
            let settings = pool_settings(args)?;
            let admission = admission_settings(args)?;
            let mut supervisor = supervisor_settings(args)?;
            let reliability = reliability_settings(args)?;
            // The self-check wants the watchdog's view; honor an
            // explicit opt-out but default it on.
            if !args.flag("no-supervisor") {
                supervisor.enabled = true;
            }
            let mut engine_cfg = EngineConfig::from_settings(&settings, &admission, &supervisor);
            engine_cfg.reliability = reliability.to_config();
            let mut engine = Engine::new(engine_cfg);
            // Warm every shard with one request per kernel so the
            // heartbeats and depth columns report a served engine, not
            // a cold one.
            let requests: Vec<Request> = GraphKernel::all()
                .into_iter()
                .enumerate()
                .map(|(i, kernel)| Request {
                    id: i as u64,
                    kernel,
                    graph: paper_graph(),
                    source: 0,
                    deadline: Deadline::none(),
                })
                .collect();
            let warmed = engine.process_batch(requests);
            let report = engine.health();
            println!("{}", report.to_json());
            anyhow::ensure!(warmed.len() == 6, "health warmup lost responses");
            anyhow::ensure!(
                report.live && report.ready,
                "engine is not healthy (live={}, ready={})",
                report.live,
                report.ready
            );
            println!("health OK: live and ready");
        }
        Some("whale") => {
            let settings = pool_settings(args)?;
            let admission = admission_settings(args)?;
            let supervisor = supervisor_settings(args)?;
            let relic = relic_settings(args)?;
            let shards = args.get_u64("shards", 2).max(1) as usize;
            let scale = args.get_u64("scale", 10) as u32;
            let reps = args.get_u64("reps", 3);
            // Borrow cap: CLI flag, else `[relic] max_borrow` when set,
            // else every other shard. Borrow 0 is always measured too —
            // it is the degeneracy anchor the speedups are read against.
            let cap_default =
                if relic.max_borrow > 0 { relic.max_borrow } else { shards - 1 };
            let cap = args.get_u64("max-borrow", cap_default as u64) as usize;
            let mut borrows = vec![0usize];
            if cap > 0 {
                borrows.push(cap);
            }
            println!("host: {}", affinity::topology_summary());
            if shards < 2 || cap == 0 {
                println!(
                    "WARNING: borrowing needs >= 2 shards and a borrow cap > 0; \
                     this run only exercises the degenerate path.\n"
                );
            }
            let template = EngineConfig::from_settings(&settings, &admission, &supervisor);
            println!(
                "whale-scaling sweep: {shards} shard(s), borrow caps {borrows:?}, \
                 graph scale {scale}, {reps} reps\n"
            );
            let rows = figures::whale_sweep(&template, shards, &borrows, scale, reps);
            println!("{}", figures::render_whale(&rows));
            write_out(args, "cross_shard.json", &figures::whale_rows_to_json(&rows))?;
        }
        Some("plan") => {
            let settings = pool_settings(args)?;
            let admission = admission_settings(args)?;
            let supervisor = supervisor_settings(args)?;
            let mut tuner = tuner_settings(args)?;
            // The sweep always measures a tuner row — `--tuner` is
            // implied; the remaining knobs shape that row.
            tuner.enabled = true;
            tuner.validate()?;
            let shards = args.get_u64("shards", 2).max(1) as usize;
            let scale = args.get_u64("scale", 8) as u32;
            let reps = args.get_u64("reps", 3);
            println!("host: {}", affinity::topology_summary());
            let mut template = EngineConfig::from_settings(&settings, &admission, &supervisor);
            template.tuner = tuner.to_config();
            println!(
                "plan-ablation sweep: {shards} shard(s), graph scale {scale}, {reps} reps, \
                 tuner epsilon {}, seed {}, calibrate {}\n",
                tuner.epsilon,
                tuner.seed,
                if tuner.calibrate { "on" } else { "off" },
            );
            let rows = figures::plan_sweep(&template, shards, scale, reps);
            println!("{}", figures::render_plan(&rows));
            write_out(args, "plan.json", &figures::plan_rows_to_json(&rows))?;
        }
        Some("stream") => {
            let settings = pool_settings(args)?;
            let admission = admission_settings(args)?;
            let supervisor = supervisor_settings(args)?;
            let streaming = stream_settings(args)?;
            let shards = args.get_u64("shards", 2).max(1) as usize;
            println!("host: {}", affinity::topology_summary());
            let template = EngineConfig::from_settings(&settings, &admission, &supervisor);
            let scfg = streaming.to_config();
            println!(
                "streaming sweep: 2^{} vertices, {} batches x {} edges, queue capacity {}, \
                 recompute every {} batches, seed {}, {} shard(s) for the off-leg\n",
                scfg.scale,
                scfg.batches,
                scfg.batch,
                scfg.queue_capacity,
                scfg.recompute_interval,
                scfg.seed,
                shards,
            );
            // Every row passes the hard gates inside the sweep or the
            // whole command exits nonzero with the failing row printed.
            let rows = figures::stream_sweep(&template, &scfg, shards)?;
            println!("{}", figures::render_stream(&rows));
            write_out(args, "stream.json", &figures::stream_rows_to_json(&rows))?;
        }
        Some("selftest") => {
            let artifacts = args.get("artifacts").unwrap_or("artifacts");
            let mut exec = GraphExecutor::new(Path::new(artifacts))?;
            println!("platform: {}", exec.platform());
            println!("artifacts: {:?}", exec.available());
            // Round-trip PageRank vs the native kernel.
            let g = paper_graph();
            let n = g.num_vertices();
            let scores = exec.execute(
                "pagerank",
                n,
                &[
                    relic_smt::graph::dense::transition(&g),
                    relic_smt::graph::dense::uniform(n),
                ],
            )?;
            let native =
                relic_smt::graph::pr::pagerank(&g, 20, 0.0, &mut relic_smt::probe::NoProbe);
            let max_err = scores
                .iter()
                .zip(&native)
                .map(|(a, b)| (*a as f64 - b).abs())
                .fold(0.0f64, f64::max);
            println!("pagerank max |pjrt - native| = {max_err:.2e}");
            anyhow::ensure!(max_err < 1e-4, "PJRT pagerank diverges from native");
            println!("selftest OK");
        }
        _ => {
            println!(
                "usage: repro <fig1|fig3|fig4|granularity|ablation|wallclock|intra\
                 |serve|pool|admission|faults|chaos|health|whale|plan|stream|selftest> \
                 [--options]"
            );
            println!("see rust/src/main.rs docs for details");
        }
    }
    Ok(())
}

/// `[relic]` settings: config file first (`--config PATH`), then the
/// `--schedule static|dynamic|edge-balanced` and `--max-borrow N` CLI
/// overrides.
fn relic_settings(args: &Args) -> anyhow::Result<RelicSettings> {
    let mut s = match args.get("config") {
        Some(path) => RelicSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => RelicSettings::default(),
    };
    if let Some(name) = args.get("schedule") {
        s.schedule = relic_smt::relic::Schedule::parse(name).ok_or_else(|| {
            anyhow::anyhow!("unknown --schedule {name:?} (static|dynamic|edge-balanced)")
        })?;
    }
    s.max_borrow = args.get_u64("max-borrow", s.max_borrow as u64) as usize;
    Ok(s)
}

/// `[admission]` settings: config file first (`--config PATH`), then
/// CLI overrides (`--shed never|past-deadline|load-factor[:F]`,
/// `--deadline-ms N`, `--service-estimate-us N`, `--ema-alpha A`,
/// `--edf` / `--no-edf` — the latter lets the CLI A/B the FIFO
/// baseline against a config file that sets `edf = true`).
fn admission_settings(args: &Args) -> anyhow::Result<AdmissionSettings> {
    let mut s = match args.get("config") {
        Some(path) => AdmissionSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => AdmissionSettings::default(),
    };
    if let Some(name) = args.get("shed") {
        anyhow::ensure!(
            ShedPolicy::parse(name).is_some(),
            "unknown --shed {name:?} (never|past-deadline|load-factor[:F])"
        );
        s.shed = name.to_string();
    }
    s.deadline_ms = args.get_u64("deadline-ms", s.deadline_ms);
    s.service_estimate_us = args.get_u64("service-estimate-us", s.service_estimate_us);
    s.ema_alpha = args.get_f64("ema-alpha", s.ema_alpha).clamp(0.0, 1.0);
    if args.flag("edf") {
        s.edf = true;
    }
    if args.flag("no-edf") {
        s.edf = false;
    }
    Ok(s)
}

/// `[pool]` settings: config file first (`--config PATH`), then CLI
/// overrides (`--shards N`, `--no-pin`, `--channel-capacity N`,
/// `--max-batch N`, `--park-timeout-ms N`, `--offer-depth N`). A
/// `--shards` value that is not a single integer (the `pool` command's
/// sweep list) leaves the file/default value.
fn pool_settings(args: &Args) -> anyhow::Result<PoolSettings> {
    let mut s = match args.get("config") {
        Some(path) => PoolSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => PoolSettings::default(),
    };
    if let Some(Ok(n)) = args.get("shards").map(|v| v.parse::<usize>()) {
        s.shards = n;
    }
    if args.flag("no-pin") {
        s.pin = false;
    }
    s.channel_capacity =
        args.get_u64("channel-capacity", s.channel_capacity as u64).max(1) as usize;
    s.max_batch = args.get_u64("max-batch", s.max_batch as u64).max(1) as usize;
    s.park_timeout_ms = args.get_u64("park-timeout-ms", s.park_timeout_ms).max(1);
    s.offer_depth = args.get_u64("offer-depth", s.offer_depth as u64) as usize;
    Ok(s)
}

/// `[supervisor]` settings: config file first (`--config PATH`), then
/// CLI overrides (`--supervisor` / `--no-supervisor` — the flag pair
/// lets the CLI A/B against a config file that disables the watchdog —
/// `--stuck-after-ms N`, `--max-restarts N`, `--backoff-ms N`,
/// `--heal-after-ticks N`, `--on-budget-exhausted POLICY`). The merged
/// result is validated before use: contradictory combinations (a zero
/// stuck threshold, a restart budget with no backoff) and unknown exit
/// policies are typed startup errors, not silent surprises at fault
/// time.
fn supervisor_settings(args: &Args) -> anyhow::Result<SupervisorSettings> {
    let mut s = match args.get("config") {
        Some(path) => SupervisorSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => SupervisorSettings::default(),
    };
    if args.flag("supervisor") {
        s.enabled = true;
    }
    if args.flag("no-supervisor") {
        s.enabled = false;
    }
    s.stuck_after_ms = args.get_u64("stuck-after-ms", s.stuck_after_ms).max(1);
    s.max_restarts = args.get_u64("max-restarts", s.max_restarts as u64) as u32;
    s.backoff_ms = args.get_u64("backoff-ms", s.backoff_ms);
    s.heal_after_ticks = args.get_u64("heal-after-ticks", s.heal_after_ticks as u64) as u32;
    if let Some(policy) = args.get("on-budget-exhausted") {
        s.on_budget_exhausted = policy.to_string();
    }
    s.validate()?;
    Ok(s)
}

/// `[reliability]` settings: config file first (`--config PATH`), then
/// CLI overrides (`--replay` / `--no-replay`, `--replay-max-attempts N`,
/// `--replay-backoff-ms N`, `--replay-kernels bfs,pr`). Validated
/// before use: replay with a zero attempt budget, an unknown kernel
/// name, or a non-idempotent kernel in the allow-list is a typed
/// startup error, not a silent no-op.
fn reliability_settings(args: &Args) -> anyhow::Result<ReliabilitySettings> {
    let mut s = match args.get("config") {
        Some(path) => ReliabilitySettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => ReliabilitySettings::default(),
    };
    if args.flag("replay") {
        s.replay = true;
    }
    if args.flag("no-replay") {
        s.replay = false;
    }
    s.max_attempts = args.get_u64("replay-max-attempts", s.max_attempts as u64) as u32;
    s.backoff_ms = args.get_u64("replay-backoff-ms", s.backoff_ms);
    if let Some(list) = args.get("replay-kernels") {
        s.replay_kernels = list.to_string();
    }
    s.validate()?;
    Ok(s)
}

/// `[plan]` settings: config file first (`--config PATH`), then the
/// `--plan SPEC` CLI override (`serial` or
/// `pair:<static|dynamic|edge-balanced>[:<grain>[:<borrow>]]`). The
/// merged spec is validated before use: an unrecognized spec is a typed
/// startup error.
fn plan_settings(args: &Args) -> anyhow::Result<PlanSettings> {
    let mut s = match args.get("config") {
        Some(path) => PlanSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => PlanSettings::default(),
    };
    if let Some(spec) = args.get("plan") {
        s.force = spec.to_string();
    }
    s.validate()?;
    Ok(s)
}

/// `[tuner]` settings: config file first (`--config PATH`), then CLI
/// overrides (`--tuner` / `--no-tuner` — the flag pair lets the CLI A/B
/// against a config file that enables the tuner — `--tuner-epsilon A`,
/// `--tuner-seed S`, `--tuner-min-samples N`; `--calibrate` seeds the
/// arm statistics from the probe/smtsim offline oracle before serving).
/// Validated before use: an out-of-range epsilon or a zero exploration
/// quota on an enabled tuner is a typed startup error.
fn tuner_settings(args: &Args) -> anyhow::Result<TunerSettings> {
    let mut s = match args.get("config") {
        Some(path) => TunerSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => TunerSettings::default(),
    };
    if args.flag("tuner") {
        s.enabled = true;
    }
    if args.flag("no-tuner") {
        s.enabled = false;
    }
    s.epsilon = args.get_f64("tuner-epsilon", s.epsilon);
    s.seed = args.get_u64("tuner-seed", s.seed);
    s.min_samples = args.get_u64("tuner-min-samples", s.min_samples);
    if args.flag("calibrate") {
        s.calibrate = true;
    }
    s.validate()?;
    Ok(s)
}

/// `[stream]` settings: config file first (`--config PATH`), then CLI
/// overrides (`--stream` turns the pipeline on for `serve`, `--scale S`,
/// `--batch B`, `--batches N`, `--queue-capacity Q`,
/// `--recompute-interval K`, `--source V`, `--seed S`, `--no-pin`).
/// The merged result is validated before use: a scale outside the
/// memoized-trajectory range, a degenerate batch shape or queue, or a
/// BFS source outside the vertex range is a typed startup error.
fn stream_settings(args: &Args) -> anyhow::Result<StreamSettings> {
    let mut s = match args.get("config") {
        Some(path) => StreamSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => StreamSettings::default(),
    };
    if args.flag("stream") {
        s.enabled = true;
    }
    s.scale = args.get_u64("scale", u64::from(s.scale)) as u32;
    s.batch = args.get_u64("batch", s.batch as u64) as usize;
    s.batches = args.get_u64("batches", s.batches as u64) as usize;
    s.queue_capacity = args.get_u64("queue-capacity", s.queue_capacity as u64) as usize;
    s.recompute_interval =
        args.get_u64("recompute-interval", s.recompute_interval as u64) as usize;
    s.source = args.get_u64("source", u64::from(s.source)) as u32;
    s.seed = args.get_u64("seed", s.seed);
    if args.flag("no-pin") {
        s.pin = false;
    }
    s.validate()?;
    Ok(s)
}

/// `[fault]` settings: config file first (`--config PATH`), then the
/// CLI injection flags (`--fault-panic-kernel K --fault-panic-nth N`,
/// `--fault-stall-shard S --fault-stall-ms D`, `--fault-drop-shard S`,
/// `--fault-kill-shard S`, each shard flag with its own `-nth`).
/// Everything defaults to off; `serve` arms the resulting plan only
/// when at least one injection is configured.
fn fault_settings(args: &Args) -> anyhow::Result<FaultSettings> {
    let mut s = match args.get("config") {
        Some(path) => FaultSettings::from_raw(&RawConfig::load(Path::new(path))?),
        None => FaultSettings::default(),
    };
    if let Some(kernel) = args.get("fault-panic-kernel") {
        s.panic_kernel = kernel.to_string();
    }
    s.panic_nth = args.get_u64("fault-panic-nth", s.panic_nth).max(1);
    let shard_flag = |name: &str, current: i64| -> anyhow::Result<i64> {
        match args.get(name) {
            Some(v) => v
                .parse::<i64>()
                .map(|n| n.max(-1))
                .map_err(|_| anyhow::anyhow!("--{name} takes a shard index (got {v:?})")),
            None => Ok(current),
        }
    };
    s.stall_shard = shard_flag("fault-stall-shard", s.stall_shard)?;
    s.stall_nth = args.get_u64("fault-stall-nth", s.stall_nth).max(1);
    s.stall_ms = args.get_u64("fault-stall-ms", s.stall_ms);
    s.drop_shard = shard_flag("fault-drop-shard", s.drop_shard)?;
    s.drop_nth = args.get_u64("fault-drop-nth", s.drop_nth).max(1);
    s.kill_shard = shard_flag("fault-kill-shard", s.kill_shard)?;
    s.kill_nth = args.get_u64("fault-kill-nth", s.kill_nth).max(1);
    Ok(s)
}

fn write_out(args: &Args, name: &str, content: &str) -> anyhow::Result<()> {
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let path = Path::new(dir).join(name);
        std::fs::write(&path, content)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
