//! Cache hierarchy model for one physical core.
//!
//! On Intel SMT (the paper's i7-8700), the two logical threads of a core
//! *share* L1d and L2 — the very property that makes producer/consumer
//! data passing cheap on an SMT pair (paper §I: "passing data through
//! lower private levels of cache hierarchy in the same physical CPU
//! core could reduce an overhead"). The model is a set-associative LRU
//! L1d and L2 plus a fixed-latency LLC/memory backstop, shared by both
//! simulated contexts; capacity/conflict contention between the two
//! co-running kernel instances emerges naturally.

/// Latencies in cycles (Skylake-ish; see DESIGN.md §2 calibration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheConfig {
    pub l1_bytes: usize,
    pub l1_ways: usize,
    pub l2_bytes: usize,
    pub l2_ways: usize,
    pub line_bytes: usize,
    pub l1_latency: u64,
    pub l2_latency: u64,
    pub llc_latency: u64,
    pub mem_latency: u64,
    /// Fraction (per mille) of LLC hits among L2 misses — a 12 MiB LLC
    /// holds every benchmark working set, so this defaults high.
    pub llc_hit_per_mille: u32,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            l1_bytes: 32 * 1024,
            l1_ways: 8,
            l2_bytes: 256 * 1024,
            l2_ways: 4,
            line_bytes: 64,
            l1_latency: 4,
            l2_latency: 14,
            llc_latency: 44,
            mem_latency: 200,
            llc_hit_per_mille: 950,
        }
    }
}

/// One set-associative LRU level.
struct Level {
    sets: Vec<Vec<u64>>, // per-set: line tags, most-recent last
    ways: usize,
    set_shift: u32,
    set_mask: u64,
}

impl Level {
    fn new(bytes: usize, ways: usize, line: usize) -> Self {
        let sets = (bytes / line / ways).max(1);
        assert!(sets.is_power_of_two(), "cache sets must be a power of two");
        Level {
            sets: vec![Vec::with_capacity(ways); sets],
            ways,
            set_shift: line.trailing_zeros(),
            set_mask: sets as u64 - 1,
        }
    }

    /// Returns true on hit; inserts/updates LRU either way.
    fn access(&mut self, line_addr: u64) -> bool {
        let set = ((line_addr >> self.set_shift) & self.set_mask) as usize;
        let lines = &mut self.sets[set];
        if let Some(pos) = lines.iter().position(|&t| t == line_addr) {
            let tag = lines.remove(pos);
            lines.push(tag);
            true
        } else {
            if lines.len() == self.ways {
                lines.remove(0); // evict LRU
            }
            lines.push(line_addr);
            false
        }
    }

    fn clear(&mut self) {
        for s in &mut self.sets {
            s.clear();
        }
    }
}

/// The shared L1d/L2 + LLC model.
pub struct CacheModel {
    cfg: CacheConfig,
    l1: Level,
    l2: Level,
    /// Deterministic counter driving the LLC-vs-memory split.
    llc_roll: u32,
    /// Stats.
    pub accesses: u64,
    pub l1_misses: u64,
    pub l2_misses: u64,
}

impl CacheModel {
    pub fn new(cfg: CacheConfig) -> Self {
        CacheModel {
            l1: Level::new(cfg.l1_bytes, cfg.l1_ways, cfg.line_bytes),
            l2: Level::new(cfg.l2_bytes, cfg.l2_ways, cfg.line_bytes),
            cfg,
            llc_roll: 0,
            accesses: 0,
            l1_misses: 0,
            l2_misses: 0,
        }
    }

    /// Access one address; returns the load-to-use latency in cycles.
    pub fn access(&mut self, addr: u64) -> u64 {
        self.accesses += 1;
        let line = addr & !(self.cfg.line_bytes as u64 - 1);
        if self.l1.access(line) {
            return self.cfg.l1_latency;
        }
        self.l1_misses += 1;
        if self.l2.access(line) {
            return self.cfg.l2_latency;
        }
        self.l2_misses += 1;
        // LLC modeled statistically (deterministic rotation): the
        // benchmarks' working sets fit, so most L2 misses hit LLC.
        self.llc_roll = (self.llc_roll + 613) % 1000;
        if self.llc_roll < self.cfg.llc_hit_per_mille {
            self.cfg.llc_latency
        } else {
            self.cfg.mem_latency
        }
    }

    /// Reset tags and stats (between independent measurements).
    pub fn clear(&mut self) {
        self.l1.clear();
        self.l2.clear();
        self.llc_roll = 0;
        self.accesses = 0;
        self.l1_misses = 0;
        self.l2_misses = 0;
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits_l1() {
        let mut c = CacheModel::new(CacheConfig::default());
        assert!(c.access(0x1000) > c.cfg.l1_latency); // cold miss
        assert_eq!(c.access(0x1000), c.cfg.l1_latency);
        assert_eq!(c.access(0x1004), c.cfg.l1_latency); // same line
        assert_eq!(c.l1_misses, 1);
    }

    #[test]
    fn working_set_larger_than_l1_spills_to_l2() {
        let mut c = CacheModel::new(CacheConfig::default());
        // Touch 64 KiB (2x L1) twice; second pass should mostly hit L2.
        for round in 0..2 {
            for i in 0..1024u64 {
                c.access(i * 64);
            }
            if round == 0 {
                c.l1_misses = 0;
                c.l2_misses = 0;
            }
        }
        assert!(c.l1_misses > 0, "L1 cannot hold 64 KiB");
        assert_eq!(c.l2_misses, 0, "L2 holds 64 KiB: {}", c.l2_misses);
    }

    #[test]
    fn lru_keeps_hot_line() {
        let cfg = CacheConfig::default();
        let mut c = CacheModel::new(cfg);
        let sets = cfg.l1_bytes / cfg.line_bytes / cfg.l1_ways;
        let stride = (sets * cfg.line_bytes) as u64; // same-set addresses
        let hot = 0u64;
        c.access(hot);
        // Touch ways-1 conflicting lines, re-touching hot in between.
        for i in 1..cfg.l1_ways as u64 {
            c.access(i * stride);
            c.access(hot);
        }
        let before = c.l1_misses;
        assert_eq!(c.access(hot), cfg.l1_latency);
        assert_eq!(c.l1_misses, before);
    }

    #[test]
    fn clear_resets_state() {
        let mut c = CacheModel::new(CacheConfig::default());
        c.access(0x40);
        c.clear();
        assert_eq!(c.accesses, 0);
        assert!(c.access(0x40) > c.config().l1_latency);
    }
}
