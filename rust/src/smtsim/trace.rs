//! Operation traces: the IR the benchmark kernels are replayed through
//! on the simulated SMT core.
//!
//! [`TraceProbe`] implements [`crate::probe::Probe`], so the *same*
//! kernel code that runs natively also produces the trace (DESIGN.md
//! §4.1 — no twin implementations to diverge).

use crate::probe::Probe;

/// Synchronization flag ids used by the runtime overhead models.
pub mod flags {
    /// Producer → consumer: a task is available.
    pub const TASK_READY: u32 = 0;
    /// Consumer → producer: the task has completed.
    pub const TASK_DONE: u32 = 1;
    /// Number of flags the simulator allocates.
    pub const COUNT: usize = 4;
}

/// How a context polls while waiting on a flag (models each runtime's
/// idle-wait mechanism — see `overhead.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PollKind {
    /// Tight load+cmp+jmp loop, no `pause`: hogs issue slots.
    Spin,
    /// Spin with `pause` between polls (Relic, OpenMP spin waits).
    SpinPause,
    /// A CAS attempt per poll (lock-less steal loops: X-OpenMP).
    CasPoll,
    /// A try-lock (atomic RMW pair) per poll (locked deques: LLVM/Intel
    /// OpenMP taskwait help-polling, OpenCilk victim locks).
    LockedPoll,
    /// Exponentially growing `pause` sequences (oneTBB backoff).
    Backoff,
    /// Bounded `pause` spin, then park until woken by a futex
    /// (Taskflow notifier; `n` = spin iterations before parking).
    HybridPark(u32),
    /// Park immediately; waking costs the OS wake latency (GNU OpenMP
    /// condvar waits).
    Park,
}

/// One architectural operation in a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Data load (blocking, in-order) from a logical byte address.
    Load(u64),
    /// Dependent (pointer-chase) load: full latency exposed, plus an SMT
    /// partitioning penalty while the sibling context is active.
    LoadDep(u64),
    /// Data store (fire-and-forget through the store buffer).
    Store(u64),
    /// `n` independent ALU micro-ops.
    Compute(u32),
    /// `n` dependent floating-point micro-ops (latency chain).
    ComputeFp(u32),
    /// Conditional branch; `true` = well-predicted.
    Branch(bool),
    /// Lock-prefixed read-modify-write on an address (serializing).
    AtomicRmw(u64),
    /// The x86 `pause` instruction: yields issue slots to the sibling.
    Pause,
    /// Publish a flag (store + cross-thread visibility delay).
    SetFlag(u32),
    /// Wait until a flag is visible, polling per [`PollKind`].
    WaitFlag(u32, PollKind),
    /// Fixed-cost kernel entry (futex wake syscall etc.), in cycles.
    Syscall(u32),
}

/// A recorded operation sequence.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub ops: Vec<Op>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rough work measure: total micro-ops (used in tests and reports).
    pub fn uops(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                Op::Compute(n) | Op::ComputeFp(n) => *n as u64,
                _ => 1,
            })
            .sum()
    }

    /// Count of memory operations (loads + stores + atomics).
    pub fn mem_ops(&self) -> u64 {
        self.ops
            .iter()
            .filter(|op| {
                matches!(op, Op::Load(_) | Op::LoadDep(_) | Op::Store(_) | Op::AtomicRmw(_))
            })
            .count() as u64
    }

    /// Append another trace.
    pub fn extend(&mut self, other: &Trace) {
        self.ops.extend_from_slice(&other.ops);
    }
}

/// Probe that records a [`Trace`], offsetting every address by
/// `instance_offset` so two benchmark instances reference distinct
/// copies of their data (the paper passes each kernel instance its own
/// graph copy).
pub struct TraceProbe {
    trace: Trace,
    instance_offset: u64,
}

impl TraceProbe {
    pub fn new() -> Self {
        Self::with_offset(0)
    }

    /// `instance` 0, 1, … place their data in disjoint address regions.
    pub fn with_offset(instance: u64) -> Self {
        TraceProbe {
            trace: Trace::new(),
            // Distinct 16 MiB regions; NOT a multiple of the L1/L2 way
            // size so the two instances don't alias the same sets
            // pathologically (matches distinct heap allocations).
            instance_offset: instance * 0x100_F040,
        }
    }

    /// Take the recorded trace.
    pub fn finish(self) -> Trace {
        self.trace
    }

    /// Ops recorded so far.
    pub fn len(&self) -> usize {
        self.trace.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trace.ops.is_empty()
    }
}

impl Default for TraceProbe {
    fn default() -> Self {
        Self::new()
    }
}

impl Probe for TraceProbe {
    #[inline]
    fn load(&mut self, addr: u64) {
        self.trace.ops.push(Op::Load(addr + self.instance_offset));
    }
    #[inline]
    fn load_dep(&mut self, addr: u64) {
        self.trace.ops.push(Op::LoadDep(addr + self.instance_offset));
    }
    #[inline]
    fn store(&mut self, addr: u64) {
        self.trace.ops.push(Op::Store(addr + self.instance_offset));
    }
    #[inline]
    fn compute(&mut self, n: u32) {
        // Merge adjacent computes to keep traces compact.
        if let Some(Op::Compute(last)) = self.trace.ops.last_mut() {
            *last += n;
        } else {
            self.trace.ops.push(Op::Compute(n));
        }
    }
    #[inline]
    fn compute_fp(&mut self, n: u32) {
        if let Some(Op::ComputeFp(last)) = self.trace.ops.last_mut() {
            *last += n;
        } else {
            self.trace.ops.push(Op::ComputeFp(n));
        }
    }
    #[inline]
    fn branch(&mut self, predictable: bool) {
        self.trace.ops.push(Op::Branch(predictable));
    }
    #[inline]
    fn atomic_rmw(&mut self, addr: u64) {
        self.trace.ops.push(Op::AtomicRmw(addr + self.instance_offset));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::probe::Probe;

    #[test]
    fn records_ops_with_offset() {
        let mut p = TraceProbe::with_offset(1);
        p.load(0x100);
        p.store(0x200);
        p.compute(3);
        p.compute(2); // merges
        p.branch(true);
        let t = p.finish();
        assert_eq!(
            t.ops,
            vec![
                Op::Load(0x100 + 0x100_F040),
                Op::Store(0x200 + 0x100_F040),
                Op::Compute(5),
                Op::Branch(true),
            ]
        );
        assert_eq!(t.uops(), 8);
        assert_eq!(t.mem_ops(), 2);
    }

    #[test]
    fn kernel_traces_are_deterministic() {
        use crate::graph::{bfs, kronecker::paper_graph};
        let g = paper_graph();
        let mk = || {
            let mut p = TraceProbe::new();
            bfs::bfs(&g, 0, &mut p);
            p.finish()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn two_instances_do_not_share_addresses() {
        use crate::graph::{kronecker::paper_graph, tc};
        let g = paper_graph();
        let mut p0 = TraceProbe::with_offset(0);
        let mut p1 = TraceProbe::with_offset(1);
        tc::triangle_count(&g, &mut p0);
        tc::triangle_count(&g, &mut p1);
        let a0: std::collections::HashSet<u64> = p0
            .finish()
            .ops
            .iter()
            .filter_map(|o| match o {
                Op::Load(a) | Op::Store(a) => Some(*a),
                _ => None,
            })
            .collect();
        let a1: std::collections::HashSet<u64> = p1
            .finish()
            .ops
            .iter()
            .filter_map(|o| match o {
                Op::Load(a) | Op::Store(a) => Some(*a),
                _ => None,
            })
            .collect();
        assert!(a0.is_disjoint(&a1));
    }
}
