//! Per-runtime overhead models: the operation-level submit / dispatch /
//! wait sequences each framework executes around a task pair
//! (DESIGN.md §4.2). These mirror, op for op, the mechanisms implemented
//! natively in [`crate::runtimes`] and [`crate::relic`].

use super::trace::{flags, Op, PollKind, Trace};

/// Logical address region for runtime-internal state (queues, locks,
/// counters) — distinct from the benchmark data regions.
pub const RT_BASE: u64 = 0x7000_0000;

const Q_HEAD: u64 = RT_BASE; // producer index / deque bottom
const Q_SLOT: u64 = RT_BASE + 0x40; // task slot / descriptor ptr
const Q_TAIL: u64 = RT_BASE + 0x80; // consumer index / deque top
const LOCK: u64 = RT_BASE + 0xC0; // team/deque lock
const DONE_CTR: u64 = RT_BASE + 0x100; // completion counter
const ALLOC: u64 = RT_BASE + 0x1000; // heap area for task descriptors

/// Operation-level model of one runtime's fine-grained task path.
#[derive(Debug, Clone)]
pub struct RuntimeModel {
    pub name: &'static str,
    /// Main thread: ops before making the task visible.
    pub submit: Vec<Op>,
    /// Main thread: ops right after publication (e.g. futex wake).
    pub post_submit: Vec<Op>,
    /// Main thread: poll mechanism while joining.
    pub main_wait: PollKind,
    /// Assistant/worker: idle-poll mechanism while awaiting work.
    pub assistant_wait: PollKind,
    /// Assistant: ops between claiming and running the task.
    pub dispatch: Vec<Op>,
    /// Assistant: ops after the task body (completion bookkeeping).
    pub complete: Vec<Op>,
}

/// Allocation fast path: a tcmalloc/ptmalloc-style bump of a thread
/// cache — `uops` ALU work plus a few metadata touches.
fn alloc_ops(uops: u32, bytes: u64) -> Vec<Op> {
    vec![
        Op::Load(ALLOC),
        Op::Compute(uops),
        Op::Store(ALLOC),
        Op::Store(ALLOC + 0x40),
        Op::Store(ALLOC + 0x40 + bytes / 2),
    ]
}

/// Mutex acquire+release around a short critical section.
fn locked(mut body: Vec<Op>) -> Vec<Op> {
    let mut ops = vec![Op::AtomicRmw(LOCK)];
    ops.append(&mut body);
    ops.push(Op::AtomicRmw(LOCK));
    ops
}

/// Model registry. Names match `crate::runtimes::FRAMEWORK_NAMES` plus
/// `"relic"`.
pub fn model(name: &str) -> Option<RuntimeModel> {
    Some(match name {
        // Relic (§VI): SPSC push = slot store + head store on the
        // producer; pop = slot load + tail store on the consumer. Both
        // sides spin with pause.
        "relic" => RuntimeModel {
            name: "relic",
            submit: vec![
                Op::Load(Q_HEAD),
                Op::Compute(3), // full-check + index arithmetic
                Op::Store(Q_SLOT),
                Op::Store(Q_HEAD),
            ],
            post_submit: vec![],
            main_wait: PollKind::SpinPause,
            assistant_wait: PollKind::SpinPause,
            dispatch: vec![Op::Load(Q_SLOT), Op::Compute(2), Op::Store(Q_TAIL)],
            complete: vec![Op::Store(DONE_CTR), Op::Compute(1)],
        },
        // LLVM OpenMP: task_alloc (descriptor) + locked team deque;
        // worker spins (KMP_BLOCKTIME); taskwait help-polls the locked
        // deque.
        "llvm-openmp" => RuntimeModel {
            name: "llvm-openmp",
            submit: {
                let mut ops = alloc_ops(40, 192);
                ops.extend(locked(vec![
                    Op::Store(Q_SLOT),
                    Op::Store(Q_HEAD),
                    Op::Compute(6),
                ]));
                ops
            },
            post_submit: vec![],
            main_wait: PollKind::LockedPoll,
            assistant_wait: PollKind::LockedPoll,
            dispatch: locked(vec![Op::Load(Q_SLOT), Op::Compute(8), Op::Store(Q_TAIL)]),
            complete: vec![Op::AtomicRmw(DONE_CTR), Op::Compute(4)],
        },
        // GNU OpenMP: team mutex + larger task struct + condvar/futex
        // sleeping worker (the wake latency dominates at µs scale).
        "gnu-openmp" => RuntimeModel {
            name: "gnu-openmp",
            submit: {
                let mut ops = alloc_ops(55, 320);
                ops.extend(locked(vec![
                    Op::Store(Q_SLOT),
                    Op::Store(Q_HEAD),
                    Op::Compute(14), // priority-queue linking
                ]));
                ops
            },
            post_submit: vec![Op::Syscall(500)], // futex wake
            main_wait: PollKind::LockedPoll,
            assistant_wait: PollKind::Park,
            dispatch: locked(vec![Op::Load(Q_SLOT), Op::Compute(12), Op::Store(Q_TAIL)]),
            complete: locked(vec![Op::AtomicRmw(DONE_CTR), Op::Compute(6)]),
        },
        // Intel OpenMP: LLVM mechanism + separate taskdata allocation
        // and bookkeeping stores.
        "intel-openmp" => RuntimeModel {
            name: "intel-openmp",
            submit: {
                let mut ops = alloc_ops(40, 192);
                ops.extend(alloc_ops(30, 256));
                ops.extend(locked(vec![
                    Op::Store(Q_SLOT),
                    Op::Store(Q_HEAD),
                    Op::Compute(10),
                ]));
                ops
            },
            post_submit: vec![],
            main_wait: PollKind::LockedPoll,
            assistant_wait: PollKind::LockedPoll,
            dispatch: locked(vec![Op::Load(Q_SLOT), Op::Compute(48), Op::Store(Q_TAIL)]),
            complete: vec![Op::AtomicRmw(DONE_CTR), Op::Compute(24)],
        },
        // X-OpenMP: lock-less deque — submission is plain stores, but
        // the worker's steal loop CASes the shared top pointer
        // continuously and the owner's pop must CAS too (the SMT-hostile
        // part the paper calls out).
        "x-openmp" => RuntimeModel {
            name: "x-openmp",
            // Owner push is plain stores, but with one stealable task the
            // owner's taskwait-pop and the thief's steal race on the SAME
            // deque-top word every iteration: a SeqCst fence + CAS on the
            // owner side, CAS (with a retry on loss) on the thief side —
            // all on one contended line. This is the SMT-hostile part the
            // paper measures (X-OpenMP below plain LLVM OpenMP, Fig. 1).
            submit: vec![
                Op::Store(Q_SLOT),
                Op::Store(Q_HEAD),
                Op::AtomicRmw(Q_TAIL), // owner pop-side fence+CAS (lost race)
                Op::Compute(4),
            ],
            post_submit: vec![],
            main_wait: PollKind::CasPoll,
            assistant_wait: PollKind::CasPoll,
            dispatch: vec![
                Op::AtomicRmw(Q_TAIL),
                Op::AtomicRmw(Q_TAIL), // retry after racing the owner
                Op::Load(Q_SLOT),
                Op::Compute(4),
            ],
            complete: vec![Op::AtomicRmw(DONE_CTR)],
        },
        // oneTBB: task_group::run allocates, enters the arena, pushes to
        // a locked deque; worker scans with exponential backoff.
        "onetbb" => RuntimeModel {
            name: "onetbb",
            submit: {
                let mut ops = alloc_ops(60, 128);
                // Arena entry, market checks, task_group context and
                // reference counting — oneTBB's fine-grained tax.
                ops.push(Op::Compute(180));
                ops.push(Op::AtomicRmw(ALLOC + 0x300)); // group refcount
                ops.extend(locked(vec![Op::Store(Q_SLOT), Op::Store(Q_HEAD)]));
                ops.push(Op::Load(Q_TAIL)); // waiter check
                ops
            },
            post_submit: vec![],
            main_wait: PollKind::SpinPause,
            assistant_wait: PollKind::Backoff,
            dispatch: {
                let mut ops = locked(vec![Op::Load(Q_SLOT), Op::Compute(16), Op::Store(Q_TAIL)]);
                ops.push(Op::Compute(120)); // arena/task dispatch bookkeeping
                ops
            },
            complete: vec![Op::AtomicRmw(DONE_CTR), Op::AtomicRmw(ALLOC + 0x300), Op::Compute(40)],
        },
        // Taskflow: async task = shared-state allocation (+refcount),
        // notifier two-phase commit on the worker side.
        "taskflow" => RuntimeModel {
            name: "taskflow",
            submit: {
                let mut ops = alloc_ops(70, 160);
                ops.push(Op::Compute(60)); // async-task shared state init
                ops.push(Op::AtomicRmw(ALLOC + 0x200)); // shared-state refcount
                ops.extend(locked(vec![Op::Store(Q_SLOT), Op::Store(Q_HEAD)]));
                ops.push(Op::Load(Q_TAIL)); // notifier waiter count
                ops
            },
            post_submit: vec![],
            main_wait: PollKind::SpinPause,
            assistant_wait: PollKind::HybridPark(16),
            dispatch: locked(vec![Op::Load(Q_SLOT), Op::Compute(10), Op::Store(Q_TAIL)]),
            complete: vec![Op::AtomicRmw(DONE_CTR), Op::AtomicRmw(ALLOC + 0x200)],
        },
        // OpenCilk: spawn is two stores + a fence (THE protocol's
        // work-first fast path); the thief's steal takes the victim
        // deque lock. Sync fast path is one CAS.
        "opencilk" => RuntimeModel {
            name: "opencilk",
            submit: vec![
                Op::Store(Q_SLOT),
                Op::Store(Q_HEAD),
                Op::AtomicRmw(Q_HEAD), // THE fence
                Op::Compute(4),
            ],
            post_submit: vec![],
            main_wait: PollKind::SpinPause,
            assistant_wait: PollKind::LockedPoll,
            dispatch: vec![
                Op::AtomicRmw(LOCK), // victim deque lock
                Op::Load(Q_SLOT),
                Op::AtomicRmw(Q_TAIL),
                Op::Compute(6),
            ],
            complete: vec![Op::AtomicRmw(DONE_CTR), Op::Compute(2)],
        },
        _ => return None,
    })
}

/// All simulator model names, paper figure order + relic.
pub fn model_names() -> [&'static str; 8] {
    [
        "llvm-openmp",
        "gnu-openmp",
        "intel-openmp",
        "x-openmp",
        "onetbb",
        "taskflow",
        "opencilk",
        "relic",
    ]
}

/// Compose the two contexts' programs for one parallel iteration of the
/// paper's benchmark protocol (two identical task instances).
pub fn parallel_programs(
    m: &RuntimeModel,
    task_main: &Trace,
    task_assist: &Trace,
) -> (Vec<Op>, Vec<Op>) {
    let mut main = m.submit.clone();
    main.push(Op::SetFlag(flags::TASK_READY));
    main.extend_from_slice(&m.post_submit);
    main.extend_from_slice(&task_main.ops);
    main.push(Op::WaitFlag(flags::TASK_DONE, m.main_wait));
    main.push(Op::Load(DONE_CTR));

    let mut assist = vec![Op::WaitFlag(flags::TASK_READY, m.assistant_wait)];
    assist.extend_from_slice(&m.dispatch);
    assist.extend_from_slice(&task_assist.ops);
    assist.extend_from_slice(&m.complete);
    assist.push(Op::SetFlag(flags::TASK_DONE));
    (main, assist)
}

/// Serial baseline: both instances back-to-back on context 0, context 1
/// idle (no second thread exists in the paper's serial mode).
pub fn serial_program(task_a: &Trace, task_b: &Trace) -> Vec<Op> {
    let mut ops = Vec::with_capacity(task_a.ops.len() + task_b.ops.len());
    ops.extend_from_slice(&task_a.ops);
    ops.extend_from_slice(&task_b.ops);
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_frameworks_plus_relic() {
        for name in model_names() {
            assert!(model(name).is_some(), "{name} missing");
        }
        assert!(model("serial").is_none());
        assert!(model("bogus").is_none());
    }

    /// Rough cycle weight of an op sequence (atomics/syscalls dominate).
    fn weight(ops: &[Op]) -> u64 {
        ops.iter()
            .map(|op| match op {
                Op::Compute(n) => (*n as u64).div_ceil(4),
                Op::AtomicRmw(_) => 20,
                Op::Syscall(c) => *c as u64,
                _ => 1,
            })
            .sum()
    }

    #[test]
    fn relic_total_overhead_is_cheapest() {
        let total = |m: &RuntimeModel| {
            weight(&m.submit) + weight(&m.post_submit) + weight(&m.dispatch) + weight(&m.complete)
        };
        let relic = total(&model("relic").unwrap());
        for name in model_names() {
            if name == "relic" {
                continue;
            }
            let m = model(name).unwrap();
            assert!(
                total(&m) > relic,
                "{name} overhead {} not above relic {relic}",
                total(&m)
            );
        }
    }

    #[test]
    fn composition_contains_tasks_and_flags() {
        let m = model("relic").unwrap();
        let t = Trace { ops: vec![Op::Compute(7)] };
        let (main, assist) = parallel_programs(&m, &t, &t);
        assert!(main.contains(&Op::SetFlag(flags::TASK_READY)));
        assert!(main.contains(&Op::Compute(7)));
        assert!(assist.contains(&Op::SetFlag(flags::TASK_DONE)));
        assert!(assist.contains(&Op::Compute(7)));
        let serial = serial_program(&t, &t);
        assert_eq!(serial.iter().filter(|o| **o == Op::Compute(7)).count(), 2);
    }

    #[test]
    fn gnu_pays_wake_syscall() {
        let m = model("gnu-openmp").unwrap();
        assert!(m.post_submit.iter().any(|o| matches!(o, Op::Syscall(_))));
        assert_eq!(m.assistant_wait, PollKind::Park);
    }
}
