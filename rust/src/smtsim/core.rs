//! The 2-way SMT core model: two hardware contexts sharing issue
//! bandwidth, the L1d/L2 hierarchy, and front-end recovery — the three
//! first-order SMT contention effects (DESIGN.md §2).
//!
//! Abstraction level: an out-of-order core is modeled at *retire*
//! granularity — independent micro-ops retire up to `issue_width` per
//! cycle (shared between contexts, alternating priority), short L1-hit
//! latencies are mostly hidden (`load_hide_cycles`), cache misses and
//! dependent-chain stalls block their context, branch mispredicts pay a
//! private penalty plus a brief *shared* front-end recovery stall, the
//! `pause` instruction parks its context's issue for `pause_latency`
//! cycles (donating slots to the sibling — exactly why the paper uses
//! it), and parked (futex-waiting) contexts consume nothing until woken.

use super::cache::{CacheConfig, CacheModel};
use super::trace::{flags, Op, PollKind};

/// SMT fetch/issue arbitration between the two contexts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchPolicy {
    /// Alternate which context issues first each cycle.
    RoundRobin,
    /// Priority to the context with fewer issued uops (ICOUNT).
    Icount,
}

/// Core model parameters (defaults ≈ Skylake client, the paper's
/// i7-8700; see EXPERIMENTS.md §Calibration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreConfig {
    /// Retire/issue slots per cycle, shared by both contexts.
    pub issue_width: u32,
    /// Per-context issue cap per cycle (SMT front-end partitioning
    /// keeps one thread from using the full width).
    pub per_thread_issue: u32,
    /// Shared L1 access ports: loads/stores/atomics per cycle, both
    /// contexts combined (the dominant SMT contention point for the
    /// paper's memory-intensive kernels).
    pub mem_ports: u32,
    /// Cycles a load/atomic keeps its L1 port busy (AGU + tag + data
    /// occupancy): >1 makes co-running pointer-chasing kernels contend
    /// on L1 bandwidth, the effect that caps BFS/CC SMT gains.
    pub mem_port_occupancy: u64,
    /// Cycles of a load's latency the OoO window hides.
    pub load_hide_cycles: u64,
    /// Extra latency of a dependent (pointer-chase) load while the
    /// sibling context is active (partitioned load buffers/scheduler).
    pub smt_dep_penalty: u64,
    /// `pause` stall (Skylake: ~140 core cycles / ~40 issue slots; we
    /// model the issue-yield portion).
    pub pause_latency: u64,
    /// Private mispredict recovery.
    pub mispredict_penalty: u64,
    /// Shared front-end stall on any mispredict (both contexts).
    pub flush_shared_cycles: u64,
    /// Mispredict probability of `Branch(false)` ops, per mille.
    pub mispredict_per_mille: u32,
    /// Latency of one step of a dependent FP chain.
    pub fp_latency: u64,
    /// Serialization latency of a lock-prefixed RMW.
    pub atomic_latency: u64,
    /// Extra delay when both contexts RMW the same cache line within
    /// `atomic_window` cycles (line arbitration between pollers and the
    /// lock holder).
    pub atomic_contention_penalty: u64,
    pub atomic_window: u64,
    /// Store-to-load visibility delay between SMT siblings (via L1).
    pub publish_delay: u64,
    /// Futex wake latency: syscall + scheduler + resume.
    pub wake_latency: u64,
    pub fetch: FetchPolicy,
    pub cache: CacheConfig,
    /// Simulated core frequency, used only for µs reporting.
    pub freq_ghz: f64,
}

impl Default for CoreConfig {
    fn default() -> Self {
        CoreConfig {
            issue_width: 3,
            per_thread_issue: 2,
            mem_ports: 1,
            mem_port_occupancy: 1,
            smt_dep_penalty: 5,
            load_hide_cycles: 3,
            pause_latency: 30,
            mispredict_penalty: 14,
            flush_shared_cycles: 1,
            mispredict_per_mille: 350,
            fp_latency: 4,
            atomic_latency: 20,
            atomic_contention_penalty: 25,
            atomic_window: 50,
            publish_delay: 12,
            wake_latency: 5_000,
            fetch: FetchPolicy::RoundRobin,
            cache: CacheConfig::default(),
            freq_ghz: 3.2,
        }
    }
}

/// Per-context execution statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CtxStats {
    pub issued_uops: u64,
    pub mispredicts: u64,
    pub pause_cycles: u64,
    pub park_cycles: u64,
    pub finish_cycle: u64,
}

/// Result of one simulation run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RunResult {
    /// Cycle at which the *last* context finished.
    pub cycles: u64,
    pub ctx: [CtxStats; 2],
    pub l1_misses: u64,
    pub l2_misses: u64,
}

impl RunResult {
    /// Wall time in microseconds at the configured frequency.
    pub fn micros(&self, cfg: &CoreConfig) -> f64 {
        self.cycles as f64 / (cfg.freq_ghz * 1000.0)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum CtxState {
    Ready,
    Parked(u32), // waiting on flag id
    Done,
}

struct Ctx<'a> {
    ops: &'a [Op],
    pc: usize,
    uops_left: u32, // remaining uops of an in-progress Compute
    fp_left: u32,   // remaining uops of an in-progress ComputeFp chain
    blocked_until: u64,
    state: CtxState,
    backoff: u64,        // Backoff poll state
    hybrid_spun: u32,    // HybridPark spin counter
    stats: CtxStats,
    /// Deterministic mispredict thinning accumulator (per mille).
    mp_acc: u32,
}

impl<'a> Ctx<'a> {
    fn new(ops: &'a [Op]) -> Self {
        let state = if ops.is_empty() { CtxState::Done } else { CtxState::Ready };
        Ctx {
            ops,
            pc: 0,
            uops_left: 0,
            fp_left: 0,
            blocked_until: 0,
            state,
            backoff: 1,
            hybrid_spun: 0,
            stats: CtxStats::default(),
            mp_acc: 0,
        }
    }

    fn done(&self) -> bool {
        matches!(self.state, CtxState::Done)
    }

    fn advance(&mut self, cycle: u64) {
        self.pc += 1;
        self.backoff = 1;
        self.hybrid_spun = 0;
        if self.pc >= self.ops.len() {
            self.state = CtxState::Done;
            self.stats.finish_cycle = cycle;
        }
    }
}

/// The simulated 2-context SMT core.
pub struct SmtCore {
    pub cfg: CoreConfig,
    cache: CacheModel,
}

impl SmtCore {
    pub fn new(cfg: CoreConfig) -> Self {
        SmtCore { cache: CacheModel::new(cfg.cache), cfg }
    }

    /// Run both programs to completion from cold caches.
    pub fn run_cold(&mut self, prog0: &[Op], prog1: &[Op]) -> RunResult {
        self.cache.clear();
        self.run_inner(prog0, prog1)
    }

    /// Run with warm caches: one throwaway pass fills the hierarchy,
    /// the second pass is measured — matching the paper's protocol of
    /// averaging 10^5 back-to-back iterations.
    pub fn run_warm(&mut self, prog0: &[Op], prog1: &[Op]) -> RunResult {
        self.cache.clear();
        let _ = self.run_inner(prog0, prog1);
        self.run_inner(prog0, prog1)
    }

    fn run_inner(&mut self, prog0: &[Op], prog1: &[Op]) -> RunResult {
        let mut ctxs = [Ctx::new(prog0), Ctx::new(prog1)];
        let mut flag_visible: [Option<u64>; flags::COUNT] = [None; flags::COUNT];
        let (l1_before, l2_before) = (self.cache.l1_misses, self.cache.l2_misses);
        let mut cycle: u64 = 0;
        // Shared front-end recovery: no context issues before this cycle.
        let mut frontend_stall_until: u64 = 0;
        // Last lock-prefixed access per context: (line, cycle).
        let mut last_rmw: [(u64, u64); 2] = [(u64::MAX, 0); 2];
        // Shared L1 port occupancy (cycle each port frees up).
        let mut ports: Vec<u64> = vec![0; self.cfg.mem_ports as usize];
        const MAX_CYCLES: u64 = 200_000_000;

        while !(ctxs[0].done() && ctxs[1].done()) {
            assert!(cycle < MAX_CYCLES, "smtsim deadlock: pc0={} pc1={}", ctxs[0].pc, ctxs[1].pc);

            // Wake parked contexts whose flag became visible.
            for ctx in ctxs.iter_mut() {
                if let CtxState::Parked(f) = ctx.state {
                    if flag_visible[f as usize].is_some_and(|t| t <= cycle) {
                        ctx.state = CtxState::Ready;
                        ctx.blocked_until = cycle + self.cfg.wake_latency;
                    } else {
                        ctx.stats.park_cycles += 1;
                    }
                }
            }

            let mut issued_any = false;
            if cycle >= frontend_stall_until {
                let mut slots = self.cfg.issue_width;
                let order = match self.cfg.fetch {
                    FetchPolicy::RoundRobin => {
                        if cycle % 2 == 0 { [0usize, 1] } else { [1, 0] }
                    }
                    FetchPolicy::Icount => {
                        if ctxs[0].stats.issued_uops <= ctxs[1].stats.issued_uops {
                            [0, 1]
                        } else {
                            [1, 0]
                        }
                    }
                };
                for &i in &order {
                    let mut budget = self.cfg.per_thread_issue.min(slots);
                    while budget > 0 && slots > 0 {
                        let issued = self.step(
                            &mut ctxs,
                            i,
                            cycle,
                            &mut flag_visible,
                            &mut frontend_stall_until,
                            &mut ports,
                            &mut last_rmw,
                        );
                        if !issued {
                            break;
                        }
                        issued_any = true;
                        budget -= 1;
                        slots -= 1;
                    }
                }
            }
            if issued_any {
                cycle += 1;
                continue;
            }
            // Idle fast-forward: nothing issued this cycle; jump to the
            // next event (unblock, front-end recovery, flag visibility)
            // instead of stepping cycle-by-cycle through long stalls.
            let mut next = u64::MAX;
            for ctx in &ctxs {
                match ctx.state {
                    CtxState::Ready if ctx.blocked_until > cycle => {
                        next = next.min(ctx.blocked_until);
                    }
                    CtxState::Parked(f) => {
                        if let Some(t) = flag_visible[f as usize] {
                            next = next.min(t.max(cycle + 1));
                        }
                    }
                    _ => {}
                }
            }
            if frontend_stall_until > cycle {
                next = next.min(frontend_stall_until);
            }
            let jump = if next == u64::MAX { cycle + 1 } else { next.max(cycle + 1) };
            // Account parked time skipped by the jump.
            for ctx in ctxs.iter_mut() {
                if matches!(ctx.state, CtxState::Parked(_)) {
                    ctx.stats.park_cycles += jump - cycle - 1;
                }
            }
            cycle = jump;
        }

        RunResult {
            cycles: ctxs[0].stats.finish_cycle.max(ctxs[1].stats.finish_cycle),
            ctx: [ctxs[0].stats, ctxs[1].stats],
            l1_misses: self.cache.l1_misses - l1_before,
            l2_misses: self.cache.l2_misses - l2_before,
        }
    }

    /// Try to issue one uop for context `i`; returns whether a slot was
    /// consumed.
    fn step(
        &mut self,
        ctxs: &mut [Ctx; 2],
        i: usize,
        cycle: u64,
        flag_visible: &mut [Option<u64>; flags::COUNT],
        frontend_stall_until: &mut u64,
        ports: &mut [u64],
        last_rmw: &mut [(u64, u64); 2],
    ) -> bool {
        let cfg = self.cfg;
        let ctxs_other_state = ctxs[1 - i].state;
        let ctx = &mut ctxs[i];
        if ctx.done() || ctx.blocked_until > cycle || !matches!(ctx.state, CtxState::Ready) {
            if matches!(ctx.state, CtxState::Ready) && ctx.blocked_until > cycle {
                ctx.stats.pause_cycles += 0; // blocked, not pause-specific
            }
            return false;
        }

        // Continue an in-progress Compute burst.
        if ctx.uops_left > 0 {
            ctx.uops_left -= 1;
            ctx.stats.issued_uops += 1;
            if ctx.uops_left == 0 {
                ctx.advance(cycle);
            }
            return true;
        }
        // Continue an in-progress FP chain (one uop per fp_latency).
        if ctx.fp_left > 0 {
            ctx.fp_left -= 1;
            ctx.stats.issued_uops += 1;
            ctx.blocked_until = cycle + cfg.fp_latency;
            if ctx.fp_left == 0 {
                ctx.advance(cycle);
            }
            return true;
        }

        let op = ctx.ops[ctx.pc];
        match op {
            Op::Compute(n) => {
                if n == 0 {
                    ctx.advance(cycle);
                    return false;
                }
                ctx.stats.issued_uops += 1;
                if n == 1 {
                    ctx.advance(cycle);
                } else {
                    ctx.uops_left = n - 1;
                }
                true
            }
            Op::ComputeFp(n) => {
                if n == 0 {
                    ctx.advance(cycle);
                    return false;
                }
                // Dependent chain: one uop per fp_latency cycles.
                ctx.stats.issued_uops += 1;
                ctx.blocked_until = cycle + cfg.fp_latency;
                if n == 1 {
                    ctx.advance(cycle);
                } else {
                    ctx.fp_left = n - 1;
                }
                true
            }
            Op::Load(addr) => {
                let Some(port) = ports.iter_mut().find(|p| **p <= cycle) else {
                    return false;
                };
                *port = cycle + cfg.mem_port_occupancy;
                let lat = self.cache.access(addr);
                let exposed = lat.saturating_sub(cfg.load_hide_cycles);
                ctx.stats.issued_uops += 1;
                ctx.blocked_until = cycle + exposed;
                ctx.advance(cycle);
                true
            }
            Op::LoadDep(addr) => {
                let Some(port) = ports.iter_mut().find(|p| **p <= cycle) else {
                    return false;
                };
                *port = cycle + cfg.mem_port_occupancy;
                // Full latency exposed (the chain cannot be hidden), plus
                // the SMT partitioning penalty while the sibling runs.
                let lat = self.cache.access(addr);
                let sibling_active = !matches!(
                    ctxs_other_state,
                    CtxState::Done | CtxState::Parked(_)
                );
                let penalty = if sibling_active { cfg.smt_dep_penalty } else { 0 };
                ctx.stats.issued_uops += 1;
                ctx.blocked_until = cycle + lat + penalty;
                ctx.advance(cycle);
                true
            }
            Op::Store(addr) => {
                // Stores retire through the store buffer: they need a
                // port slot but only for one cycle.
                let Some(port) = ports.iter_mut().find(|p| **p <= cycle) else {
                    return false;
                };
                *port = cycle + 1;
                // Store buffer: no stall; still moves the line for state.
                let _ = self.cache.access(addr);
                ctx.stats.issued_uops += 1;
                ctx.advance(cycle);
                true
            }
            Op::Branch(predictable) => {
                ctx.stats.issued_uops += 1;
                // Deterministic thinning: exactly `mispredict_per_mille`
                // of unpredictable branches mispredict, independent of
                // trace position (keeps serial vs parallel comparable).
                let mispredicted = !predictable && {
                    ctx.mp_acc += cfg.mispredict_per_mille;
                    if ctx.mp_acc >= 1000 {
                        ctx.mp_acc -= 1000;
                        true
                    } else {
                        false
                    }
                };
                if mispredicted {
                    ctx.stats.mispredicts += 1;
                    ctx.blocked_until = cycle + cfg.mispredict_penalty;
                    // Flush recovery briefly occupies the shared front-end.
                    *frontend_stall_until =
                        (*frontend_stall_until).max(cycle + cfg.flush_shared_cycles);
                }
                ctx.advance(cycle);
                true
            }
            Op::AtomicRmw(addr) => {
                let Some(port) = ports.iter_mut().find(|p| **p <= cycle) else {
                    return false;
                };
                *port = cycle + cfg.mem_port_occupancy;
                let lat = self.cache.access(addr);
                let line = addr & !63;
                // Line arbitration against the sibling's recent RMW.
                let other = last_rmw[1 - i];
                let contended = other.0 == line
                    && cycle.saturating_sub(other.1) < cfg.atomic_window;
                last_rmw[i] = (line, cycle);
                let extra = if contended { cfg.atomic_contention_penalty } else { 0 };
                ctx.stats.issued_uops += 1;
                ctx.blocked_until = cycle
                    + cfg.atomic_latency
                    + extra
                    + lat.saturating_sub(cfg.cache.l1_latency);
                ctx.advance(cycle);
                true
            }
            Op::Pause => {
                ctx.stats.issued_uops += 1;
                ctx.stats.pause_cycles += cfg.pause_latency;
                ctx.blocked_until = cycle + cfg.pause_latency;
                ctx.advance(cycle);
                true
            }
            Op::SetFlag(f) => {
                flag_visible[f as usize] = Some(cycle + cfg.publish_delay);
                ctx.stats.issued_uops += 1;
                ctx.advance(cycle);
                true
            }
            Op::Syscall(c) => {
                ctx.stats.issued_uops += 1;
                ctx.blocked_until = cycle + c as u64;
                ctx.advance(cycle);
                true
            }
            Op::WaitFlag(f, kind) => {
                if flag_visible[f as usize].is_some_and(|t| t <= cycle) {
                    ctx.stats.issued_uops += 1;
                    ctx.advance(cycle);
                    return true;
                }
                // Not yet visible: perform one poll step.
                match kind {
                    PollKind::Spin => {
                        // load + cmp + jmp every poll: hogs a slot.
                        ctx.stats.issued_uops += 1;
                        true
                    }
                    PollKind::SpinPause => {
                        ctx.stats.issued_uops += 1;
                        ctx.stats.pause_cycles += cfg.pause_latency;
                        ctx.blocked_until = cycle + cfg.pause_latency;
                        true
                    }
                    PollKind::CasPoll => {
                        let Some(port) = ports.iter_mut().find(|p| **p <= cycle) else {
                            return false;
                        };
                        *port = cycle + cfg.mem_port_occupancy;
                        ctx.stats.issued_uops += 1;
                        ctx.blocked_until = cycle + cfg.atomic_latency;
                        true
                    }
                    PollKind::LockedPoll => {
                        let Some(port) = ports.iter_mut().find(|p| **p <= cycle) else {
                            return false;
                        };
                        *port = cycle + cfg.mem_port_occupancy;
                        ctx.stats.issued_uops += 1;
                        ctx.blocked_until = cycle + 2 * cfg.atomic_latency;
                        true
                    }
                    PollKind::Backoff => {
                        ctx.stats.issued_uops += 1;
                        ctx.blocked_until = cycle + ctx.backoff * cfg.pause_latency;
                        ctx.backoff = (ctx.backoff * 2).min(32);
                        true
                    }
                    PollKind::HybridPark(spins) => {
                        if ctx.hybrid_spun < spins {
                            ctx.hybrid_spun += 1;
                            ctx.stats.issued_uops += 1;
                            ctx.blocked_until = cycle + cfg.pause_latency;
                            true
                        } else {
                            ctx.state = CtxState::Parked(f);
                            false
                        }
                    }
                    PollKind::Park => {
                        ctx.state = CtxState::Parked(f);
                        false
                    }
                }
            }
        }
    }

    /// Access to cumulative cache statistics.
    pub fn cache_stats(&self) -> (u64, u64, u64) {
        (self.cache.accesses, self.cache.l1_misses, self.cache.l2_misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CoreConfig {
        CoreConfig::default()
    }

    #[test]
    fn empty_programs_finish_immediately() {
        let mut core = SmtCore::new(cfg());
        let r = core.run_cold(&[], &[]);
        assert_eq!(r.cycles, 0);
    }

    #[test]
    fn compute_throughput_single_context() {
        // 4000 independent uops, one context capped at 2/cycle: ~2000 cycles.
        let mut core = SmtCore::new(cfg());
        let prog = vec![Op::Compute(4000)];
        let r = core.run_cold(&prog, &[]);
        assert!((1900..2200).contains(&r.cycles), "cycles={}", r.cycles);
    }

    #[test]
    fn two_compute_contexts_share_width() {
        // Two contexts of 4000 uops each share width 3 (2 per thread):
        // ~2700 cycles total — pure-ALU code gains only 3/2 from SMT.
        let mut core = SmtCore::new(cfg());
        let prog = vec![Op::Compute(4000)];
        let r = core.run_cold(&prog, &prog);
        assert!((2600..3000).contains(&r.cycles), "cycles={}", r.cycles);
        // Fairness: both contexts issued the same amount.
        assert_eq!(r.ctx[0].issued_uops, r.ctx[1].issued_uops);
    }

    #[test]
    fn stall_heavy_contexts_overlap() {
        // Loads with cold misses stall; two stall-heavy contexts should
        // co-run far better than 2x serial (the SMT premise).
        let mk = |base: u64| -> Vec<Op> {
            (0..500)
                .map(|i| Op::Load(base + i * 128)) // new line every load
                .collect()
        };
        let mut core = SmtCore::new(cfg());
        let solo = core.run_cold(&mk(0), &[]).cycles;
        let both = core.run_cold(&mk(0), &mk(0x4000_0000)).cycles;
        assert!(
            (both as f64) < 1.4 * solo as f64,
            "SMT overlap missing: solo={solo} both={both}"
        );
    }

    #[test]
    fn pause_donates_slots_to_sibling() {
        // ctx1 spins (Spin) vs pauses (SpinPause) while ctx0 computes;
        // ctx0 must finish faster against a pausing sibling.
        let work = vec![Op::Compute(8000), Op::SetFlag(flags::TASK_READY)];
        let waiter = |kind| vec![Op::WaitFlag(flags::TASK_READY, kind)];
        let mut core = SmtCore::new(cfg());
        let vs_spin = core.run_cold(&work, &waiter(PollKind::Spin)).ctx[0].finish_cycle;
        let vs_pause =
            core.run_cold(&work, &waiter(PollKind::SpinPause)).ctx[0].finish_cycle;
        assert!(
            vs_pause < vs_spin,
            "pause must help the sibling: spin={vs_spin} pause={vs_pause}"
        );
    }

    #[test]
    fn parked_context_costs_wake_latency() {
        let c = cfg();
        let producer = vec![Op::SetFlag(flags::TASK_READY)];
        let parker = vec![Op::WaitFlag(flags::TASK_READY, PollKind::Park), Op::Compute(1)];
        let mut core = SmtCore::new(c);
        let r = core.run_cold(&producer, &parker);
        assert!(
            r.cycles >= c.wake_latency,
            "wake latency unpaid: {}",
            r.cycles
        );
        assert!(r.ctx[1].park_cycles > 0);
    }

    #[test]
    fn spinpause_wait_is_fast() {
        let c = cfg();
        let producer = vec![Op::Compute(100), Op::SetFlag(flags::TASK_READY)];
        let spinner =
            vec![Op::WaitFlag(flags::TASK_READY, PollKind::SpinPause), Op::Compute(1)];
        let mut core = SmtCore::new(c);
        let r = core.run_cold(&producer, &spinner);
        assert!(
            r.cycles < 200,
            "spin wait should react in ~pause+publish cycles: {}",
            r.cycles
        );
    }

    #[test]
    fn warm_run_not_slower_than_cold() {
        let prog: Vec<Op> = (0..200).map(|i| Op::Load(i * 64)).collect();
        let mut core = SmtCore::new(cfg());
        let cold = core.run_cold(&prog, &[]).cycles;
        let warm = core.run_warm(&prog, &[]).cycles;
        assert!(warm <= cold, "cold={cold} warm={warm}");
    }

    #[test]
    fn deterministic_across_runs() {
        let prog: Vec<Op> = (0..300)
            .flat_map(|i| [Op::Load(i * 72), Op::Branch(false), Op::Compute(3)])
            .collect();
        let r1 = SmtCore::new(cfg()).run_warm(&prog, &prog);
        let r2 = SmtCore::new(cfg()).run_warm(&prog, &prog);
        assert_eq!(r1, r2);
    }

    #[test]
    fn icount_policy_runs() {
        let mut c = cfg();
        c.fetch = FetchPolicy::Icount;
        let prog = vec![Op::Compute(1000)];
        let r = SmtCore::new(c).run_cold(&prog, &prog);
        assert!(r.cycles >= 450);
    }
}
