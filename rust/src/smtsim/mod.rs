//! `smtsim` — a cycle-approximate simulator of one 2-way SMT x86 core.
//!
//! This is the hardware substitution that makes the paper's evaluation
//! reproducible on hosts without SMT (DESIGN.md §2): the benchmark
//! kernels record operation traces through [`TraceProbe`] (the *same*
//! code path as the native kernels — see [`crate::probe`]), runtime
//! overhead models ([`overhead`]) inject each framework's submit /
//! dispatch / wait operations, and [`SmtCore`] replays two contexts
//! against shared issue bandwidth, a shared L1d/L2 hierarchy, shared
//! front-end recovery, `pause` semantics, and an OS futex wake model.
//!
//! ```
//! use relic_smt::smtsim::{self, CoreConfig, TraceProbe};
//! use relic_smt::graph::{kronecker::paper_graph, tc};
//!
//! let g = paper_graph();
//! let task = |i| {
//!     let mut p = TraceProbe::with_offset(i);
//!     tc::triangle_count(&g, &mut p);
//!     p.finish()
//! };
//! let s = smtsim::speedup("relic", &task(0), &task(1), &CoreConfig::default());
//! assert!(s > 1.0, "Relic should accelerate TC: {s}");
//! ```

pub mod cache;
pub mod core;
pub mod overhead;
pub mod trace;

pub use cache::{CacheConfig, CacheModel};
pub use core::{CoreConfig, CtxStats, FetchPolicy, RunResult, SmtCore};
pub use overhead::{model, model_names, parallel_programs, serial_program, RuntimeModel};
pub use trace::{flags, Op, PollKind, Trace, TraceProbe};

/// Simulated cycles for one *serial* iteration (two instances
/// back-to-back on one context, warm caches).
pub fn serial_cycles(task_a: &Trace, task_b: &Trace, cfg: &CoreConfig) -> u64 {
    let prog = serial_program(task_a, task_b);
    SmtCore::new(*cfg).run_warm(&prog, &[]).cycles
}

/// Simulated cycles for one *parallel* iteration under `runtime`
/// (framework name or `"relic"`), warm caches.
pub fn parallel_cycles(
    runtime: &str,
    task_a: &Trace,
    task_b: &Trace,
    cfg: &CoreConfig,
) -> Option<u64> {
    let m = model(runtime)?;
    let (main, assist) = parallel_programs(&m, task_a, task_b);
    Some(SmtCore::new(*cfg).run_warm(&main, &assist).cycles)
}

/// Speedup of `runtime` over serial execution for a pair of task
/// instances — the quantity plotted in the paper's Figures 1 and 3.
/// `"serial"` returns exactly 1.0.
pub fn speedup(runtime: &str, task_a: &Trace, task_b: &Trace, cfg: &CoreConfig) -> f64 {
    if runtime == "serial" {
        return 1.0;
    }
    let serial = serial_cycles(task_a, task_b, cfg) as f64;
    let par = parallel_cycles(runtime, task_a, task_b, cfg)
        .unwrap_or_else(|| panic!("unknown runtime {runtime}")) as f64;
    serial / par
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{kronecker::paper_graph, pr, tc};

    fn trace_tc(instance: u64) -> Trace {
        let g = paper_graph();
        let mut p = TraceProbe::with_offset(instance);
        tc::triangle_count(&g, &mut p);
        p.finish()
    }

    fn trace_pr(instance: u64) -> Trace {
        let g = paper_graph();
        let mut p = TraceProbe::with_offset(instance);
        pr::pagerank(&g, pr::MAX_ITERS, pr::TOLERANCE, &mut p);
        p.finish()
    }

    #[test]
    fn serial_is_unity() {
        let cfg = CoreConfig::default();
        assert_eq!(speedup("serial", &trace_tc(0), &trace_tc(1), &cfg), 1.0);
    }

    #[test]
    fn relic_beats_every_framework_on_fine_tasks() {
        // The paper's headline: on µs-scale tasks Relic's overhead
        // advantage dominates (Fig. 3 vs Fig. 1).
        let cfg = CoreConfig::default();
        let (a, b) = (trace_tc(0), trace_tc(1));
        let relic = speedup("relic", &a, &b, &cfg);
        for name in model_names() {
            if name == "relic" {
                continue;
            }
            let s = speedup(name, &a, &b, &cfg);
            assert!(
                relic >= s,
                "relic ({relic:.3}) must beat {name} ({s:.3}) on TC"
            );
        }
    }

    #[test]
    fn gnu_openmp_loses_on_fine_tasks_wins_less_on_coarse() {
        // Futex wake latency swamps a ~1.3 µs task but amortizes over
        // the 4.3 µs PageRank task (GNU even wins PR in the paper's
        // Fig. 1). Uses granularity-calibrated traces.
        let cfg = CoreConfig::default();
        let tc = crate::bench::Workload::new("tc");
        let pr = crate::bench::Workload::new("pr");
        let fine = speedup("gnu-openmp", &tc.trace(0, &cfg), &tc.trace(1, &cfg), &cfg);
        let coarse = speedup("gnu-openmp", &pr.trace(0, &cfg), &pr.trace(1, &cfg), &cfg);
        assert!(fine < 1.0, "gnu on ~1.3µs task should degrade: {fine:.3}");
        assert!(coarse > fine, "coarse {coarse:.3} !> fine {fine:.3}");
        assert!(coarse > 1.0, "gnu should still win coarse PR: {coarse:.3}");
    }

    #[test]
    fn deterministic_speedups() {
        let cfg = CoreConfig::default();
        let s1 = speedup("llvm-openmp", &trace_tc(0), &trace_tc(1), &cfg);
        let s2 = speedup("llvm-openmp", &trace_tc(0), &trace_tc(1), &cfg);
        assert_eq!(s1, s2);
    }

    #[test]
    fn speedups_bounded_by_two() {
        let cfg = CoreConfig::default();
        for name in model_names() {
            let s = speedup(name, &trace_pr(0), &trace_pr(1), &cfg);
            assert!(s < 2.0, "{name} speedup {s:.3} exceeds the 2-task bound");
            assert!(s > 0.1, "{name} speedup {s:.3} implausibly low");
        }
    }
}
