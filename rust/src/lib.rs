//! # relic-smt — fine-grained task parallelism on SMT cores
//!
//! A reproduction of *"Exploring Fine-grained Task Parallelism on
//! Simultaneous Multithreading Cores"* (Los & Petushkov, 2024) as a
//! complete system:
//!
//! * [`relic`] — the paper's contribution: a specialized software-only
//!   task-parallel framework for one 2-way SMT core (main/assistant
//!   threads, lock-free SPSC queue, busy-waiting with `pause`,
//!   `wake_up_hint`/`sleep_hint`).
//! * [`runtimes`] — models of the seven baseline frameworks the paper
//!   compares against (LLVM/GNU/Intel/X-OpenMP, oneTBB, Taskflow,
//!   OpenCilk), behind one [`runtimes::TaskRuntime`] interface.
//! * [`graph`] — the GAP benchmark substrate: CSR graphs, a Kronecker
//!   generator, and the six GAP kernels (BC, BFS, CC, PR, SSSP, TC).
//! * [`json`] — the RapidJSON-substitute parser used by the JSON
//!   benchmark.
//! * [`smtsim`] — the hardware substitution (DESIGN.md §2): a
//!   cycle-approximate simulator of a 2-way SMT x86 core used to
//!   regenerate the paper's figures deterministically on non-SMT hosts.
//! * [`bench`] — the experiment harness regenerating Figures 1/3/4 and
//!   the §IV granularity table, in both simulator and wall-clock modes.
//! * [`runtime`] — PJRT client wrapper executing the AOT-compiled JAX /
//!   Pallas graph kernels (`artifacts/*.hlo.txt`).
//! * [`coordinator`] — the hybrid analytics service: coarse graph
//!   analytics offloaded to PJRT executables, fine-grained subtasks run
//!   through Relic, as motivated in the paper's §VI-A; its
//!   [`coordinator::Engine`] scales the service across every physical
//!   core via a [`relic::RelicPool`] of pinned pair-shards, behind a
//!   deadline-aware admission gate ([`coordinator::admission`]:
//!   non-blocking and parked submits, counted work shedding). The
//!   engine is *self-measuring*: each shard maintains a
//!   per-kernel-class service-time EMA
//!   ([`metrics::ServiceEstimator`]) that drives least-estimated-wait
//!   routing, and can serve deadline-carrying requests
//!   earliest-deadline-first within each batch
//!   ([`coordinator::edf_order`]).
//!
//! **Start with `ARCHITECTURE.md`** (repo root) for the module map,
//! the request lifecycle from `submit` to `record_completion`, and the
//! three invariants every PR must preserve (per-shard FIFO among
//! equals, no drop after accept, bitwise-deterministic checksums).
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod json;
pub mod metrics;
pub mod probe;
pub mod relic;
pub mod runtime;
pub mod runtimes;
pub mod smtsim;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
