//! Data-parallel helpers over [`Relic::scope`]: the `Par` toggle the
//! GAP kernels and the JSON parser take to run their hot loops on both
//! logical threads of the SMT pair.
//!
//! [`Par`] is deliberately an enum, not a trait object: kernels accept
//! `&Par` and stay monomorphic, `Par::Serial` compiles to the plain
//! loop, and `Par::Relic` routes chunks through the fork-join scope.
//! A [`Schedule`] decides how chunks are *assigned* to the pair —
//! statically (PR 1), self-scheduled from a shared cursor, or
//! self-scheduled over work-balanced boundaries — without changing what
//! any chunk computes. All helpers are *deterministic by construction*
//! where the paper's checksums require it:
//!
//! * [`Par::map_into`] writes disjoint slice elements — bitwise equal to
//!   the serial loop regardless of scheduling;
//! * [`Par::reduce`] combines per-chunk partials in ascending chunk
//!   order — exact for integer monoids (the checksum kind), and
//!   fixed-shape (chunk boundaries depend only on the range, grain and
//!   schedule, never on timing) for floats;
//! * [`Par::chunk_map`] concatenates per-chunk outputs in chunk order.
//!
//! Every helper runs serially — without even entering a scope — when
//! the range fits a single grain: a 4-element loop should not pay the
//! submit/wait handshake.
//!
//! [`Par::Cross`] (built only inside [`super::cross::with_lease`])
//! widens the same helpers into a *two-level* fork-join: the loop is
//! first carved at deterministic shard-level boundaries, then this
//! pair and every borrowed pair-shard claim those chunks from a shared
//! cursor and run each one through the ordinary pair-level protocol.
//! Determinism is unchanged — boundaries stay a pure function of
//! `(range, grain, schedule)` and partials still fold in ascending
//! chunk order, so which shard ran a chunk never shows in the result.
//!
//! ```
//! use relic_smt::relic::{Par, Relic, Schedule};
//!
//! let relic = Relic::new();
//! let par = Par::Relic(&relic);
//! let mut squares = vec![0u64; 100];
//! par.map_into(&mut squares, 8, |i| (i * i) as u64);
//! assert_eq!(squares[7], 49);
//! let total = par.reduce(0..100, 8, 0u64, |i| i as u64, |a, b| a + b);
//! assert_eq!(total, 99 * 100 / 2);
//! // Opt a loop into self-scheduling (same result, balanced work):
//! let dynamic = par.with_schedule(Schedule::Dynamic);
//! assert_eq!(dynamic.reduce(0..100, 8, 0u64, |i| i as u64, |a, b| a + b), total);
//! // The parallel_for convenience on the runtime itself:
//! use std::sync::atomic::{AtomicU64, Ordering};
//! let n = AtomicU64::new(0);
//! relic.parallel_for(0..1000, 64, |_i| {
//!     n.fetch_add(1, Ordering::Relaxed);
//! });
//! assert_eq!(n.load(Ordering::Relaxed), 1000);
//! ```

use std::ops::Range;

use super::cross::{bounds_by, cross_chunk_count, even_bounds, CrossSession, MAX_CROSS_CHUNKS};
use super::framework::Relic;
use super::scope::{dyn_chunk_count, MAX_CHUNK_SLOTS};

/// Default minimum indices per chunk: with the paper's ~0.1 µs/iteration
/// kernel loops this keeps every chunk well above Relic's ~70 ns
/// submit+dispatch cost.
pub const DEFAULT_GRAIN: usize = 16;

/// How a parallel loop is chunked: a plain minimum chunk size, or a
/// chunk size plus *work-balanced* boundaries.
///
/// Every [`Par`] entry point takes `impl Into<Grain>`, so ordinary call
/// sites keep passing a bare `usize` and only the kernels that own a
/// CSR work profile spell out [`Grain::Bounded`] — this replaced the
/// duplicated `_by` helper variants (ISSUE 9).
///
/// ```
/// use relic_smt::relic::{Grain, Par, Relic, Schedule};
///
/// let relic = Relic::new();
/// let par = Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced);
/// let n = 500;
/// // Quadratically skewed boundaries stand in for a CSR bisection:
/// let bound = |i: usize, k: usize| n * i * i / (k * k);
/// let balanced = par.reduce(0..n, Grain::Bounded(8, &bound), 0u64, |i| i as u64, |a, b| a + b);
/// let plain = par.reduce(0..n, 8, 0u64, |i| i as u64, |a, b| a + b);
/// assert_eq!(balanced, plain, "boundaries change assignment, never the result");
/// ```
#[derive(Clone, Copy)]
pub enum Grain<'b> {
    /// At least this many indices per chunk; boundaries are even splits
    /// of the index range. Under [`Schedule::EdgeBalanced`] a loop with
    /// no work information falls back to [`Schedule::Dynamic`] — the
    /// substitution is counted in
    /// [`RelicStats::schedule_downgrades`](crate::relic::RelicStats::schedule_downgrades).
    Elems(usize),
    /// A minimum chunk size plus work-balanced boundaries: under
    /// [`Schedule::EdgeBalanced`], chunk `i` of `k` covers
    /// `bound(i, k)..bound(i + 1, k)` (monotone; typically a CSR
    /// bisection like [`crate::graph::CsrGraph::edge_balanced_boundary`]).
    /// Other schedules use the chunk size and ignore the boundaries.
    Bounded(usize, &'b dyn Fn(usize, usize) -> usize),
}

impl From<usize> for Grain<'static> {
    fn from(elems: usize) -> Self {
        Grain::Elems(elems)
    }
}

impl<'b> Grain<'b> {
    /// The minimum indices per chunk, whichever variant carries it.
    pub fn size(&self) -> usize {
        match self {
            Grain::Elems(g) | Grain::Bounded(g, _) => *g,
        }
    }
}

/// How a `Par::Relic` loop's chunks are assigned to the SMT pair.
///
/// # Example
///
/// Schedules round-trip through their CLI/config spelling and attach
/// to a [`Par`] per loop:
///
/// ```
/// use relic_smt::relic::Schedule;
///
/// let s = Schedule::parse("edge-balanced").unwrap();
/// assert_eq!(s, Schedule::EdgeBalanced);
/// assert_eq!(Schedule::parse(s.name()), Some(s), "name round-trips");
/// assert_eq!(Schedule::parse("nope"), None);
/// assert_eq!(Schedule::default(), Schedule::Static, "PR 1's partition is the default");
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Schedule {
    /// PR 1's static partition: a main-thread half plus ≤8 assistant
    /// chunks. Lowest overhead (one join per split); imbalances on
    /// skewed inputs where one half holds the hub vertices.
    #[default]
    Static,
    /// Self-scheduled: chunk boundaries are still a pure function of
    /// `(range, grain)`, but assignment is claimed from a shared atomic
    /// cursor by whichever thread is free
    /// ([`crate::relic::Scope::split_dynamic`]).
    Dynamic,
    /// [`Schedule::Dynamic`] claiming over *work-balanced* boundaries —
    /// e.g. equal edge counts bisected from the CSR offsets array.
    /// Loops without weight information ([`Grain::Elems`] call sites)
    /// fall back to `Dynamic`; the substitution is recorded in
    /// [`RelicStats::schedule_downgrades`](crate::relic::RelicStats::schedule_downgrades).
    EdgeBalanced,
}

impl Schedule {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<Schedule> {
        Some(match s {
            "static" => Schedule::Static,
            "dynamic" => Schedule::Dynamic,
            "edge" | "edge-balanced" | "edgebalanced" => Schedule::EdgeBalanced,
            _ => return None,
        })
    }

    /// Canonical display name (round-trips through [`parse`](Self::parse)).
    pub fn name(self) -> &'static str {
        match self {
            Schedule::Static => "static",
            Schedule::Dynamic => "dynamic",
            Schedule::EdgeBalanced => "edge-balanced",
        }
    }

    /// All schedules, in ablation order.
    pub fn all() -> [Schedule; 3] {
        [Schedule::Static, Schedule::Dynamic, Schedule::EdgeBalanced]
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// How a kernel's internal loops execute.
///
/// # Example
///
/// The same loop body, serial and forked over the SMT pair, produces
/// bitwise-identical output — the determinism the paper's checksums
/// rest on:
///
/// ```
/// use relic_smt::relic::{Par, Relic, Schedule};
///
/// let body = |i: usize| (i * i) as u64;
/// let mut serial = vec![0u64; 64];
/// Par::Serial.map_into(&mut serial, 8, body);
///
/// let relic = Relic::new();
/// let mut forked = vec![0u64; 64];
/// Par::Relic(&relic).with_schedule(Schedule::Dynamic).map_into(&mut forked, 8, body);
///
/// assert_eq!(serial, forked);
/// assert!(!Par::Serial.is_parallel());
/// assert!(Par::Relic(&relic).is_parallel());
/// ```
#[derive(Clone, Copy)]
pub enum Par<'r> {
    /// Plain serial loops on the calling thread (the baseline).
    Serial,
    /// Fork-join over the SMT pair through a [`Relic`] runtime, using
    /// the runtime's configured default [`Schedule`].
    Relic(&'r Relic),
    /// Fork-join with an explicit per-loop schedule (built by
    /// [`Par::with_schedule`]; overrides the runtime default).
    Scheduled(&'r Relic, Schedule),
    /// Hierarchical two-level fork-join: loops big enough to split are
    /// carved at shard-level boundaries and claimed by this pair *and*
    /// every borrowed pair-shard attached to the
    /// [`CrossSession`](super::cross::CrossSession) (built only by
    /// [`super::cross::with_lease`]). Loops that don't split fall back
    /// to the plain pair path under the carried [`Schedule`].
    Cross(&'r Relic, Schedule, &'r CrossSession<'r>),
}

/// Raw slice base pointer that may cross to the assistant thread.
/// Soundness rests on the chunk disjointness the scope splitters
/// guarantee: no element is touched by more than one chunk at a time.
struct RawSlice<T>(*mut T);

impl<T> Clone for RawSlice<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for RawSlice<T> {}

// SAFETY: only ever used to access disjoint elements from the two
// threads of one scope; T itself crosses threads, hence T: Send.
unsafe impl<T: Send> Send for RawSlice<T> {}
unsafe impl<T: Send> Sync for RawSlice<T> {}

impl<'r> Par<'r> {
    /// Build from an optional runtime reference.
    pub fn from_relic(relic: Option<&'r Relic>) -> Self {
        match relic {
            Some(r) => Par::Relic(r),
            None => Par::Serial,
        }
    }

    /// True when loops actually fan out to the assistant.
    pub fn is_parallel(&self) -> bool {
        !matches!(self, Par::Serial)
    }

    /// This `Par` with an explicit chunk-assignment schedule. Serial
    /// stays serial — the schedule only governs parallel execution.
    pub fn with_schedule(self, schedule: Schedule) -> Par<'r> {
        match self {
            Par::Serial => Par::Serial,
            Par::Relic(r) | Par::Scheduled(r, _) => Par::Scheduled(r, schedule),
            Par::Cross(r, _, session) => Par::Cross(r, schedule, session),
        }
    }

    /// The schedule parallel loops run under ([`Schedule::Static`] for
    /// `Par::Serial`, whose loops have no chunks to assign).
    pub fn schedule(&self) -> Schedule {
        match self {
            Par::Serial => Schedule::Static,
            Par::Relic(r) => r.default_schedule(),
            Par::Scheduled(_, s) | Par::Cross(_, s, _) => *s,
        }
    }

    /// This `Par` as an *unweighted* loop of `len` indices must run it:
    /// edge-balanced needs per-chunk work information a
    /// [`Grain::Elems`] call site doesn't have, so it falls back to
    /// plain self-scheduling. No longer silent (ISSUE 9): whenever the
    /// substitution takes effect — i.e. the loop actually fans out; a
    /// tiny range runs serially under every schedule — it is counted in
    /// [`RelicStats::schedule_downgrades`](crate::relic::RelicStats::schedule_downgrades).
    fn downgrade_unweighted(&self, len: usize, grain: usize) -> Par<'r> {
        if self.schedule() != Schedule::EdgeBalanced {
            return *self;
        }
        if let Some((relic, _)) = self.plan_for(len, grain) {
            relic.note_schedule_downgrade();
        }
        self.with_schedule(Schedule::Dynamic)
    }

    /// The runtime + schedule a loop of `len` indices should use.
    /// `None` means run serially: no runtime, or the tiny-range fast
    /// path — a range that fits one grain would pay the submit/wait
    /// handshake for nothing.
    fn plan_for(&self, len: usize, grain: usize) -> Option<(&'r Relic, Schedule)> {
        if len <= grain.max(1) {
            return None;
        }
        match *self {
            Par::Serial => None,
            Par::Relic(r) => Some((r, r.default_schedule())),
            Par::Scheduled(r, s) | Par::Cross(r, s, _) => Some((r, s)),
        }
    }

    /// The cross-shard session a loop of `len` indices should fan out
    /// through, with the shard-level chunk count already computed.
    /// `None` for every non-cross plan and for loops too small to carve
    /// into at least two shard-level chunks — those fall through to the
    /// single-pair paths via [`plan_for`](Self::plan_for).
    fn cross_plan(
        &self,
        len: usize,
        grain: usize,
    ) -> Option<(&'r Relic, &'r CrossSession<'r>, usize)> {
        match *self {
            Par::Cross(r, _, session) if len > grain.max(1) => {
                let k = cross_chunk_count(len, grain);
                (k >= 2).then_some((r, session, k))
            }
            _ => None,
        }
    }

    /// Shard-level chunk boundaries for a cross loop: edge-balanced
    /// when this plan runs under [`Schedule::EdgeBalanced`] (the same
    /// monotone-forced bisection the pair-level bounded splitters use),
    /// even index splits otherwise. Pure in `(range, k, bound)` — the
    /// boundaries never depend on which shards end up serving.
    fn cross_bounds(
        &self,
        range: &Range<usize>,
        k: usize,
        bound: &dyn Fn(usize, usize) -> usize,
        bounds: &mut [usize],
    ) {
        match self.schedule() {
            Schedule::EdgeBalanced => bounds_by(range, k, bound, bounds),
            _ => even_bounds(range, k, bounds),
        }
    }

    /// Call `f(i)` for every `i` in `range`. The [`Grain`] picks the
    /// chunking: a bare `usize` for plain chunks of at least that many
    /// indices, or [`Grain::Bounded`] to add work-balanced boundaries
    /// for [`Schedule::EdgeBalanced`]. Shared-state effects inside `f`
    /// must be thread-safe (atomics).
    pub fn for_each_index<'b, F: Fn(usize) + Sync>(
        &self,
        range: Range<usize>,
        grain: impl Into<Grain<'b>>,
        f: F,
    ) {
        match grain.into() {
            Grain::Elems(g) => {
                self.downgrade_unweighted(range.len(), g).for_each_unbounded(range, g, f)
            }
            Grain::Bounded(g, bound) => self.for_each_bounded(range, g, bound, f),
        }
    }

    /// [`for_each_index`](Self::for_each_index) for [`Grain::Elems`]
    /// call sites; the caller has already applied the edge-balanced
    /// downgrade.
    fn for_each_unbounded<F: Fn(usize) + Sync>(&self, range: Range<usize>, grain: usize, f: F) {
        if let Some((relic, session, k)) = self.cross_plan(range.len(), grain) {
            let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
            even_bounds(&range, k, &mut bounds);
            session.run(relic, &bounds[..=k], &|_, sub: Range<usize>| {
                for i in sub {
                    f(i);
                }
            });
            return;
        }
        match self.plan_for(range.len(), grain) {
            None => {
                for i in range {
                    f(i);
                }
            }
            Some((relic, Schedule::Static)) => relic.scope(|s| {
                s.split(range, grain, |sub| {
                    for i in sub {
                        f(i);
                    }
                });
            }),
            Some((relic, _)) => relic.scope(|s| {
                s.split_dynamic(range, grain, |sub| {
                    for i in sub {
                        f(i);
                    }
                });
            }),
        }
    }

    /// [`for_each_index`](Self::for_each_index) for [`Grain::Bounded`]
    /// call sites.
    fn for_each_bounded<F>(
        &self,
        range: Range<usize>,
        grain: usize,
        bound: &dyn Fn(usize, usize) -> usize,
        f: F,
    ) where
        F: Fn(usize) + Sync,
    {
        if let Some((relic, session, k)) = self.cross_plan(range.len(), grain) {
            let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
            self.cross_bounds(&range, k, bound, &mut bounds);
            session.run(relic, &bounds[..=k], &|_, sub: Range<usize>| {
                for i in sub {
                    f(i);
                }
            });
            return;
        }
        match self.plan_for(range.len(), grain) {
            Some((relic, Schedule::EdgeBalanced)) => {
                let k = dyn_chunk_count(range.len(), grain);
                relic.scope(|s| {
                    s.split_dynamic_by(
                        range,
                        k,
                        bound,
                        |_, sub| {
                            for i in sub {
                                f(i);
                            }
                        },
                        |_| {},
                    );
                });
            }
            _ => self.for_each_unbounded(range, grain, f),
        }
    }

    /// `out[i] = f(i)` for every element — the scatter/pull-loop shape.
    /// `f` may read any shared data except `out` itself. See
    /// [`for_each_index`](Self::for_each_index) for the [`Grain`]
    /// semantics (the boundary function spans `0..out.len()`).
    pub fn map_into<'b, T, F>(&self, out: &mut [T], grain: impl Into<Grain<'b>>, f: F)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match grain.into() {
            Grain::Elems(g) => {
                self.downgrade_unweighted(out.len(), g).map_into_unbounded(out, g, f)
            }
            Grain::Bounded(g, bound) => self.map_into_bounded(out, g, bound, f),
        }
    }

    /// [`map_into`](Self::map_into) for [`Grain::Elems`] call sites;
    /// the caller has already applied the edge-balanced downgrade.
    fn map_into_unbounded<T, F>(&self, out: &mut [T], grain: usize, f: F)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let n = out.len();
        if let Some((relic, session, k)) = self.cross_plan(n, grain) {
            let base = RawSlice(out.as_mut_ptr());
            let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
            even_bounds(&(0..n), k, &mut bounds);
            session.run(relic, &bounds[..=k], &|_, sub: Range<usize>| {
                for i in sub {
                    // SAFETY: shard-level chunks are disjoint and
                    // in-bounds (`sub ⊆ 0..n`); RawSlice's contract.
                    unsafe { *base.0.add(i) = f(i) };
                }
            });
            return;
        }
        match self.plan_for(n, grain) {
            None => {
                for (i, slot) in out.iter_mut().enumerate() {
                    *slot = f(i);
                }
            }
            Some((relic, sched)) => {
                let base = RawSlice(out.as_mut_ptr());
                // SAFETY (both arms): chunks are disjoint and in-bounds
                // (`sub ⊆ 0..n`); RawSlice's contract.
                relic.scope(|s| {
                    let body = |sub: Range<usize>| {
                        for i in sub {
                            unsafe { *base.0.add(i) = f(i) };
                        }
                    };
                    match sched {
                        Schedule::Static => s.split(0..n, grain, body),
                        _ => s.split_dynamic(0..n, grain, body),
                    }
                });
            }
        }
    }

    /// [`map_into`](Self::map_into) for [`Grain::Bounded`] call sites.
    fn map_into_bounded<T, F>(
        &self,
        out: &mut [T],
        grain: usize,
        bound: &dyn Fn(usize, usize) -> usize,
        f: F,
    ) where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let n = out.len();
        if let Some((relic, session, k)) = self.cross_plan(n, grain) {
            let base = RawSlice(out.as_mut_ptr());
            let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
            self.cross_bounds(&(0..n), k, bound, &mut bounds);
            session.run(relic, &bounds[..=k], &|_, sub: Range<usize>| {
                for i in sub {
                    // SAFETY: disjoint in-bounds shard-level chunks.
                    unsafe { *base.0.add(i) = f(i) };
                }
            });
            return;
        }
        match self.plan_for(n, grain) {
            Some((relic, Schedule::EdgeBalanced)) => {
                let base = RawSlice(out.as_mut_ptr());
                let k = dyn_chunk_count(n, grain);
                relic.scope(|s| {
                    s.split_dynamic_by(
                        0..n,
                        k,
                        bound,
                        |_, sub| {
                            for i in sub {
                                // SAFETY: disjoint in-bounds chunks.
                                unsafe { *base.0.add(i) = f(i) };
                            }
                        },
                        |_| {},
                    );
                });
            }
            _ => self.map_into_unbounded(out, grain, f),
        }
    }

    /// Fold `f(i)` over `range` with `combine`, parallel by chunk.
    /// Each chunk folds serially in index order into a private slot;
    /// slots are combined in ascending chunk order on the main thread
    /// (wave by wave under the self-scheduled modes — still ascending).
    /// `identity` must be neutral for `combine`. See
    /// [`for_each_index`](Self::for_each_index) for the [`Grain`]
    /// semantics.
    pub fn reduce<'b, T, F, C>(
        &self,
        range: Range<usize>,
        grain: impl Into<Grain<'b>>,
        identity: T,
        f: F,
        combine: C,
    ) -> T
    where
        T: Copy + Send + Sync,
        F: Fn(usize) -> T + Sync,
        C: Fn(T, T) -> T + Sync,
    {
        match grain.into() {
            Grain::Elems(g) => {
                // The dummy bound is unreachable: downgrade_unweighted
                // guarantees the EdgeBalanced path is never taken here.
                self.downgrade_unweighted(range.len(), g)
                    .reduce_bounded(range, g, &|_, _| 0, identity, f, combine)
            }
            Grain::Bounded(g, bound) => self.reduce_bounded(range, g, bound, identity, f, combine),
        }
    }

    /// [`reduce`](Self::reduce) for [`Grain::Bounded`] call sites (a
    /// [`Grain::Elems`] caller passes a dummy bound after applying the
    /// edge-balanced downgrade).
    fn reduce_bounded<T, F, C>(
        &self,
        range: Range<usize>,
        grain: usize,
        bound: &dyn Fn(usize, usize) -> usize,
        identity: T,
        f: F,
        combine: C,
    ) -> T
    where
        T: Copy + Send + Sync,
        F: Fn(usize) -> T + Sync,
        C: Fn(T, T) -> T + Sync,
    {
        if let Some((relic, session, k)) = self.cross_plan(range.len(), grain) {
            let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
            self.cross_bounds(&range, k, bound, &mut bounds);
            let mut partials = [identity; MAX_CROSS_CHUNKS];
            let slots = RawSlice(partials.as_mut_ptr());
            session.run(relic, &bounds[..=k], &|ci: usize, sub: Range<usize>| {
                let mut a = identity;
                for i in sub {
                    a = combine(a, f(i));
                }
                // SAFETY: `ci < MAX_CROSS_CHUNKS` (session contract)
                // and each shard-level chunk owns its slot; the join
                // in `session.run` publishes the writes before the
                // ascending fold below reads them.
                unsafe { *slots.0.add(ci) = a };
            });
            let mut acc = identity;
            for p in &partials[..k] {
                acc = combine(acc, *p);
            }
            return acc;
        }
        let Some((relic, sched)) = self.plan_for(range.len(), grain) else {
            let mut acc = identity;
            for i in range {
                acc = combine(acc, f(i));
            }
            return acc;
        };
        if sched == Schedule::Static {
            let mut partials = [identity; MAX_CHUNK_SLOTS];
            let slots = RawSlice(partials.as_mut_ptr());
            relic.scope(|s| {
                s.split_indexed(range, grain, |ci, sub| {
                    let mut acc = identity;
                    for i in sub {
                        acc = combine(acc, f(i));
                    }
                    // SAFETY: `ci < MAX_CHUNK_SLOTS` (scope contract)
                    // and each chunk owns its slot exclusively.
                    unsafe { *slots.0.add(ci) = acc };
                });
            });
            let mut acc = identity;
            for p in partials {
                acc = combine(acc, p);
            }
            return acc;
        }
        // Self-scheduled: per-wave slots, drained in ascending chunk
        // order after each wave joins and before any slot is reused.
        let mut partials = [identity; MAX_CHUNK_SLOTS];
        let slots = RawSlice(partials.as_mut_ptr());
        let mut acc = identity;
        {
            let combine = &combine;
            let body = |ci: usize, sub: Range<usize>| {
                let mut a = identity;
                for i in sub {
                    a = combine(a, f(i));
                }
                // SAFETY: `ci < MAX_CHUNK_SLOTS`, exclusive per wave.
                unsafe { *slots.0.add(ci) = a };
            };
            let acc_ref = &mut acc;
            let wave_done = |n: usize| {
                for slot in 0..n {
                    // SAFETY: the wave joined; its chunks wrote these.
                    *acc_ref = combine(*acc_ref, unsafe { *slots.0.add(slot) });
                }
            };
            let k = dyn_chunk_count(range.len(), grain);
            relic.scope(|s| match sched {
                Schedule::EdgeBalanced => s.split_dynamic_by(range, k, bound, body, wave_done),
                _ => s.split_dynamic_indexed(range, grain, body, wave_done),
            });
        }
        acc
    }

    /// Run `f` once per chunk of `range` and collect the per-chunk
    /// outputs in ascending chunk order (i.e. range order). The frontier
    /// shape: each chunk gathers into its own buffer, the main thread
    /// concatenates. The returned `Vec` (plus the per-chunk outputs
    /// themselves) is the only allocation. See
    /// [`for_each_index`](Self::for_each_index) for the [`Grain`]
    /// semantics.
    pub fn chunk_map<'b, T, F>(
        &self,
        range: Range<usize>,
        grain: impl Into<Grain<'b>>,
        f: F,
    ) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        match grain.into() {
            Grain::Elems(g) => {
                // The dummy bound is unreachable: downgrade_unweighted
                // guarantees the EdgeBalanced path is never taken here.
                self.downgrade_unweighted(range.len(), g)
                    .chunk_map_bounded(range, g, &|_, _| 0, f)
            }
            Grain::Bounded(g, bound) => self.chunk_map_bounded(range, g, bound, f),
        }
    }

    /// [`chunk_map`](Self::chunk_map) for [`Grain::Bounded`] call sites
    /// (a [`Grain::Elems`] caller passes a dummy bound after applying
    /// the edge-balanced downgrade).
    fn chunk_map_bounded<T, F>(
        &self,
        range: Range<usize>,
        grain: usize,
        bound: &dyn Fn(usize, usize) -> usize,
        f: F,
    ) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        if let Some((relic, session, k)) = self.cross_plan(range.len(), grain) {
            let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
            self.cross_bounds(&range, k, bound, &mut bounds);
            let mut outputs: [Option<T>; MAX_CROSS_CHUNKS] = std::array::from_fn(|_| None);
            let slots = RawSlice(outputs.as_mut_ptr());
            session.run(relic, &bounds[..=k], &|ci: usize, sub: Range<usize>| {
                let v = f(sub);
                // SAFETY: `ci < MAX_CROSS_CHUNKS`, chunk-private; the
                // join in `session.run` publishes before the drain.
                unsafe { *slots.0.add(ci) = Some(v) };
            });
            return outputs.into_iter().flatten().collect();
        }
        let Some((relic, sched)) = self.plan_for(range.len(), grain) else {
            return if range.is_empty() { Vec::new() } else { vec![f(range)] };
        };
        if sched == Schedule::Static {
            let mut outputs: [Option<T>; MAX_CHUNK_SLOTS] = std::array::from_fn(|_| None);
            let slots = RawSlice(outputs.as_mut_ptr());
            relic.scope(|s| {
                s.split_indexed(range, grain, |ci, sub| {
                    let v = f(sub);
                    // SAFETY: `ci < MAX_CHUNK_SLOTS`, chunk-private.
                    unsafe { *slots.0.add(ci) = Some(v) };
                });
            });
            return outputs.into_iter().flatten().collect();
        }
        // Self-scheduled: drain the wave's slots in ascending chunk
        // order after each join, before the slots are reused.
        let mut outputs: [Option<T>; MAX_CHUNK_SLOTS] = std::array::from_fn(|_| None);
        let slots = RawSlice(outputs.as_mut_ptr());
        let mut all: Vec<T> = Vec::new();
        {
            let body = |ci: usize, sub: Range<usize>| {
                let v = f(sub);
                // SAFETY: `ci < MAX_CHUNK_SLOTS`, exclusive per wave.
                unsafe { *slots.0.add(ci) = Some(v) };
            };
            let all_ref = &mut all;
            let wave_done = |n: usize| {
                for slot in 0..n {
                    // SAFETY: the wave joined; its chunks wrote these.
                    if let Some(v) = unsafe { (*slots.0.add(slot)).take() } {
                        all_ref.push(v);
                    }
                }
            };
            let k = dyn_chunk_count(range.len(), grain);
            relic.scope(|s| match sched {
                Schedule::EdgeBalanced => s.split_dynamic_by(range, k, bound, body, wave_done),
                _ => s.split_dynamic_indexed(range, grain, body, wave_done),
            });
        }
        all
    }
}

impl Relic {
    /// Convenience fork-join loop: split `range` across the SMT pair
    /// (under this runtime's default schedule) and call `f(i)` for
    /// every index, chunks of at least `grain`. Zero-allocation;
    /// equivalent to `Par::Relic(self).for_each_index(range, grain, f)`.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, range: Range<usize>, grain: usize, f: F) {
        Par::Relic(self).for_each_index(range, grain, f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relic::RelicConfig;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// The parallel plans worth exercising in every helper test.
    fn plans(relic: &Relic) -> [Par<'_>; 4] {
        [
            Par::Serial,
            Par::Relic(relic),
            Par::Relic(relic).with_schedule(Schedule::Dynamic),
            Par::Relic(relic).with_schedule(Schedule::EdgeBalanced),
        ]
    }

    #[test]
    fn for_each_index_all_schedules_agree() {
        let relic = Relic::new();
        for par in plans(&relic) {
            let sum = AtomicU64::new(0);
            par.for_each_index(5..500, 16, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            let want: u64 = (5..500).sum();
            assert_eq!(sum.load(Ordering::Relaxed), want, "{}", par.schedule().name());
        }
    }

    #[test]
    fn map_into_matches_serial_bitwise() {
        let relic = Relic::new();
        let n = 777;
        let mut serial = vec![0.0f64; n];
        Par::Serial.map_into(&mut serial, 8, |i| (i as f64).sqrt());
        for par in plans(&relic) {
            let mut parallel = vec![0.0f64; n];
            par.map_into(&mut parallel, 8, |i| (i as f64).sqrt());
            assert_eq!(serial, parallel, "{}", par.schedule().name());
        }
    }

    #[test]
    fn map_into_bounded_uses_balanced_bounds() {
        let relic = Relic::new();
        let n = 500;
        let mut want = vec![0u64; n];
        Par::Serial.map_into(&mut want, 8, |i| i as u64 * 3);
        for par in plans(&relic) {
            let mut got = vec![0u64; n];
            // Quadratically skewed boundaries exercise uneven chunks.
            let bound = |i: usize, k: usize| n * i * i / (k * k);
            par.map_into(&mut got, Grain::Bounded(8, &bound), |i| i as u64 * 3);
            assert_eq!(got, want, "{}", par.schedule().name());
        }
    }

    #[test]
    fn reduce_exact_for_integer_sums() {
        let relic = Relic::new();
        for n in [0usize, 1, 9, 100, 4096] {
            let serial = Par::Serial.reduce(0..n, 32, 0u64, |i| i as u64 * 3, |a, b| a + b);
            for par in plans(&relic) {
                let got = par.reduce(0..n, 32, 0u64, |i| i as u64 * 3, |a, b| a + b);
                assert_eq!(serial, got, "n={n} {}", par.schedule().name());
            }
        }
    }

    #[test]
    fn reduce_bounded_balanced_bounds_exact() {
        let relic = Relic::new();
        let n = 3000usize;
        let want = Par::Serial.reduce(0..n, 16, 0u64, |i| (i * i) as u64, |a, b| a + b);
        let bound = |i: usize, k: usize| n * i * i / (k * k);
        for par in plans(&relic) {
            let got = par.reduce(
                0..n,
                Grain::Bounded(16, &bound),
                0u64,
                |i| (i * i) as u64,
                |a, b| a + b,
            );
            assert_eq!(got, want, "{}", par.schedule().name());
        }
    }

    #[test]
    fn reduce_max_monoid() {
        let relic = Relic::new();
        let want = Par::Serial.reduce(
            0..1000,
            16,
            0u64,
            |i| ((i * 2654435761) % 1009) as u64,
            |a, b| a.max(b),
        );
        for par in plans(&relic) {
            let got = par.reduce(
                0..1000,
                16,
                0u64,
                |i| ((i * 2654435761) % 1009) as u64,
                |a, b| a.max(b),
            );
            assert_eq!(got, want, "{}", par.schedule().name());
        }
    }

    #[test]
    fn dynamic_float_reduce_is_deterministic() {
        // The fixed chunk shape must make the float combination tree
        // identical run to run, whichever thread claims which chunk.
        let relic = Relic::new();
        let par = Par::Relic(&relic).with_schedule(Schedule::Dynamic);
        let first = par.reduce(0..5000, 7, 0.0f64, |i| (i as f64).sqrt(), |a, b| a + b);
        for round in 0..20 {
            let again = par.reduce(0..5000, 7, 0.0f64, |i| (i as f64).sqrt(), |a, b| a + b);
            assert_eq!(first.to_bits(), again.to_bits(), "round {round}");
        }
    }

    #[test]
    fn chunk_map_preserves_range_order() {
        let relic = Relic::new();
        for par in plans(&relic) {
            let chunks = par.chunk_map(0..100, 4, |sub| sub.collect::<Vec<usize>>());
            let flat: Vec<usize> = chunks.into_iter().flatten().collect();
            assert_eq!(flat, (0..100).collect::<Vec<usize>>(), "{}", par.schedule().name());
            assert!(par.chunk_map(3..3, 4, |s| s.len()).is_empty());
        }
    }

    #[test]
    fn chunk_map_bounded_preserves_range_order_across_waves() {
        let relic = Relic::new();
        let bound = |i: usize, k: usize| 1000 * i * i / (k * k);
        for par in plans(&relic) {
            // Grain 1 over 1000 indices forces the MAX_DYN_CHUNKS cap
            // and multiple waves under the self-scheduled modes.
            let chunks = par.chunk_map(0..1000, Grain::Bounded(1, &bound), |sub| {
                sub.collect::<Vec<usize>>()
            });
            let flat: Vec<usize> = chunks.into_iter().flatten().collect();
            assert_eq!(flat, (0..1000).collect::<Vec<usize>>(), "{}", par.schedule().name());
        }
    }

    #[test]
    fn bounded_grain_routes_every_entry_point_through_bounded_paths() {
        // The `_by` shims are gone (deprecated one PR, ISSUE 9 → 10);
        // `Grain::Bounded` on the plan-carrying entry points is the one
        // way to hand a boundary function to every helper.
        let relic = Relic::new();
        let n = 400usize;
        let bound = |i: usize, k: usize| n * i * i / (k * k);
        let par = Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced);

        let hits = AtomicU64::new(0);
        par.for_each_index(0..n, Grain::Bounded(8, &bound), |i| {
            hits.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);

        let mut out = vec![0u64; n];
        par.map_into(&mut out, Grain::Bounded(8, &bound), |i| i as u64 * 7);
        assert_eq!(out[n - 1], (n as u64 - 1) * 7);

        let red =
            par.reduce(0..n, Grain::Bounded(8, &bound), 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(red, (n as u64 - 1) * n as u64 / 2);

        let chunks = par.chunk_map(0..n, Grain::Bounded(8, &bound), |sub| sub.len());
        assert_eq!(chunks.iter().sum::<usize>(), n);
    }

    #[test]
    fn edge_balanced_without_bounds_counts_a_downgrade() {
        let relic = Relic::new();
        let par = Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced);
        assert_eq!(relic.stats().schedule_downgrades, 0);

        // An unweighted loop that actually fans out: one downgrade.
        let sum = AtomicU64::new(0);
        par.for_each_index(0..1000, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
        assert_eq!(relic.stats().schedule_downgrades, 1);

        // A bounded loop carries its own weights: no downgrade.
        let bound = |i: usize, k: usize| 1000 * i * i / (k * k);
        par.for_each_index(0..1000, Grain::Bounded(8, &bound), |_| {});
        assert_eq!(relic.stats().schedule_downgrades, 1);

        // A tiny unweighted range runs serially under every schedule:
        // the substitution never takes effect, so it is not counted.
        par.reduce(0..8, 8, 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(relic.stats().schedule_downgrades, 1);

        // Other schedules never downgrade.
        Par::Relic(&relic).with_schedule(Schedule::Dynamic).for_each_index(0..1000, 8, |_| {});
        assert_eq!(relic.stats().schedule_downgrades, 1);

        // And each fanning-out unweighted loop counts once more.
        let mut out = vec![0u64; 1000];
        par.map_into(&mut out, 8, |i| i as u64);
        assert_eq!(relic.stats().schedule_downgrades, 2);
    }

    #[test]
    fn parallel_for_convenience_covers_range() {
        let relic = Relic::new();
        let hits = AtomicU64::new(0);
        relic.parallel_for(0..10_000, 64, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10_000);
    }

    #[test]
    fn from_relic_toggles() {
        let relic = Relic::new();
        assert!(!Par::from_relic(None).is_parallel());
        assert!(Par::from_relic(Some(&relic)).is_parallel());
    }

    #[test]
    fn with_schedule_overrides_and_serial_stays_serial() {
        let relic = Relic::new();
        assert_eq!(Par::Relic(&relic).schedule(), Schedule::Static);
        let dynamic = Par::Relic(&relic).with_schedule(Schedule::Dynamic);
        assert_eq!(dynamic.schedule(), Schedule::Dynamic);
        assert_eq!(
            dynamic.with_schedule(Schedule::EdgeBalanced).schedule(),
            Schedule::EdgeBalanced,
            "with_schedule replaces an earlier override"
        );
        assert!(!Par::Serial.with_schedule(Schedule::Dynamic).is_parallel());
    }

    #[test]
    fn relic_config_sets_the_default_schedule() {
        let relic = Relic::with_config(RelicConfig {
            schedule: Schedule::Dynamic,
            ..RelicConfig::default()
        });
        assert_eq!(Par::Relic(&relic).schedule(), Schedule::Dynamic);
        // Per-loop override still wins.
        let par = Par::Relic(&relic).with_schedule(Schedule::Static);
        assert_eq!(par.schedule(), Schedule::Static);
        // And the configured default actually drives the helpers.
        let sum = AtomicU64::new(0);
        Par::Relic(&relic).for_each_index(0..1000, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn schedule_parse_roundtrip() {
        for s in Schedule::all() {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("edge"), Some(Schedule::EdgeBalanced));
        assert_eq!(Schedule::parse("nope"), None);
        assert_eq!(Schedule::default(), Schedule::Static);
    }

    #[test]
    fn tiny_ranges_skip_the_scope_entirely() {
        let relic = Relic::new();
        for schedule in Schedule::all() {
            let par = Par::Relic(&relic).with_schedule(schedule);
            let before = relic.stats().submitted;
            let sum = AtomicU64::new(0);
            par.for_each_index(0..8, 8, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            let mut out = vec![0u64; 8];
            par.map_into(&mut out, 8, |i| i as u64);
            let red = par.reduce(0..8, 8, 0u64, |i| i as u64, |a, b| a + b);
            let chunks = par.chunk_map(0..8, 8, |sub| sub.len());
            assert_eq!(sum.load(Ordering::Relaxed), 28);
            assert_eq!(out[7], 7);
            assert_eq!(red, 28);
            assert_eq!(chunks, vec![8]);
            assert_eq!(
                relic.stats().submitted,
                before,
                "{}: a range that fits one grain must not submit",
                schedule.name()
            );
        }
    }

    #[test]
    fn grain_zero_is_treated_as_one() {
        let relic = Relic::new();
        let sum = AtomicU64::new(0);
        relic.parallel_for(0..64, 0, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 63 * 64 / 2);
    }
}
