//! Data-parallel helpers over [`Relic::scope`]: the `Par` toggle the
//! GAP kernels and the JSON parser take to run their hot loops on both
//! logical threads of the SMT pair.
//!
//! [`Par`] is deliberately an enum, not a trait object: kernels accept
//! `&Par` and stay monomorphic, `Par::Serial` compiles to the plain
//! loop, and `Par::Relic` routes chunks through the fork-join scope.
//! All helpers are *deterministic by construction* where the paper's
//! checksums require it:
//!
//! * [`Par::map_into`] writes disjoint slice elements — bitwise equal to
//!   the serial loop regardless of scheduling;
//! * [`Par::reduce`] combines per-chunk partials in fixed chunk order —
//!   exact for integer monoids (the checksum kind), and fixed-shape
//!   (chunk boundaries depend only on the range and grain) for floats;
//! * [`Par::chunk_map`] concatenates per-chunk outputs in chunk order.
//!
//! ```
//! use relic_smt::relic::{Par, Relic};
//!
//! let relic = Relic::new();
//! let par = Par::Relic(&relic);
//! let mut squares = vec![0u64; 100];
//! par.map_into(&mut squares, 8, |i| (i * i) as u64);
//! assert_eq!(squares[7], 49);
//! let total = par.reduce(0..100, 8, 0u64, |i| i as u64, |a, b| a + b);
//! assert_eq!(total, 99 * 100 / 2);
//! // The parallel_for convenience on the runtime itself:
//! use std::sync::atomic::{AtomicU64, Ordering};
//! let n = AtomicU64::new(0);
//! relic.parallel_for(0..1000, 64, |_i| {
//!     n.fetch_add(1, Ordering::Relaxed);
//! });
//! assert_eq!(n.load(Ordering::Relaxed), 1000);
//! ```

use std::ops::Range;

use super::framework::Relic;
use super::scope::MAX_CHUNK_SLOTS;

/// Default minimum indices per chunk: with the paper's ~0.1 µs/iteration
/// kernel loops this keeps every chunk well above Relic's ~70 ns
/// submit+dispatch cost.
pub const DEFAULT_GRAIN: usize = 16;

/// How a kernel's internal loops execute.
pub enum Par<'r> {
    /// Plain serial loops on the calling thread (the baseline).
    Serial,
    /// Fork-join over the SMT pair through a [`Relic`] runtime.
    Relic(&'r Relic),
}

/// Raw slice base pointer that may cross to the assistant thread.
/// Soundness rests on the chunk disjointness `Scope::split` guarantees:
/// no element is touched by more than one chunk.
struct RawSlice<T>(*mut T);

// SAFETY: only ever used to access disjoint elements from the two
// threads of one scope; T itself crosses threads, hence T: Send.
unsafe impl<T: Send> Send for RawSlice<T> {}
unsafe impl<T: Send> Sync for RawSlice<T> {}

impl<'r> Par<'r> {
    /// Build from an optional runtime reference.
    pub fn from_relic(relic: Option<&'r Relic>) -> Self {
        match relic {
            Some(r) => Par::Relic(r),
            None => Par::Serial,
        }
    }

    /// True when loops actually fan out to the assistant.
    pub fn is_parallel(&self) -> bool {
        matches!(self, Par::Relic(_))
    }

    /// Call `f(i)` for every `i` in `range`, chunks of at least `grain`.
    /// Shared-state effects inside `f` must be thread-safe (atomics).
    pub fn for_each_index<F: Fn(usize) + Sync>(&self, range: Range<usize>, grain: usize, f: F) {
        match self {
            Par::Serial => {
                for i in range {
                    f(i);
                }
            }
            Par::Relic(relic) => relic.scope(|s| {
                s.split(range, grain, |sub| {
                    for i in sub {
                        f(i);
                    }
                });
            }),
        }
    }

    /// `out[i] = f(i)` for every element — the scatter/pull-loop shape.
    /// `f` may read any shared data except `out` itself.
    pub fn map_into<T, F>(&self, out: &mut [T], grain: usize, f: F)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match self {
            Par::Serial => {
                for (i, slot) in out.iter_mut().enumerate() {
                    *slot = f(i);
                }
            }
            Par::Relic(relic) => {
                let n = out.len();
                let base = RawSlice(out.as_mut_ptr());
                relic.scope(|s| {
                    s.split(0..n, grain, |sub| {
                        for i in sub {
                            // SAFETY: chunks are disjoint and in-bounds
                            // (`sub ⊆ 0..n`); RawSlice's contract.
                            unsafe { *base.0.add(i) = f(i) };
                        }
                    });
                });
            }
        }
    }

    /// Fold `f(i)` over `range` with `combine`, parallel by chunk.
    /// Each chunk folds serially in index order into a private slot;
    /// slots are combined in ascending chunk order on the main thread.
    /// `identity` must be neutral for `combine`.
    pub fn reduce<T, F, C>(
        &self,
        range: Range<usize>,
        grain: usize,
        identity: T,
        f: F,
        combine: C,
    ) -> T
    where
        T: Copy + Send,
        F: Fn(usize) -> T + Sync,
        C: Fn(T, T) -> T + Sync,
    {
        match self {
            Par::Serial => {
                let mut acc = identity;
                for i in range {
                    acc = combine(acc, f(i));
                }
                acc
            }
            Par::Relic(relic) => {
                let mut partials = [identity; MAX_CHUNK_SLOTS];
                let slots = RawSlice(partials.as_mut_ptr());
                relic.scope(|s| {
                    s.split_indexed(range, grain, |ci, sub| {
                        let mut acc = identity;
                        for i in sub {
                            acc = combine(acc, f(i));
                        }
                        // SAFETY: `ci < MAX_CHUNK_SLOTS` (scope contract)
                        // and each chunk owns its slot exclusively.
                        unsafe { *slots.0.add(ci) = acc };
                    });
                });
                let mut acc = identity;
                for p in partials {
                    acc = combine(acc, p);
                }
                acc
            }
        }
    }

    /// Run `f` once per chunk of `range` and collect the per-chunk
    /// outputs in ascending chunk order (i.e. range order). The frontier
    /// shape: each chunk gathers into its own buffer, the main thread
    /// concatenates. The returned `Vec` is the only allocation.
    pub fn chunk_map<T, F>(&self, range: Range<usize>, grain: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        match self {
            Par::Serial => {
                if range.is_empty() {
                    Vec::new()
                } else {
                    vec![f(range)]
                }
            }
            Par::Relic(relic) => {
                let mut outputs: [Option<T>; MAX_CHUNK_SLOTS] = std::array::from_fn(|_| None);
                let slots = RawSlice(outputs.as_mut_ptr());
                relic.scope(|s| {
                    s.split_indexed(range, grain, |ci, sub| {
                        let v = f(sub);
                        // SAFETY: `ci < MAX_CHUNK_SLOTS`, chunk-private.
                        unsafe { *slots.0.add(ci) = Some(v) };
                    });
                });
                outputs.into_iter().flatten().collect()
            }
        }
    }
}

impl Relic {
    /// Convenience fork-join loop: statically split `range` across the
    /// SMT pair and call `f(i)` for every index, chunks of at least
    /// `grain`. Zero-allocation; equivalent to
    /// `Par::Relic(self).for_each_index(range, grain, f)`.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, range: Range<usize>, grain: usize, f: F) {
        Par::Relic(self).for_each_index(range, grain, f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn for_each_index_serial_and_parallel_agree() {
        let relic = Relic::new();
        for par in [Par::Serial, Par::Relic(&relic)] {
            let sum = AtomicU64::new(0);
            par.for_each_index(5..500, 16, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            let want: u64 = (5..500).sum();
            assert_eq!(sum.load(Ordering::Relaxed), want);
        }
    }

    #[test]
    fn map_into_matches_serial_bitwise() {
        let relic = Relic::new();
        let n = 777;
        let mut serial = vec![0.0f64; n];
        Par::Serial.map_into(&mut serial, 8, |i| (i as f64).sqrt());
        let mut parallel = vec![0.0f64; n];
        Par::Relic(&relic).map_into(&mut parallel, 8, |i| (i as f64).sqrt());
        assert_eq!(serial, parallel);
    }

    #[test]
    fn reduce_exact_for_integer_sums() {
        let relic = Relic::new();
        for n in [0usize, 1, 9, 100, 4096] {
            let serial = Par::Serial.reduce(0..n, 32, 0u64, |i| i as u64 * 3, |a, b| a + b);
            let par = Par::Relic(&relic).reduce(0..n, 32, 0u64, |i| i as u64 * 3, |a, b| a + b);
            assert_eq!(serial, par, "n={n}");
        }
    }

    #[test]
    fn reduce_max_monoid() {
        let relic = Relic::new();
        let got = Par::Relic(&relic).reduce(
            0..1000,
            16,
            0u64,
            |i| ((i * 2654435761) % 1009) as u64,
            |a, b| a.max(b),
        );
        let want = Par::Serial.reduce(
            0..1000,
            16,
            0u64,
            |i| ((i * 2654435761) % 1009) as u64,
            |a, b| a.max(b),
        );
        assert_eq!(got, want);
    }

    #[test]
    fn chunk_map_preserves_range_order() {
        let relic = Relic::new();
        for par in [Par::Serial, Par::Relic(&relic)] {
            let chunks = par.chunk_map(0..100, 4, |sub| sub.collect::<Vec<usize>>());
            let flat: Vec<usize> = chunks.into_iter().flatten().collect();
            assert_eq!(flat, (0..100).collect::<Vec<usize>>());
        }
        assert!(Par::Serial.chunk_map(3..3, 4, |s| s.len()).is_empty());
        assert!(Par::Relic(&relic).chunk_map(3..3, 4, |s| s.len()).is_empty());
    }

    #[test]
    fn parallel_for_convenience_covers_range() {
        let relic = Relic::new();
        let hits = AtomicU64::new(0);
        relic.parallel_for(0..10_000, 64, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10_000);
    }

    #[test]
    fn from_relic_toggles() {
        let relic = Relic::new();
        assert!(!Par::from_relic(None).is_parallel());
        assert!(Par::from_relic(Some(&relic)).is_parallel());
    }

    #[test]
    fn grain_zero_is_treated_as_one() {
        let relic = Relic::new();
        let sum = AtomicU64::new(0);
        relic.parallel_for(0..64, 0, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 63 * 64 / 2);
    }
}
