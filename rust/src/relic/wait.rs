//! Waiting mechanisms (paper §VI-B).
//!
//! Relic busy-waits with `pause` by default — the right choice for
//! µs-scale tasks between two logical threads of one SMT core, where the
//! `pause` instruction both saves power and *releases pipeline resources
//! to the sibling thread*. The other policies exist for the waiting-
//! mechanism ablation (DESIGN.md exp A2) and for embedding Relic in
//! applications with long serial phases (where the paper instead
//! recommends `sleep_hint`/`wake_up_hint`).

/// How a thread waits for a condition that another thread will set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitPolicy {
    /// Tight loop, no `pause` — burns issue slots of the SMT sibling
    /// (included to demonstrate why `pause` matters on SMT).
    SpinBusy,
    /// Tight loop with `pause` (x86) / spin-loop hint — Relic's default.
    SpinPause,
    /// Spin `spins` times with `pause`, then park the OS thread
    /// (the classic hybrid; wake costs a futex syscall + scheduler trip).
    Hybrid { spins: u32 },
    /// Park immediately (models condvar-style waiting à la GNU OpenMP).
    Park,
}

impl Default for WaitPolicy {
    fn default() -> Self {
        WaitPolicy::SpinPause
    }
}

impl WaitPolicy {
    /// Short human name (used by bench output and the CLI).
    pub fn name(self) -> &'static str {
        match self {
            WaitPolicy::SpinBusy => "spin",
            WaitPolicy::SpinPause => "spin+pause",
            WaitPolicy::Hybrid { .. } => "hybrid",
            WaitPolicy::Park => "park",
        }
    }

    /// All policies swept by the A2 ablation.
    pub fn all() -> [WaitPolicy; 4] {
        [
            WaitPolicy::SpinBusy,
            WaitPolicy::SpinPause,
            WaitPolicy::Hybrid { spins: 1 << 12 },
            WaitPolicy::Park,
        ]
    }
}

/// Spin until `cond()` holds, following `policy`. Returns the number of
/// loop iterations (useful for tests and for the simulator's
/// calibration).
///
/// With `Hybrid`/`Park` the caller must arrange for the setter to call
/// [`std::thread::Thread::unpark`] on this thread after establishing the
/// condition; `wait_until` re-checks on every wakeup so spurious unparks
/// are harmless.
pub fn wait_until<F: Fn() -> bool>(policy: WaitPolicy, cond: F) -> u64 {
    let mut iters = 0u64;
    match policy {
        WaitPolicy::SpinBusy => {
            while !cond() {
                iters += 1;
            }
        }
        WaitPolicy::SpinPause => {
            while !cond() {
                std::hint::spin_loop();
                iters += 1;
            }
        }
        WaitPolicy::Hybrid { spins } => {
            while !cond() {
                if iters < spins as u64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::park();
                }
                iters += 1;
            }
        }
        WaitPolicy::Park => {
            while !cond() {
                std::thread::park();
                iters += 1;
            }
        }
    }
    iters
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    #[test]
    fn immediate_condition_returns_zero_iters() {
        for p in WaitPolicy::all() {
            assert_eq!(wait_until(p, || true), 0, "{}", p.name());
        }
    }

    #[test]
    fn spin_policies_observe_flag_from_other_thread() {
        for p in [WaitPolicy::SpinBusy, WaitPolicy::SpinPause] {
            let flag = Arc::new(AtomicBool::new(false));
            let setter = {
                let flag = Arc::clone(&flag);
                std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    flag.store(true, Ordering::Release);
                })
            };
            wait_until(p, || flag.load(Ordering::Acquire));
            setter.join().unwrap();
        }
    }

    #[test]
    fn park_policies_wake_on_unpark() {
        for p in [WaitPolicy::Hybrid { spins: 4 }, WaitPolicy::Park] {
            let flag = Arc::new(AtomicBool::new(false));
            let waiter = {
                let flag = Arc::clone(&flag);
                std::thread::spawn(move || {
                    wait_until(p, || flag.load(Ordering::Acquire));
                })
            };
            std::thread::sleep(std::time::Duration::from_millis(5));
            flag.store(true, Ordering::Release);
            waiter.thread().unpark();
            waiter.join().unwrap();
        }
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(WaitPolicy::SpinPause.name(), "spin+pause");
        assert_eq!(WaitPolicy::Hybrid { spins: 1 }.name(), "hybrid");
    }
}
