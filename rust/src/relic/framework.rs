//! The Relic framework proper (paper §VI).
//!
//! One *main* thread (the producer — the only thread allowed to submit)
//! and one *assistant* thread (the consumer — the only thread allowed to
//! run tasks), joined by the lock-free SPSC queue. No work stealing, no
//! recursive tasks, busy-waiting with `pause` on both sides, and
//! explicit `wake_up_hint` / `sleep_hint` control of the assistant for
//! applications with long serial phases.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::affinity::pin_to_cpu;
use super::parallel::Schedule;
use super::spsc::SpscQueue;
use super::wait::WaitPolicy;

/// Queue capacity used in the paper (§VI-A).
pub const DEFAULT_QUEUE_CAPACITY: usize = 128;

/// Smallest publish block [`Relic::run_batch`] uses: batches up to this
/// size are published with a single release store (the PR 1 behavior).
pub const MIN_BATCH_BLOCK: usize = 32;

/// Largest publish block [`Relic::run_batch`] uses (half the paper's
/// queue capacity, so one block never monopolizes the queue).
pub const MAX_BATCH_BLOCK: usize = 64;

/// Spin iterations before a waiting thread starts yielding its
/// timeslice — a degraded-host escape hatch, unreachable during
/// µs-scale waits on a real SMT pair.
const YIELD_THRESHOLD: u32 = 10_000;

/// A submitted task: routine + argument pointer (the paper's
/// `submit()` signature: "passing pointers to a task routine and its
/// arguments"), plus an integer argument word so the `fn(usize)` fast
/// path needs no allocation (EXPERIMENTS.md §Perf iteration 2).
#[derive(Clone, Copy)]
struct Task {
    routine: unsafe fn(*const (), usize),
    data: *const (),
    arg: usize,
}

// SAFETY: tasks cross to the assistant thread; validity and Sync-ness of
// `data` is the submitting wrapper's obligation (see `submit`/`pair`).
unsafe impl Send for Task {}

/// Trampoline for borrowed-closure tasks (`submit_ref` / `run_batch`).
unsafe fn call_ref<F: Fn() + Sync>(data: *const (), _arg: usize) {
    // SAFETY: `data` was created from an `&F` by the submitting wrapper,
    // whose contract keeps the borrow alive until a wait() completes the
    // task.
    (*(data as *const F))();
}

#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Running = 0,
    Sleeping = 1,
    Stopping = 2,
}

struct Shared {
    queue: SpscQueue<Task>,
    /// Tasks finished by the assistant.
    completed: AtomicU64,
    /// Lifecycle (Running / Sleeping / Stopping).
    state: AtomicU8,
    /// Set by the assistant just before parking (so the producer knows
    /// an unpark is needed — kept out of the submit fast path otherwise).
    parked: AtomicBool,
}

/// Configuration for a [`Relic`] instance.
#[derive(Debug, Clone)]
pub struct RelicConfig {
    /// SPSC queue capacity (paper: 128).
    pub queue_capacity: usize,
    /// Assistant-side waiting policy (paper: busy-wait with `pause`).
    pub wait_policy: WaitPolicy,
    /// Pin the assistant thread to this logical CPU (the application is
    /// expected to pin the main thread itself — paper §VI-B).
    pub assistant_cpu: Option<usize>,
    /// Default chunk-assignment schedule for the fork-join helpers:
    /// every [`crate::relic::Par::Relic`] loop that does not pick a
    /// schedule per loop (`Par::with_schedule`) uses this one.
    pub schedule: Schedule,
}

impl Default for RelicConfig {
    fn default() -> Self {
        RelicConfig {
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            wait_policy: WaitPolicy::SpinPause,
            assistant_cpu: None,
            schedule: Schedule::Static,
        }
    }
}

/// Counters exposed for profiling (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RelicStats {
    /// Tasks submitted so far.
    pub submitted: u64,
    /// Tasks completed by the assistant.
    pub completed: u64,
    /// `submit` calls that found the queue full.
    pub queue_full_events: u64,
    /// Fork-join chunks the main thread ran through the claim path:
    /// static-split chunks it claimed back from the assistant plus
    /// dynamic chunks it claimed from the shared cursor. High values
    /// relative to the chunk volume mean the assistant contributed
    /// little — load imbalance made measurable (ISSUE 3).
    pub helped_chunks: u64,
    /// Fork-join chunks that ran inline on the main thread because the
    /// SPSC queue was full when their task (or their wave's task) was
    /// submitted.
    pub inline_fallback: u64,
    /// Parallel loops that asked for [`Schedule::EdgeBalanced`] without
    /// supplying work boundaries ([`crate::relic::Grain::Elems`] call
    /// sites) and were run under [`Schedule::Dynamic`] instead. The
    /// substitution used to be silent (ISSUE 9); now every occurrence
    /// is counted, so a profile showing zero edge-balanced benefit can
    /// be told apart from one that never ran edge-balanced at all.
    pub schedule_downgrades: u64,
}

impl RelicStats {
    /// One-line human-readable report, shared by `repro intra` and the
    /// fork-join benches so every surface prints the same fields.
    pub fn report(&self) -> String {
        let mut line = format!(
            "{} tasks submitted, {} completed, {} queue-full events, \
             {} helped chunks (main-thread claims), {} inline-fallback chunks",
            self.submitted,
            self.completed,
            self.queue_full_events,
            self.helped_chunks,
            self.inline_fallback
        );
        // Silent at zero so the pre-plan surfaces print unchanged.
        if self.schedule_downgrades > 0 {
            line += &format!(
                ", {} schedule downgrades (edge-balanced without bounds -> dynamic)",
                self.schedule_downgrades
            );
        }
        line
    }
}

/// The Relic runtime handle, owned by the main thread.
///
/// Not `Sync`: only the creating (main) thread may submit — Relic's
/// single-producer restriction is enforced by the type system.
pub struct Relic {
    shared: Arc<Shared>,
    submitted: Cell<u64>,
    queue_full: Cell<u64>,
    helped: Cell<u64>,
    inline_fallback: Cell<u64>,
    schedule_downgrades: Cell<u64>,
    /// True while a [`scope`](Self::scope) is active (fork-join sections
    /// may not nest — see `relic::scope`).
    in_scope: Cell<bool>,
    /// Default schedule for fork-join loops (from [`RelicConfig`]).
    schedule: Schedule,
    assistant: Option<JoinHandle<()>>,
}

/// Error returned by [`Relic::submit`] when the SPSC queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull;

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Relic SPSC queue is full")
    }
}

impl std::error::Error for QueueFull {}

impl Relic {
    /// Start a Relic runtime with the paper's defaults.
    pub fn new() -> Self {
        Self::with_config(RelicConfig::default())
    }

    /// Start a Relic runtime with explicit configuration.
    pub fn with_config(config: RelicConfig) -> Self {
        let shared = Arc::new(Shared {
            queue: SpscQueue::new(config.queue_capacity),
            completed: AtomicU64::new(0),
            state: AtomicU8::new(State::Running as u8),
            parked: AtomicBool::new(false),
        });
        let assistant = {
            let shared = Arc::clone(&shared);
            let policy = config.wait_policy;
            let cpu = config.assistant_cpu;
            std::thread::Builder::new()
                .name("relic-assistant".into())
                .spawn(move || assistant_loop(&shared, policy, cpu))
                .expect("failed to spawn relic assistant")
        };
        Relic {
            shared,
            submitted: Cell::new(0),
            queue_full: Cell::new(0),
            helped: Cell::new(0),
            inline_fallback: Cell::new(0),
            schedule_downgrades: Cell::new(0),
            in_scope: Cell::new(false),
            schedule: config.schedule,
            assistant: Some(assistant),
        }
    }

    /// The schedule [`crate::relic::Par::Relic`] loops use when none is
    /// set per loop (see [`RelicConfig::schedule`]).
    pub fn default_schedule(&self) -> Schedule {
        self.schedule
    }

    /// Record one fork-join chunk the main thread ran through the claim
    /// path (scope-layer bookkeeping; main thread only).
    pub(crate) fn note_helped(&self) {
        self.helped.set(self.helped.get() + 1);
    }

    /// Record `chunks` fork-join chunks that ran inline because the
    /// SPSC queue was full at submit time (main thread only).
    pub(crate) fn note_inline_fallback(&self, chunks: u64) {
        self.inline_fallback.set(self.inline_fallback.get() + chunks);
    }

    /// Record one parallel loop that requested [`Schedule::EdgeBalanced`]
    /// without work boundaries and fell back to [`Schedule::Dynamic`]
    /// (main thread only; see [`RelicStats::schedule_downgrades`]).
    pub(crate) fn note_schedule_downgrade(&self) {
        self.schedule_downgrades.set(self.schedule_downgrades.get() + 1);
    }

    /// Submit a raw routine/data task — the untyped core the safe
    /// fork-join layer ([`crate::relic::Scope`]) builds on.
    ///
    /// The caller guarantees `data` stays valid (and unmoved) until the
    /// task completes, and that the routine is safe to run on the
    /// assistant thread.
    pub(crate) fn submit_raw(
        &self,
        routine: unsafe fn(*const (), usize),
        data: *const (),
    ) -> Result<(), QueueFull> {
        self.push(Task { routine, data, arg: 0 }).map_err(|_| QueueFull)
    }

    /// Mark this runtime as inside a fork-join scope. Returns `false`
    /// (without changing state) when a scope is already active.
    pub(crate) fn enter_scope(&self) -> bool {
        !self.in_scope.replace(true)
    }

    /// Leave the fork-join scope entered with [`enter_scope`](Self::enter_scope).
    pub(crate) fn exit_scope(&self) {
        self.in_scope.set(false);
    }

    /// Submit a task as a plain function pointer + integer argument —
    /// the allocation-free fast path matching the paper's C interface
    /// (the fn pointer travels in the task's data word; no heap).
    pub fn submit(&self, routine: fn(usize), arg: usize) -> Result<(), QueueFull> {
        unsafe fn call_fn(data: *const (), arg: usize) {
            // SAFETY: `data` was produced from a valid `fn(usize)` below;
            // plain-fn pointers round-trip through raw pointers.
            let f: fn(usize) = std::mem::transmute(data);
            f(arg);
        }
        let task = Task { routine: call_fn, data: routine as *const (), arg };
        self.push(task).map_err(|_| QueueFull)
    }

    /// Submit a borrowed closure. The closure **must stay alive and
    /// unmoved until [`wait`](Self::wait) returns**; enforce with the
    /// safe [`pair`](Self::pair) / [`run_batch`](Self::run_batch)
    /// wrappers wherever possible. This is the zero-allocation path used
    /// by the fine-grained benchmarks.
    ///
    /// # Safety
    /// `f` must outlive the completion of this task (i.e. a subsequent
    /// `wait()` on this thread), and must be safe to call from the
    /// assistant thread (`Sync`).
    pub unsafe fn submit_ref<F: Fn() + Sync>(&self, f: &F) -> Result<(), QueueFull> {
        let task =
            Task { routine: call_ref::<F>, data: f as *const F as *const (), arg: 0 };
        self.push(task).map_err(|_| QueueFull)
    }

    /// Run `a` on the calling (main) thread and `b` on the assistant in
    /// parallel, returning when both finish — the paper's benchmark
    /// protocol (§IV: "we run two instances of the same kernel in
    /// parallel"). Falls back to serial execution if the queue is full.
    pub fn pair<A: FnOnce(), B: Fn() + Sync>(&self, a: A, b: &B) {
        // SAFETY: we wait() before returning, so `b` outlives its task.
        let submitted = unsafe { self.submit_ref(b) }.is_ok();
        a();
        if submitted {
            self.wait();
        } else {
            b();
        }
    }

    /// Submit every closure in `tasks` and wait for all of them.
    /// Closures the queue cannot hold run inline on the main thread —
    /// Relic never blocks the producer on a full queue.
    ///
    /// Tasks are published in blocks through [`SpscQueue::push_many`],
    /// so a batch of N pays one release store (and at most one unpark
    /// check) per block instead of one per task. The block size scales
    /// with the batch (~¼ of it) instead of a fixed constant: batches
    /// up to [`MIN_BATCH_BLOCK`] publish in a single store, larger ones
    /// split into a few blocks so the assistant starts draining while
    /// later blocks are still being published — capped at
    /// [`MAX_BATCH_BLOCK`] to bound the stack block and stay well under
    /// the queue capacity.
    pub fn run_batch<F: Fn() + Sync>(&self, tasks: &[F]) {
        let block_len = tasks.len().div_ceil(4).clamp(MIN_BATCH_BLOCK, MAX_BATCH_BLOCK);
        for chunk in tasks.chunks(block_len) {
            let mut block = [Task { routine: call_ref::<F>, data: std::ptr::null(), arg: 0 };
                MAX_BATCH_BLOCK];
            for (slot, t) in block.iter_mut().zip(chunk) {
                slot.data = t as *const F as *const ();
            }
            let pushed = self.push_batch(&block[..chunk.len()]);
            // Overflow runs inline in submission order — Relic never
            // blocks the producer on a full queue.
            for t in &chunk[pushed..] {
                t();
            }
        }
        self.wait();
    }

    /// Publish a block of tasks with one release store; returns how many
    /// fit (a prefix of `tasks`). Counters and the parked-assistant
    /// handshake match [`push`](Self::push), paid once per block.
    fn push_batch(&self, tasks: &[Task]) -> usize {
        let n = self.shared.queue.push_many(tasks);
        if n > 0 {
            self.submitted.set(self.submitted.get() + n as u64);
            // Same Dekker store-load handshake as `push`.
            std::sync::atomic::fence(Ordering::SeqCst);
            if self.shared.parked.load(Ordering::Acquire) {
                if let Some(h) = &self.assistant {
                    h.thread().unpark();
                }
            }
        }
        self.queue_full.set(self.queue_full.get() + (tasks.len() - n) as u64);
        n
    }

    fn push(&self, task: Task) -> Result<(), Task> {
        let r = self.shared.queue.push(task);
        if r.is_ok() {
            self.submitted.set(self.submitted.get() + 1);
            // Assistant may be parked (Hybrid/Park policies or sleep_hint
            // race); wake it. The SeqCst fence pairs with the assistant's
            // SeqCst parked-store/queue-check so exactly one of us sees
            // the other (classic Dekker store-load handshake).
            std::sync::atomic::fence(Ordering::SeqCst);
            if self.shared.parked.load(Ordering::Acquire) {
                if let Some(h) = &self.assistant {
                    h.thread().unpark();
                }
            }
        } else {
            self.queue_full.set(self.queue_full.get() + 1);
        }
        r
    }

    /// Wait for every submitted task to complete (paper `wait()`):
    /// busy-waits with `pause` on the completion counter.
    pub fn wait(&self) {
        let target = self.submitted.get();
        if self.shared.completed.load(Ordering::Acquire) >= target {
            return;
        }
        // Recover from a sleep_hint left active across submissions —
        // otherwise the assistant never drains and we spin forever.
        if self.shared.state.load(Ordering::Acquire) == State::Sleeping as u8 {
            self.wake_up_hint();
        }
        // Busy-wait with pause (the paper's design). The yield escape
        // only fires after ~10k spins — far beyond any µs-scale task on
        // a real SMT sibling — and keeps single-CPU hosts (where main
        // spinning would starve the assistant for a whole scheduling
        // quantum) functional; see EXPERIMENTS.md §Perf iteration 4.
        let mut spins = 0u32;
        while self.shared.completed.load(Ordering::Acquire) < target {
            std::hint::spin_loop();
            spins += 1;
            if spins >= YIELD_THRESHOLD {
                std::thread::yield_now();
                // Restart the spin budget: yielding once must not turn
                // the remainder of the wait into a yield-per-iteration
                // loop (each yield is a scheduler round trip).
                spins = 0;
            }
        }
    }

    /// Hint that parallel work is imminent: ensure the assistant is
    /// awake and spinning (paper `wake_up_hint()`).
    pub fn wake_up_hint(&self) {
        self.shared.state.store(State::Running as u8, Ordering::Release);
        if let Some(h) = &self.assistant {
            h.thread().unpark();
        }
    }

    /// Hint that a long serial phase follows: the assistant parks and
    /// stops consuming core resources (paper `sleep_hint()`).
    pub fn sleep_hint(&self) {
        self.shared.state.store(State::Sleeping as u8, Ordering::Release);
    }

    /// Profiling counters.
    pub fn stats(&self) -> RelicStats {
        RelicStats {
            submitted: self.submitted.get(),
            completed: self.shared.completed.load(Ordering::Acquire),
            queue_full_events: self.queue_full.get(),
            helped_chunks: self.helped.get(),
            inline_fallback: self.inline_fallback.get(),
            schedule_downgrades: self.schedule_downgrades.get(),
        }
    }
}

impl Default for Relic {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Relic {
    fn drop(&mut self) {
        // Drain obligations before stopping so no submitted task is lost.
        self.wait();
        self.shared.state.store(State::Stopping as u8, Ordering::Release);
        if let Some(h) = self.assistant.take() {
            h.thread().unpark();
            let _ = h.join();
        }
    }
}

/// The assistant thread's main loop — pseudocode of the paper's Fig. 2.
fn assistant_loop(shared: &Shared, policy: WaitPolicy, cpu: Option<usize>) {
    if let Some(cpu) = cpu {
        pin_to_cpu(cpu);
    }
    let mut idle_spins: u32 = 0;
    loop {
        if let Some(task) = shared.queue.pop() {
            idle_spins = 0;
            // SAFETY: submitters guarantee task validity until completion.
            unsafe { (task.routine)(task.data, task.arg) };
            shared.completed.fetch_add(1, Ordering::Release);
            continue;
        }
        match shared.state.load(Ordering::Acquire) {
            s if s == State::Stopping as u8 => break,
            s if s == State::Sleeping as u8 => {
                shared.parked.store(true, Ordering::SeqCst);
                // Re-check after announcing: a submit/wake may have raced.
                if shared.state.load(Ordering::Acquire) == State::Sleeping as u8
                    && shared.queue.is_empty()
                {
                    std::thread::park();
                }
                shared.parked.store(false, Ordering::SeqCst);
            }
            _ => match policy {
                WaitPolicy::SpinBusy => {}
                WaitPolicy::SpinPause => {
                    std::hint::spin_loop();
                    idle_spins += 1;
                    if idle_spins >= YIELD_THRESHOLD {
                        std::thread::yield_now();
                        // Same spin-budget reset as `Relic::wait`.
                        idle_spins = 0;
                    }
                }
                WaitPolicy::Hybrid { spins } => {
                    if idle_spins < spins {
                        std::hint::spin_loop();
                        idle_spins += 1;
                    } else {
                        shared.parked.store(true, Ordering::SeqCst);
                        if shared.queue.is_empty()
                            && shared.state.load(Ordering::Acquire)
                                == State::Running as u8
                        {
                            std::thread::park();
                        }
                        shared.parked.store(false, Ordering::SeqCst);
                        idle_spins = 0;
                    }
                }
                WaitPolicy::Park => {
                    shared.parked.store(true, Ordering::SeqCst);
                    if shared.queue.is_empty()
                        && shared.state.load(Ordering::Acquire) == State::Running as u8
                    {
                        std::thread::park();
                    }
                    shared.parked.store(false, Ordering::SeqCst);
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static COUNTER: AtomicUsize = AtomicUsize::new(0);

    fn bump(by: usize) {
        COUNTER.fetch_add(by, Ordering::SeqCst);
    }

    #[test]
    fn submit_fn_runs_on_assistant() {
        let relic = Relic::new();
        COUNTER.store(0, Ordering::SeqCst);
        for i in 0..10 {
            relic.submit(bump, i).unwrap();
        }
        relic.wait();
        assert_eq!(COUNTER.load(Ordering::SeqCst), 45);
        let s = relic.stats();
        assert_eq!(s.submitted, 10);
        assert_eq!(s.completed, 10);
    }

    #[test]
    fn pair_runs_both_sides() {
        let relic = Relic::new();
        let a_ran = AtomicUsize::new(0);
        let b_ran = AtomicUsize::new(0);
        relic.pair(|| { a_ran.fetch_add(1, Ordering::SeqCst); },
                   &|| { b_ran.fetch_add(1, Ordering::SeqCst); });
        assert_eq!(a_ran.load(Ordering::SeqCst), 1);
        assert_eq!(b_ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn run_batch_completes_all() {
        let relic = Relic::new();
        let sum = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..200usize)
            .map(|i| {
                let sum = &sum;
                move || {
                    sum.fetch_add(i, Ordering::SeqCst);
                }
            })
            .collect();
        relic.run_batch(&tasks);
        assert_eq!(sum.load(Ordering::SeqCst), 199 * 200 / 2);
    }

    #[test]
    fn run_batch_block_sizing_covers_all_lengths() {
        // Lengths straddling the sizing breakpoints: single-store
        // batches (≤ MIN_BATCH_BLOCK), ~len/4 blocks in between, and
        // the MAX_BATCH_BLOCK cap (≥ 256).
        let relic = Relic::new();
        for n in [0usize, 1, 3, 4, 5, 31, 32, 33, 255, 256, 257, 500] {
            let sum = AtomicUsize::new(0);
            let tasks: Vec<_> = (0..n)
                .map(|i| {
                    let sum = &sum;
                    move || {
                        sum.fetch_add(i + 1, Ordering::SeqCst);
                    }
                })
                .collect();
            relic.run_batch(&tasks);
            assert_eq!(sum.load(Ordering::SeqCst), n * (n + 1) / 2, "n={n}");
        }
    }

    #[test]
    fn queue_full_falls_back_serially() {
        let relic = Relic::with_config(RelicConfig {
            queue_capacity: 2,
            ..RelicConfig::default()
        });
        let sum = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..100usize)
            .map(|_| {
                let sum = &sum;
                move || {
                    sum.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        relic.run_batch(&tasks);
        assert_eq!(sum.load(Ordering::SeqCst), 100, "no task lost on overflow");
    }

    #[test]
    fn sleep_and_wake_hints() {
        let relic = Relic::new();
        relic.sleep_hint();
        std::thread::sleep(std::time::Duration::from_millis(2));
        relic.wake_up_hint();
        let ran = AtomicUsize::new(0);
        relic.pair(|| {}, &|| { ran.fetch_add(1, Ordering::SeqCst); });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn wait_recovers_from_sleeping_assistant() {
        let relic = Relic::new();
        let ran = AtomicUsize::new(0);
        relic.sleep_hint();
        // Submit while asleep; wait() must auto-wake (documented recovery).
        let task = || {
            ran.fetch_add(1, Ordering::SeqCst);
        };
        // SAFETY: wait() before task drops.
        unsafe { relic.submit_ref(&task).unwrap() };
        relic.wait();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn hybrid_and_park_policies_work() {
        for policy in [WaitPolicy::Hybrid { spins: 64 }, WaitPolicy::Park] {
            let relic = Relic::with_config(RelicConfig {
                wait_policy: policy,
                ..RelicConfig::default()
            });
            let n = AtomicUsize::new(0);
            for round in 0..20 {
                // Let the assistant park between rounds.
                if round % 5 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                relic.pair(|| {}, &|| { n.fetch_add(1, Ordering::SeqCst); });
            }
            assert_eq!(n.load(Ordering::SeqCst), 20, "{:?}", policy);
        }
    }

    #[test]
    fn drop_waits_for_outstanding_tasks() {
        COUNTER.store(0, Ordering::SeqCst);
        {
            let relic = Relic::new();
            for _ in 0..50 {
                relic.submit(bump, 1).unwrap();
            }
            // No explicit wait: Drop must flush.
        }
        assert_eq!(COUNTER.load(Ordering::SeqCst), 50);
    }
}
