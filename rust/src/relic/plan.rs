//! First-class execution plans: *how* a kernel's hot loops should run.
//!
//! Before this module the decision was scattered: kernels hardcoded a
//! grain, callers picked a [`Schedule`] per loop, config toggled a
//! runtime default, and the cross-shard borrow cap lived in yet another
//! knob. An [`ExecutionPlan`] folds all of it into one `Copy` value
//! that flows config → engine → shard → kernel call site unchanged, so
//! the coordinator's online tuner (`coordinator::tuner`) can swap whole
//! plans per (kernel, graph-shape) instead of twiddling four knobs.
//!
//! A plan changes *assignment only*: which thread runs which chunk, or
//! whether the request forks at all. Chunk boundaries stay a pure
//! function of `(range, grain, schedule)`, so every plan yields results
//! bitwise-equal to serial — the repo's standing determinism contract.
//!
//! ```
//! use relic_smt::relic::{ExecutionPlan, ParMode, Schedule};
//!
//! let plan = ExecutionPlan::parse("pair:edge-balanced:32").unwrap();
//! assert_eq!(plan.par_mode, ParMode::Pair);
//! assert_eq!(plan.schedule, Schedule::EdgeBalanced);
//! assert_eq!(plan.grain_or(16), 32);
//! assert_eq!(ExecutionPlan::parse(&plan.name()), Some(plan), "name round-trips");
//! // Grain 0 defers to the kernel's own default:
//! assert_eq!(ExecutionPlan::default().grain_or(16), 16);
//! assert_eq!(ExecutionPlan::parse("serial"), Some(ExecutionPlan::serial()));
//! ```

use super::parallel::{Par, Schedule};

/// Whether a kernel's loops run on one thread or fork over the pair.
///
/// `Serial` is a real plan, not an absence of one: on sub-grain inputs
/// the submit/wait handshake costs more than it buys, and the tuner
/// must be able to *choose* that (the source paper's §IV crossover).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParMode {
    /// Plain serial loops on the serving thread.
    Serial,
    /// Intra-kernel fork-join over the SMT pair.
    #[default]
    Pair,
}

/// One complete execution decision for a kernel invocation.
///
/// The four fields are exactly the knobs that used to be scattered:
/// serial vs pair ([`ParMode`]), chunk assignment ([`Schedule`]), chunk
/// size (`grain`, 0 = the kernel's own default), and how many idle
/// pair-shards a whale invocation may borrow (`max_borrow_hint`, a
/// *hint* — borrowing still requires a broker and idle lenders).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecutionPlan {
    /// Serial loops, or fork-join over the SMT pair.
    pub par_mode: ParMode,
    /// How parallel chunks are assigned (ignored under `Serial`).
    pub schedule: Schedule,
    /// Minimum indices per chunk; 0 defers to the kernel's default.
    pub grain: usize,
    /// Cross-shard borrow hint; 0 = stay on this pair. Honored only
    /// where a lease broker is actually wired (see `relic::cross`).
    pub max_borrow_hint: usize,
}

impl Default for ExecutionPlan {
    /// Pair-parallel, static assignment, kernel-default grain, no
    /// borrowing — the behavior every kernel had before plans existed.
    fn default() -> Self {
        ExecutionPlan {
            par_mode: ParMode::Pair,
            schedule: Schedule::Static,
            grain: 0,
            max_borrow_hint: 0,
        }
    }
}

impl ExecutionPlan {
    /// The all-serial plan.
    pub fn serial() -> ExecutionPlan {
        ExecutionPlan { par_mode: ParMode::Serial, ..ExecutionPlan::default() }
    }

    /// A pair-parallel plan under `schedule` with kernel-default grain.
    pub fn pair(schedule: Schedule) -> ExecutionPlan {
        ExecutionPlan { schedule, ..ExecutionPlan::default() }
    }

    /// This plan with an explicit grain (0 = kernel default).
    pub fn with_grain(self, grain: usize) -> ExecutionPlan {
        ExecutionPlan { grain, ..self }
    }

    /// The grain a call site should use: the plan's, unless the plan
    /// defers (`grain == 0`) to the kernel's own `default`.
    pub fn grain_or(&self, default: usize) -> usize {
        if self.grain == 0 {
            default
        } else {
            self.grain
        }
    }

    /// Rebind a call site's `Par` under this plan: `Serial` plans force
    /// the plain loop, `Pair` plans keep the runtime (and any attached
    /// cross-shard session) but impose the plan's schedule.
    pub fn apply<'r>(&self, par: &Par<'r>) -> Par<'r> {
        match self.par_mode {
            ParMode::Serial => Par::Serial,
            ParMode::Pair => par.with_schedule(self.schedule),
        }
    }

    /// Canonical spelling, round-trips through [`parse`](Self::parse):
    /// `serial`, `pair:<schedule>`, `pair:<schedule>:<grain>`, or
    /// `pair:<schedule>:<grain>:<borrow>` — trailing zero fields are
    /// omitted.
    pub fn name(&self) -> String {
        match self.par_mode {
            ParMode::Serial => "serial".to_string(),
            ParMode::Pair => {
                let mut s = format!("pair:{}", self.schedule.name());
                if self.grain > 0 || self.max_borrow_hint > 0 {
                    s += &format!(":{}", self.grain);
                }
                if self.max_borrow_hint > 0 {
                    s += &format!(":{}", self.max_borrow_hint);
                }
                s
            }
        }
    }

    /// Parse a CLI/config spelling (see [`name`](Self::name)).
    pub fn parse(s: &str) -> Option<ExecutionPlan> {
        if s == "serial" {
            return Some(ExecutionPlan::serial());
        }
        let mut parts = s.split(':');
        if parts.next()? != "pair" {
            return None;
        }
        let schedule = Schedule::parse(parts.next()?)?;
        let grain = match parts.next() {
            Some(g) => g.parse().ok()?,
            None => 0,
        };
        let max_borrow_hint = match parts.next() {
            Some(b) => b.parse().ok()?,
            None => 0,
        };
        if parts.next().is_some() {
            return None;
        }
        Some(ExecutionPlan { par_mode: ParMode::Pair, schedule, grain, max_borrow_hint })
    }

    /// The tuner's candidate lattice: serial, plus pair-parallel under
    /// every schedule at three grain tiers — the kernel default (0), a
    /// fine tier that halves most kernels' chunks, and a coarse tier
    /// that amortizes the submit/wait handshake on cheap loop bodies.
    /// [`ExecutionPlan::default`] is always a member, so a tuner that
    /// never moves is the pre-plan engine.
    pub fn lattice() -> Vec<ExecutionPlan> {
        let mut arms = vec![ExecutionPlan::serial()];
        for schedule in Schedule::all() {
            for grain in [0usize, 4, 64] {
                arms.push(ExecutionPlan {
                    par_mode: ParMode::Pair,
                    schedule,
                    grain,
                    max_borrow_hint: 0,
                });
            }
        }
        arms
    }
}

impl std::fmt::Display for ExecutionPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relic::Relic;

    #[test]
    fn lattice_round_trips_and_contains_default() {
        let arms = ExecutionPlan::lattice();
        assert_eq!(arms.len(), 1 + 3 * 3, "serial + 3 schedules x 3 grain tiers");
        assert!(arms.contains(&ExecutionPlan::default()));
        assert!(arms.contains(&ExecutionPlan::serial()));
        for arm in &arms {
            assert_eq!(ExecutionPlan::parse(&arm.name()), Some(*arm), "{arm}");
        }
        // No duplicate arms — the tuner keys statistics by index.
        for (i, a) in arms.iter().enumerate() {
            assert!(!arms[i + 1..].contains(a), "duplicate arm {a}");
        }
    }

    #[test]
    fn parse_rejects_junk() {
        let junk = ["", "pair", "pair:nope", "serial:static", "pair:static:x", "pair:static:8:1:9"];
        for bad in junk {
            assert_eq!(ExecutionPlan::parse(bad), None, "{bad:?}");
        }
        let hinted = ExecutionPlan::parse("pair:dynamic:8:2").unwrap();
        assert_eq!(hinted.max_borrow_hint, 2);
        assert_eq!(ExecutionPlan::parse(&hinted.name()), Some(hinted));
    }

    #[test]
    fn apply_rebinds_par() {
        let relic = Relic::new();
        let par = Par::Relic(&relic);
        assert!(!ExecutionPlan::serial().apply(&par).is_parallel());
        let dynamic = ExecutionPlan::pair(Schedule::Dynamic).apply(&par);
        assert!(dynamic.is_parallel());
        assert_eq!(dynamic.schedule(), Schedule::Dynamic);
        // Serial call sites stay serial whatever the plan says.
        assert!(!ExecutionPlan::default().apply(&Par::Serial).is_parallel());
    }

    #[test]
    fn grain_tiers_defer_or_override() {
        assert_eq!(ExecutionPlan::default().with_grain(4).grain_or(16), 4);
        assert_eq!(ExecutionPlan::default().with_grain(0).grain_or(16), 16);
    }
}
