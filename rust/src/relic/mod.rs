//! **Relic** — the paper's specialized framework for extremely
//! fine-grained task parallelism on SMT cores (§VI).
//!
//! Design, verbatim from the paper:
//! * two roles: a *main* (producer) thread and an *assistant* (consumer)
//!   thread — no work stealing, no recursive task submission;
//! * a lock-free single-producer single-consumer queue (capacity 128);
//! * busy-waiting with the x86 `pause` instruction on both sides;
//! * `wake_up_hint()` / `sleep_hint()` so applications with long serial
//!   phases can park the assistant explicitly;
//! * CPU pinning left to the application ([`affinity`] has the helpers).
//!
//! On top of the paper's pairing API sits an intra-kernel fork-join
//! layer ([`scope`] / [`parallel`]): `relic.scope(|s| s.split(..))` and
//! `relic.parallel_for(range, grain, f)` split an index range across
//! the pair — stack-resident chunk descriptors, per-chunk
//! claim/completion flags, zero heap. A [`Schedule`] picks how chunks
//! are *assigned*: `Static` (PR 1's half + ≤8 assistant chunks),
//! `Dynamic` (self-scheduled from a shared atomic cursor — whichever
//! thread is free claims the next chunk), or `EdgeBalanced` (dynamic
//! claiming over work-balanced boundaries bisected from the CSR
//! offsets). Chunk boundaries stay pure functions of the inputs, so
//! results are deterministic under every schedule. The [`Par`] toggle
//! lets the GAP kernels and the JSON parser run their hot loops either
//! serially or across the SMT pair, moving the speedup from "two
//! requests in parallel" to "one request finishes faster".
//!
//! Beyond one core, [`pool`] replicates the paper's pair as the unit of
//! scheduling: a [`RelicPool`] spawns one pinned shard per physical
//! core (each shard's main thread owning its own [`Relic`]), with
//! bounded per-shard admission queues, least-loaded routing, and
//! three admission flavors — blocking backpressure, non-blocking
//! `try_submit_to`, and `submit_or_park_to` (the producer sleeps on the
//! shard's drain signal until its consumer frees capacity) — multi-core
//! scaling without ever widening the SPSC queue to MPMC. A
//! [`pool::Supervisor`] watchdog plus the deterministic [`fault`]
//! injection hooks make each shard a *failure domain*: panics are
//! contained, stuck or dead shards are quarantined and respawned, and
//! their queued work is redirected (see `ARCHITECTURE.md` §Failure
//! domains & recovery).
//!
//! [`cross`] adds the *second* level of fork-join on top of the pool:
//! one whale request can borrow idle sibling shards through a
//! [`LeaseBroker`], fanning its parallel loops out to
//! `2 × (1 + borrowed)` hardware threads while keeping results bitwise
//! identical to the single-pair path — leases are revocable at chunk
//! granularity, so a borrowed shard returns to its own queue the moment
//! real work arrives (see `ARCHITECTURE.md` §Cross-shard cooperation).
//!
//! ```
//! use relic_smt::relic::Relic;
//! use std::sync::atomic::{AtomicU64, Ordering};
//!
//! let relic = Relic::new();
//! let hits = AtomicU64::new(0);
//! // Run two fine-grained tasks in parallel: one on the main thread,
//! // one on the assistant (the paper's benchmark protocol).
//! relic.pair(
//!     || { hits.fetch_add(1, Ordering::Relaxed); },
//!     &|| { hits.fetch_add(1, Ordering::Relaxed); },
//! );
//! assert_eq!(hits.load(Ordering::Relaxed), 2);
//!
//! // …or split one loop across the pair (intra-kernel fork-join):
//! relic.parallel_for(0..1024, 64, |_i| {
//!     hits.fetch_add(1, Ordering::Relaxed);
//! });
//! assert_eq!(hits.load(Ordering::Relaxed), 2 + 1024);
//! ```

pub mod affinity;
pub mod cross;
pub mod fault;
mod framework;
pub mod parallel;
pub mod plan;
pub mod pool;
pub mod scope;
mod spsc;
pub mod wait;

pub use cross::{
    cross_chunk_count, with_lease, CrossCtx, CrossSession, LeaseBroker, LeaseStats,
    MAX_CROSS_CHUNKS,
};
pub use fault::{FaultKind, FaultPlan};
pub use framework::{
    QueueFull, Relic, RelicConfig, RelicStats, DEFAULT_QUEUE_CAPACITY, MAX_BATCH_BLOCK,
    MIN_BATCH_BLOCK,
};
pub use parallel::{Grain, Par, Schedule, DEFAULT_GRAIN};
pub use plan::{ExecutionPlan, ParMode};
pub use pool::{
    BudgetPolicy, IdleHook, PoolConfig, PoolSnapshot, RelicPool, ShardDead, ShardHealth,
    ShardPlacement, ShardStatus, Supervisor, SupervisorConfig, SupervisorVerdict,
};
pub use scope::{dyn_chunk_count, Scope, MAX_ASSIST_CHUNKS, MAX_CHUNK_SLOTS, MAX_DYN_CHUNKS};
pub use spsc::SpscQueue;
pub use wait::WaitPolicy;
