//! **Relic** — the paper's specialized framework for extremely
//! fine-grained task parallelism on SMT cores (§VI).
//!
//! Design, verbatim from the paper:
//! * two roles: a *main* (producer) thread and an *assistant* (consumer)
//!   thread — no work stealing, no recursive task submission;
//! * a lock-free single-producer single-consumer queue (capacity 128);
//! * busy-waiting with the x86 `pause` instruction on both sides;
//! * `wake_up_hint()` / `sleep_hint()` so applications with long serial
//!   phases can park the assistant explicitly;
//! * CPU pinning left to the application ([`affinity`] has the helpers).
//!
//! ```
//! use relic_smt::relic::Relic;
//! use std::sync::atomic::{AtomicU64, Ordering};
//!
//! let relic = Relic::new();
//! let hits = AtomicU64::new(0);
//! // Run two fine-grained tasks in parallel: one on the main thread,
//! // one on the assistant (the paper's benchmark protocol).
//! relic.pair(
//!     || { hits.fetch_add(1, Ordering::Relaxed); },
//!     &|| { hits.fetch_add(1, Ordering::Relaxed); },
//! );
//! assert_eq!(hits.load(Ordering::Relaxed), 2);
//! ```

pub mod affinity;
mod framework;
mod spsc;
pub mod wait;

pub use framework::{QueueFull, Relic, RelicConfig, RelicStats, DEFAULT_QUEUE_CAPACITY};
pub use spsc::SpscQueue;
pub use wait::WaitPolicy;
