//! **RelicPool** — a pool of pinned pair-shards: one Relic SMT pair per
//! physical core.
//!
//! The paper scopes Relic to *one* SMT core: one main (producer) thread
//! and one assistant (consumer) thread over a lock-free SPSC queue.
//! Scaling that to a whole machine could widen the queue to MPMC — but
//! that would forfeit exactly what makes Relic fast: the single-producer
//! single-consumer invariant is what lets `push`/`pop` run lock-free
//! with one release store and no CAS on the hot path, and the pair's
//! cache affinity (both threads on one core's L1/L2) is the paper's
//! whole premise. So the pool **replicates the pair instead of widening
//! it** (the FastFlow lesson: SPSC channels compose into larger
//! topologies without giving up their guarantees):
//!
//! * topology discovery parses
//!   `/sys/devices/system/cpu/cpu*/topology/thread_siblings_list` into
//!   SMT sibling pairs (with a portable adjacent-CPU fallback pairing);
//! * one **shard** per physical core: a dedicated main thread, pinned
//!   to the pair's first logical CPU, that *owns* its shard state —
//!   typically a [`crate::coordinator::Coordinator`], whose embedded
//!   [`super::Relic`] pins its assistant to the sibling. Each Relic is
//!   created on, and only ever submitted to from, its shard thread, so
//!   the single-producer invariant holds *by construction*;
//! * an **admission layer**: items are dispatched to shards over
//!   per-shard bounded channels with least-loaded routing; when the
//!   chosen shard's channel is full the submitter blocks on that same
//!   channel (backpressure — counted, never dropped, never reordered
//!   within a shard);
//! * a shard's inner loop drains its channel into small batches, so a
//!   batch handler built on `Coordinator::process_batch` still gets to
//!   pair requests two-at-a-time and run the odd leftover with
//!   intra-request fork-join — the paper's fine-grained scenario is
//!   preserved *inside* every shard.
//!
//! The pool is generic over the item type `I` and the shard state `S`
//! (built on the shard thread by a factory, so `S` need not be `Send`);
//! [`crate::coordinator::Engine`] instantiates it with
//! `I = sequenced Request`, `S = Coordinator`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::metrics::Counter;

use super::affinity::{num_cpus, parse_cpulist, pin_to_cpu, sibling_lists};

/// Default bound of each shard's admission channel.
pub const DEFAULT_CHANNEL_CAPACITY: usize = 64;

/// Default maximum items a shard's inner loop hands its batch handler.
pub const DEFAULT_MAX_BATCH: usize = 32;

/// Pool sizing and placement knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Number of shards; `None` = one per detected physical core.
    pub shards: Option<usize>,
    /// Pin shard main threads (and their Relic assistants) to sibling
    /// pairs. Disable on hosts where affinity calls are denied.
    pub pin: bool,
    /// Per-shard bounded channel depth (admission backpressure point).
    pub channel_capacity: usize,
    /// Maximum items per batch handed to the shard's inner loop.
    pub max_batch: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: None,
            pin: true,
            channel_capacity: DEFAULT_CHANNEL_CAPACITY,
            max_batch: DEFAULT_MAX_BATCH,
        }
    }
}

/// Where one shard runs: its main thread's CPU and its Relic
/// assistant's CPU (`None` = unpinned).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlacement {
    pub shard: usize,
    pub main_cpu: Option<usize>,
    pub assistant_cpu: Option<usize>,
}

/// Parse sysfs `thread_siblings_list` contents into deduplicated SMT
/// sibling pairs, sorted by first CPU. Each sibling's file names the
/// same pair, so the raw list contains every pair twice; lists with
/// fewer than two CPUs (no SMT) and unparsable entries are skipped.
pub fn sibling_pairs_from_lists<'a, I>(lists: I) -> Vec<(usize, usize)>
where
    I: IntoIterator<Item = &'a str>,
{
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for text in lists {
        let cpus = parse_cpulist(text);
        if cpus.len() >= 2 {
            let key = (cpus[0].min(cpus[1]), cpus[0].max(cpus[1]));
            if !pairs.contains(&key) {
                pairs.push(key);
            }
        }
    }
    pairs.sort_unstable();
    pairs
}

/// Portable fallback pairing when sysfs exposes no sibling topology:
/// adjacent logical CPUs `(2i, 2i+1)`. Not true SMT siblings, but the
/// pinning still gives each shard two stable, distinct CPUs.
pub fn fallback_pairs(cpus: usize) -> Vec<(usize, usize)> {
    (0..cpus / 2).map(|i| (2 * i, 2 * i + 1)).collect()
}

/// The host's physical-core pairs: sysfs SMT siblings where available,
/// otherwise the adjacent-CPU fallback (which may be empty on a
/// single-CPU host — callers fall back to unpinned shards).
pub fn physical_core_pairs() -> Vec<(usize, usize)> {
    let lists = sibling_lists();
    let pairs = sibling_pairs_from_lists(lists.iter().map(String::as_str));
    if pairs.is_empty() {
        fallback_pairs(num_cpus())
    } else {
        pairs
    }
}

/// Decide shard placements: `want` shards (default: one per physical
/// core, minimum one), pinned onto the discovered pairs in order.
/// Shards beyond the available pairs — or all shards when `pin` is
/// false — run unpinned.
pub fn discover_placements(want: Option<usize>, pin: bool) -> Vec<ShardPlacement> {
    let pairs = if pin { physical_core_pairs() } else { Vec::new() };
    let n = want.unwrap_or_else(|| pairs.len().max(1)).max(1);
    (0..n)
        .map(|shard| match pairs.get(shard) {
            Some(&(a, b)) if pin => ShardPlacement {
                shard,
                main_cpu: Some(a),
                assistant_cpu: Some(b),
            },
            _ => ShardPlacement { shard, main_cpu: None, assistant_cpu: None },
        })
        .collect()
}

/// Pool-level admission counters.
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Items routed to a shard.
    pub dispatched: Counter,
    /// Submissions that found the chosen shard's channel full and had
    /// to block (backpressure events; the item is still delivered).
    pub backpressure_stalls: Counter,
}

/// Point-in-time view of the pool (see [`RelicPool::snapshot`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolSnapshot {
    pub shards: usize,
    pub dispatched: u64,
    pub backpressure_stalls: u64,
    /// Items completed per shard (shard occupancy over the run).
    pub occupancy: Vec<u64>,
    /// Items queued or in processing per shard right now.
    pub in_flight: Vec<usize>,
}

/// Per-shard bookkeeping kept on the admission side.
struct ShardInfo {
    placement: ShardPlacement,
    /// Items queued or being processed (incremented at submit,
    /// decremented by the shard after each batch) — the least-loaded
    /// routing signal.
    depth: Arc<AtomicUsize>,
    /// Items the shard has finished.
    completed: Arc<Counter>,
}

/// A pool of pair-shards processing items of type `I`.
pub struct RelicPool<I: Send + 'static> {
    senders: Vec<SyncSender<I>>,
    shards: Vec<ShardInfo>,
    joins: Vec<JoinHandle<()>>,
    stats: PoolStats,
}

impl<I: Send + 'static> RelicPool<I> {
    /// Spawn a pool per `config`. `factory` runs once on each shard
    /// thread (after pinning) to build the shard's state — this is
    /// where a `Coordinator`, and with it the shard's `Relic` pair, is
    /// created, so the state never crosses threads. `handler` processes
    /// each drained batch against that state.
    pub fn new<S, F, H>(config: &PoolConfig, factory: F, handler: H) -> Self
    where
        S: 'static,
        F: Fn(&ShardPlacement) -> S + Send + Clone + 'static,
        H: Fn(&mut S, Vec<I>) + Send + Clone + 'static,
    {
        let placements = discover_placements(config.shards, config.pin);
        Self::with_placements(placements, config, factory, handler)
    }

    /// [`new`](Self::new) with explicit placements (the admission layer
    /// above may need the shard count before spawning, e.g. to set up
    /// per-shard metrics).
    pub fn with_placements<S, F, H>(
        placements: Vec<ShardPlacement>,
        config: &PoolConfig,
        factory: F,
        handler: H,
    ) -> Self
    where
        S: 'static,
        F: Fn(&ShardPlacement) -> S + Send + Clone + 'static,
        H: Fn(&mut S, Vec<I>) + Send + Clone + 'static,
    {
        assert!(!placements.is_empty(), "RelicPool needs at least one shard");
        let max_batch = config.max_batch.max(1);
        let capacity = config.channel_capacity.max(1);
        let mut senders = Vec::with_capacity(placements.len());
        let mut shards = Vec::with_capacity(placements.len());
        let mut joins = Vec::with_capacity(placements.len());
        for placement in placements {
            let (tx, rx) = sync_channel::<I>(capacity);
            let depth = Arc::new(AtomicUsize::new(0));
            let completed = Arc::new(Counter::new());
            let join = std::thread::Builder::new()
                .name(format!("relic-shard-{}", placement.shard))
                .spawn({
                    let factory = factory.clone();
                    let handler = handler.clone();
                    let depth = Arc::clone(&depth);
                    let completed = Arc::clone(&completed);
                    let placement = placement.clone();
                    move || {
                        shard_loop(rx, &placement, factory, handler, &depth, &completed, max_batch)
                    }
                })
                .expect("failed to spawn relic pool shard");
            senders.push(tx);
            shards.push(ShardInfo { placement, depth, completed });
            joins.push(join);
        }
        RelicPool { senders, shards, joins, stats: PoolStats::default() }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Placement of shard `i`.
    pub fn placement(&self, shard: usize) -> &ShardPlacement {
        &self.shards[shard].placement
    }

    /// The shard with the fewest items queued or in processing (ties go
    /// to the lowest index).
    pub fn least_loaded(&self) -> usize {
        let mut best = 0;
        let mut best_depth = usize::MAX;
        for (i, s) in self.shards.iter().enumerate() {
            let d = s.depth.load(Ordering::Acquire);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        best
    }

    /// Dispatch `item` to the least-loaded shard; returns the shard
    /// index it went to. Blocks (and counts a backpressure stall) when
    /// that shard's channel is full — items are never dropped, and
    /// per-shard FIFO order is preserved.
    pub fn submit(&self, item: I) -> usize {
        let shard = self.least_loaded();
        self.submit_to(shard, item);
        shard
    }

    /// Dispatch `item` to a specific shard (same backpressure rules as
    /// [`submit`](Self::submit)).
    pub fn submit_to(&self, shard: usize, item: I) {
        self.shards[shard].depth.fetch_add(1, Ordering::AcqRel);
        self.stats.dispatched.inc();
        match self.senders[shard].try_send(item) {
            Ok(()) => {}
            Err(TrySendError::Full(item)) => {
                self.stats.backpressure_stalls.inc();
                self.senders[shard]
                    .send(item)
                    .expect("relic pool shard thread died");
            }
            Err(TrySendError::Disconnected(_)) => {
                panic!("relic pool shard thread died");
            }
        }
    }

    /// Admission counters.
    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Shards whose threads have exited. While the pool is alive the
    /// channels are open, so a finished shard thread can only mean its
    /// handler (or factory) panicked — responses routed to it are lost.
    /// Admission layers poll this instead of blocking forever on them.
    pub fn dead_shards(&self) -> Vec<usize> {
        self.joins
            .iter()
            .enumerate()
            .filter(|(_, j)| j.is_finished())
            .map(|(i, _)| i)
            .collect()
    }

    /// Point-in-time counters for reporting.
    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            shards: self.shards.len(),
            dispatched: self.stats.dispatched.get(),
            backpressure_stalls: self.stats.backpressure_stalls.get(),
            occupancy: self.shards.iter().map(|s| s.completed.get()).collect(),
            in_flight: self.shards.iter().map(|s| s.depth.load(Ordering::Acquire)).collect(),
        }
    }
}

impl<I: Send + 'static> Drop for RelicPool<I> {
    fn drop(&mut self) {
        // Closing the channels ends each shard loop after it drains its
        // remaining items; joining flushes all in-flight work.
        self.senders.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// A shard's inner loop: pin, build state, then drain the channel in
/// small batches. Blocking on the first item of a batch and
/// `try_recv`-draining the rest gives natural micro-batching — under
/// load the handler sees multi-request batches (so a
/// `Coordinator`-backed handler still pairs requests on the SMT core),
/// while a lone request is processed immediately.
fn shard_loop<I, S, F, H>(
    rx: Receiver<I>,
    placement: &ShardPlacement,
    factory: F,
    handler: H,
    depth: &AtomicUsize,
    completed: &Counter,
    max_batch: usize,
) where
    F: Fn(&ShardPlacement) -> S,
    H: Fn(&mut S, Vec<I>),
{
    if let Some(cpu) = placement.main_cpu {
        pin_to_cpu(cpu);
    }
    let mut state = factory(placement);
    loop {
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => break,
        };
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(item) => batch.push(item),
                Err(_) => break,
            }
        }
        let n = batch.len();
        handler(&mut state, batch);
        depth.fetch_sub(n, Ordering::AcqRel);
        completed.add(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn sibling_pairs_parse_fixture_lists() {
        // A 4-core/8-thread topology: each pair appears twice (once per
        // sibling), in whatever order sysfs enumerates CPUs.
        let lists = ["0,4\n", "1,5\n", "2,6\n", "3,7\n", "4,0\n", "5,1\n", "6,2\n", "7,3\n"];
        assert_eq!(
            sibling_pairs_from_lists(lists),
            vec![(0, 4), (1, 5), (2, 6), (3, 7)]
        );
        // Range form (adjacent sibling numbering), deduplicated.
        let lists = ["0-1\n", "0-1\n", "2-3\n", "2-3\n"];
        assert_eq!(sibling_pairs_from_lists(lists), vec![(0, 1), (2, 3)]);
        // No SMT: one CPU per list → no pairs.
        let lists = ["0\n", "1\n", "2\n", "3\n"];
        assert!(sibling_pairs_from_lists(lists).is_empty());
        // Garbage and empties are skipped, valid entries survive.
        let lists = ["", "oops\n", "2,6\n"];
        assert_eq!(sibling_pairs_from_lists(lists), vec![(2, 6)]);
    }

    #[test]
    fn fallback_pairs_adjacent() {
        assert_eq!(fallback_pairs(8), vec![(0, 1), (2, 3), (4, 5), (6, 7)]);
        assert_eq!(fallback_pairs(3), vec![(0, 1)]);
        assert!(fallback_pairs(1).is_empty());
    }

    #[test]
    fn placements_respect_want_and_pin() {
        let unpinned = discover_placements(Some(3), false);
        assert_eq!(unpinned.len(), 3);
        for (i, p) in unpinned.iter().enumerate() {
            assert_eq!(p.shard, i);
            assert_eq!(p.main_cpu, None);
            assert_eq!(p.assistant_cpu, None);
        }
        // Auto sizing always yields at least one shard, even hostless.
        assert!(!discover_placements(None, true).is_empty());
        assert!(!discover_placements(None, false).is_empty());
        // Asking for more shards than the host has cores still works
        // (the surplus runs unpinned).
        assert_eq!(discover_placements(Some(64), true).len(), 64);
    }

    #[test]
    fn pool_processes_every_item_in_per_shard_fifo_order() {
        let (tx, rx) = mpsc::channel::<(usize, u64)>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(3), false),
            &PoolConfig { shards: Some(3), pin: false, ..PoolConfig::default() },
            |p: &ShardPlacement| p.shard,
            move |shard: &mut usize, batch: Vec<u64>| {
                for item in batch {
                    tx.send((*shard, item)).unwrap();
                }
            },
        );
        for i in 0..200u64 {
            pool.submit(i);
        }
        drop(pool); // joins shards: everything flushed
        let mut last_per_shard = [None::<u64>; 3];
        let mut seen = 0usize;
        while let Ok((shard, item)) = rx.recv() {
            if let Some(prev) = last_per_shard[shard] {
                assert!(prev < item, "shard {shard} reordered: {prev} before {item}");
            }
            last_per_shard[shard] = Some(item);
            seen += 1;
        }
        assert_eq!(seen, 200, "no item dropped");
    }

    #[test]
    fn backpressure_blocks_but_never_drops() {
        let (tx, rx) = mpsc::channel::<u64>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 1,
                max_batch: 1,
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                // Slow consumer: force the capacity-1 channel to fill.
                std::thread::sleep(Duration::from_millis(1));
                for item in batch {
                    tx.send(item).unwrap();
                }
            },
        );
        for i in 0..32u64 {
            pool.submit(i);
        }
        let stalls = pool.stats().backpressure_stalls.get();
        assert!(stalls > 0, "capacity-1 channel must have stalled at least once");
        drop(pool);
        let got: Vec<u64> = rx.iter().collect();
        assert_eq!(got, (0..32).collect::<Vec<_>>(), "FIFO, nothing dropped");
    }

    #[test]
    fn least_loaded_routing_spreads_across_busy_shards() {
        // Handlers consume one gate token per item: every submitted
        // item keeps its shard's depth raised until the test releases
        // it, so the routing assertions below are deterministic — no
        // sleeps, no scheduler timing.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = std::sync::Mutex::new(gate_rx);
        let gate = Arc::new(gate);
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(2), false),
            &PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for _ in &batch {
                    gate.lock().unwrap().recv().unwrap();
                }
            },
        );
        // Depths at submit time: (0,0) → shard 0; (1,0) → shard 1;
        // (1,1) → shard 0 again (tie goes low).
        assert_eq!(pool.submit(1), 0);
        assert_eq!(pool.submit(2), 1);
        assert_eq!(pool.submit(3), 0);
        let snap = pool.snapshot();
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.dispatched, 3);
        // Release every held item before join.
        for _ in 0..3 {
            gate_tx.send(()).unwrap();
        }
        drop(pool);
    }

    #[test]
    fn snapshot_counts_occupancy() {
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(2), false),
            &PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            |_: &mut (), _batch: Vec<u64>| {},
        );
        for i in 0..50 {
            pool.submit(i);
        }
        // Wait for the shards to drain so occupancy is stable.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let snap = pool.snapshot();
            if snap.occupancy.iter().sum::<u64>() == 50
                && snap.in_flight.iter().sum::<usize>() == 0
            {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "pool never drained");
            std::thread::yield_now();
        }
    }
}
