//! **RelicPool** — a pool of pinned pair-shards: one Relic SMT pair per
//! physical core.
//!
//! The paper scopes Relic to *one* SMT core: one main (producer) thread
//! and one assistant (consumer) thread over a lock-free SPSC queue.
//! Scaling that to a whole machine could widen the queue to MPMC — but
//! that would forfeit exactly what makes Relic fast: the single-producer
//! single-consumer invariant is what lets `push`/`pop` run lock-free
//! with one release store and no CAS on the hot path, and the pair's
//! cache affinity (both threads on one core's L1/L2) is the paper's
//! whole premise. So the pool **replicates the pair instead of widening
//! it** (the FastFlow lesson: SPSC channels compose into larger
//! topologies without giving up their guarantees):
//!
//! * topology discovery parses
//!   `/sys/devices/system/cpu/cpu*/topology/thread_siblings_list` into
//!   SMT sibling pairs (with a portable adjacent-CPU fallback pairing);
//! * one **shard** per physical core: a dedicated main thread, pinned
//!   to the pair's first logical CPU, that *owns* its shard state —
//!   typically a [`crate::coordinator::Coordinator`], whose embedded
//!   [`super::Relic`] pins its assistant to the sibling. Each Relic is
//!   created on, and only ever submitted to from, its shard thread, so
//!   the single-producer invariant holds *by construction*;
//! * an **admission layer**: items are dispatched to shards over
//!   per-shard bounded [`ShardQueue`]s with least-loaded routing,
//!   through three flavors sharing the same counters and ordering
//!   guarantees: [`RelicPool::submit_to`] blocks on the full queue
//!   (backpressure — counted, never dropped, never reordered within a
//!   shard), [`RelicPool::try_submit_to`] returns the item on a full
//!   queue instead of waiting, and [`RelicPool::submit_or_park_to`]
//!   parks the producer on the queue's `not_full` condvar until the
//!   shard's consumer frees capacity. A parked producer still times out
//!   every [`PoolConfig::park_timeout`] to check for a dead shard — and
//!   reports [`ShardDead`] (handing the item back for re-routing)
//!   instead of waiting forever or panicking;
//! * a **fault-isolation layer**: the queue is a `Mutex<VecDeque>`
//!   rather than a channel precisely so it *outlives the shard thread*.
//!   A panicked handler is caught (the thread survives), a dead thread
//!   leaves its queued items stealable, and a [`Supervisor`] watchdog
//!   classifies shards [`ShardHealth::Healthy`]/`Stuck`/`Dead` from
//!   per-shard heartbeats, quarantines misbehaving shards, steals their
//!   queued-but-unprocessed items for redirection (at-most-once by
//!   queue mutual exclusion: an item is either popped by the consumer
//!   or stolen, never both), and respawns dead shards onto the *same*
//!   queue up to a restart budget with exponential backoff;
//! * an optional **idle hook** ([`RelicPool::with_placements_idle`]):
//!   a shard whose queue stays empty past a ~1 ms poll can lend its
//!   pair to a cross-shard lease ([`super::cross`]) through a
//!   `should_return` predicate that pulls it back to its own queue
//!   within one chunk of new work arriving — without the hook the loop
//!   is byte-for-byte the plain blocking drain;
//! * a shard's inner loop drains its queue into small batches, so a
//!   batch handler built on `Coordinator::process_batch` still gets to
//!   pair requests two-at-a-time and run the odd leftover with
//!   intra-request fork-join — the paper's fine-grained scenario is
//!   preserved *inside* every shard.
//!
//! The pool is generic over the item type `I` and the shard state `S`
//! (built on the shard thread by a factory, so `S` need not be `Send`);
//! [`crate::coordinator::Engine`] instantiates it with
//! `I = sequenced Request`, `S = Coordinator`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::Counter;

use super::affinity::{num_cpus, parse_cpulist, pin_to_cpu, sibling_lists};
use super::fault::FaultPlan;

/// Default bound of each shard's admission queue.
pub const DEFAULT_CHANNEL_CAPACITY: usize = 64;

/// Default maximum items a shard's inner loop hands its batch handler.
pub const DEFAULT_MAX_BATCH: usize = 32;

/// Default interval at which a parked producer wakes to check for a
/// dead shard (overridable via [`PoolConfig::park_timeout`]).
pub const DEFAULT_PARK_TIMEOUT: Duration = Duration::from_millis(50);

/// How long an idle-hooked shard waits on its empty queue before
/// running its idle hook (lease serving). Short enough that a posted
/// lease is picked up promptly, long enough that an idle shard without
/// offers burns no measurable CPU in the wait loop.
const IDLE_POLL: Duration = Duration::from_millis(1);

/// A shard's idle hook: run when the queue has stayed empty past the
/// idle poll interval, with the shard's state and a `should_return`
/// predicate that turns true the moment the shard has reasons to get
/// back to its queue (new work admitted, quarantine, shutdown). The
/// hook must poll the predicate and return promptly once it fires.
/// Returns whether it found anything to do (currently informational).
pub type IdleHook<S> = Arc<dyn Fn(&mut S, &(dyn Fn() -> bool + Sync)) -> bool + Send + Sync>;

/// Pool sizing and placement knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Number of shards; `None` = one per detected physical core.
    pub shards: Option<usize>,
    /// Pin shard main threads (and their Relic assistants) to sibling
    /// pairs. Disable on hosts where affinity calls are denied.
    pub pin: bool,
    /// Per-shard bounded queue depth (admission backpressure point).
    pub channel_capacity: usize,
    /// Maximum items per batch handed to the shard's inner loop.
    pub max_batch: usize,
    /// How long a parked producer sleeps between dead-shard checks.
    /// Pure liveness insurance: the normal wakeup is the consumer's
    /// notify.
    pub park_timeout: Duration,
    /// Deterministic fault-injection plan (`None` = no faults; the
    /// disabled cost is one branch per batch).
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: None,
            pin: true,
            channel_capacity: DEFAULT_CHANNEL_CAPACITY,
            max_batch: DEFAULT_MAX_BATCH,
            park_timeout: DEFAULT_PARK_TIMEOUT,
            fault: None,
        }
    }
}

/// Where one shard runs: its main thread's CPU and its Relic
/// assistant's CPU (`None` = unpinned).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlacement {
    pub shard: usize,
    pub main_cpu: Option<usize>,
    pub assistant_cpu: Option<usize>,
}

/// Parse sysfs `thread_siblings_list` contents into deduplicated SMT
/// sibling pairs, sorted by first CPU. Each sibling's file names the
/// same pair, so the raw list contains every pair twice; lists with
/// fewer than two CPUs (no SMT) and unparsable entries are skipped.
pub fn sibling_pairs_from_lists<'a, I>(lists: I) -> Vec<(usize, usize)>
where
    I: IntoIterator<Item = &'a str>,
{
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for text in lists {
        let cpus = parse_cpulist(text);
        if cpus.len() >= 2 {
            let key = (cpus[0].min(cpus[1]), cpus[0].max(cpus[1]));
            if !pairs.contains(&key) {
                pairs.push(key);
            }
        }
    }
    pairs.sort_unstable();
    pairs
}

/// Portable fallback pairing when sysfs exposes no sibling topology:
/// adjacent logical CPUs `(2i, 2i+1)`. Not true SMT siblings, but the
/// pinning still gives each shard two stable, distinct CPUs.
pub fn fallback_pairs(cpus: usize) -> Vec<(usize, usize)> {
    (0..cpus / 2).map(|i| (2 * i, 2 * i + 1)).collect()
}

/// The host's physical-core pairs: sysfs SMT siblings where available,
/// otherwise the adjacent-CPU fallback (which may be empty on a
/// single-CPU host — callers fall back to unpinned shards).
pub fn physical_core_pairs() -> Vec<(usize, usize)> {
    let lists = sibling_lists();
    let pairs = sibling_pairs_from_lists(lists.iter().map(String::as_str));
    if pairs.is_empty() {
        fallback_pairs(num_cpus())
    } else {
        pairs
    }
}

/// Decide shard placements: `want` shards (default: one per physical
/// core, minimum one), pinned onto the discovered pairs in order.
/// Shards beyond the available pairs — or all shards when `pin` is
/// false — run unpinned.
pub fn discover_placements(want: Option<usize>, pin: bool) -> Vec<ShardPlacement> {
    let pairs = if pin { physical_core_pairs() } else { Vec::new() };
    let n = want.unwrap_or_else(|| pairs.len().max(1)).max(1);
    (0..n)
        .map(|shard| match pairs.get(shard) {
            Some(&(a, b)) if pin => ShardPlacement {
                shard,
                main_cpu: Some(a),
                assistant_cpu: Some(b),
            },
            _ => ShardPlacement { shard, main_cpu: None, assistant_cpu: None },
        })
        .collect()
}

/// Pool-level admission counters.
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Items routed to a shard.
    pub dispatched: Counter,
    /// Submissions that found the chosen shard's queue full and had
    /// to block (backpressure events; the item is still delivered).
    pub backpressure_stalls: Counter,
    /// Submissions that found the queue full and parked on the
    /// shard's `not_full` condvar (the item is still delivered unless
    /// the shard dies, which is reported, not dropped).
    pub parked_submits: Counter,
}

/// A parked submission failed because the shard's thread exited; the
/// item is handed back untouched so the caller can re-route it.
#[derive(Debug)]
pub struct ShardDead<I> {
    /// The shard whose thread died.
    pub shard: usize,
    /// The undelivered item.
    pub item: I,
}

/// The bounded, stealable admission queue of one shard.
///
/// Deliberately a `Mutex<VecDeque>` + two condvars instead of a
/// channel: a channel's receiver dies with its thread (destroying
/// queued items), while this queue is owned by the *pool*, outlives
/// any particular shard thread, and supports the supervisor's
/// `steal_all` with at-most-once semantics by plain mutual exclusion.
/// Admission is not the hot path (kernel execution is), so the lock
/// never shows up in profiles — the SPSC fast path inside each shard's
/// Relic pair is untouched.
#[derive(Debug)]
struct ShardQueue<I> {
    capacity: usize,
    inner: Mutex<QueueInner<I>>,
    not_full: Condvar,
    not_empty: Condvar,
}

#[derive(Debug)]
struct QueueInner<I> {
    items: VecDeque<I>,
    closed: bool,
}

impl<I> ShardQueue<I> {
    fn new(capacity: usize) -> Self {
        ShardQueue {
            capacity,
            inner: Mutex::new(QueueInner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Enqueue without blocking; a full (or closed) queue hands the
    /// item back unchanged.
    fn try_push(&self, item: I) -> Result<(), I> {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        if inner.closed || inner.items.len() >= self.capacity {
            return Err(item);
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue, waiting for capacity. Returns the item only if the
    /// queue is closed while waiting.
    fn push_blocking(&self, item: I) -> Result<(), I> {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        while inner.items.len() >= self.capacity {
            if inner.closed {
                return Err(item);
            }
            inner = self.not_full.wait(inner).expect("shard queue poisoned");
        }
        if inner.closed {
            return Err(item);
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue, parking until capacity frees. Wakes every `timeout` to
    /// run `give_up` (the dead-shard check); when it returns true the
    /// item is handed back instead of waiting forever. Lost-wakeup-free
    /// by construction: the full check and the wait share one mutex
    /// with the consumer's notify.
    fn push_parked<F: Fn() -> bool>(
        &self,
        item: I,
        timeout: Duration,
        give_up: F,
    ) -> Result<(), I> {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        loop {
            if inner.closed {
                return Err(item);
            }
            if inner.items.len() < self.capacity {
                inner.items.push_back(item);
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            let (guard, wait) = self
                .not_full
                .wait_timeout(inner, timeout)
                .expect("shard queue poisoned");
            inner = guard;
            if wait.timed_out() && give_up() {
                return Err(item);
            }
        }
    }

    /// Whether the queue has been closed (pool shutdown). Part of the
    /// idle hook's `should_return` predicate, not a hot path.
    fn is_closed(&self) -> bool {
        self.inner.lock().expect("shard queue poisoned").closed
    }

    /// Consumer side: block for the first item, then drain up to `max`
    /// without waiting. Returns false when the queue is closed and
    /// empty (the shard loop's exit condition). Every pop frees
    /// capacity, so parked producers are notified *before* the handler
    /// runs — admission refills the queue while the batch is processed.
    fn pop_batch(&self, max: usize, out: &mut Vec<I>) -> bool {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        loop {
            if !inner.items.is_empty() {
                while out.len() < max {
                    match inner.items.pop_front() {
                        Some(item) => out.push(item),
                        None => break,
                    }
                }
                drop(inner);
                self.not_full.notify_all();
                return true;
            }
            if inner.closed {
                return false;
            }
            inner = self.not_empty.wait(inner).expect("shard queue poisoned");
        }
    }

    /// [`pop_batch`](Self::pop_batch) with an idle budget: gives up
    /// after `timeout` with an empty batch ([`Popped::Idle`]) so an
    /// idle-hooked shard loop can go serve a lease instead of blocking
    /// on its empty queue forever.
    fn pop_batch_timed(&self, max: usize, out: &mut Vec<I>, timeout: Duration) -> Popped {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        loop {
            if !inner.items.is_empty() {
                while out.len() < max {
                    match inner.items.pop_front() {
                        Some(item) => out.push(item),
                        None => break,
                    }
                }
                drop(inner);
                self.not_full.notify_all();
                return Popped::Items;
            }
            if inner.closed {
                return Popped::Closed;
            }
            let (guard, wait) = self
                .not_empty
                .wait_timeout(inner, timeout)
                .expect("shard queue poisoned");
            inner = guard;
            if wait.timed_out() && inner.items.is_empty() && !inner.closed {
                return Popped::Idle;
            }
        }
    }

    /// Put a popped batch back at the *front* of the queue, preserving
    /// FIFO order (used by the kill fault so a dying thread loses no
    /// items).
    fn requeue_front(&self, items: Vec<I>) {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        for item in items.into_iter().rev() {
            inner.items.push_front(item);
        }
        drop(inner);
        self.not_empty.notify_one();
    }

    /// Supervisor side: take every queued-but-unprocessed item. Mutual
    /// exclusion with `pop_batch` makes redirection at-most-once: an
    /// item is either popped by the consumer or stolen here, never
    /// both.
    fn steal_all(&self) -> Vec<I> {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        let items: Vec<I> = inner.items.drain(..).collect();
        drop(inner);
        if !items.is_empty() {
            self.not_full.notify_all();
        }
        items
    }

    /// Close the queue: producers get their items back, consumers
    /// drain what remains and exit.
    fn close(&self) {
        let mut inner = self.inner.lock().expect("shard queue poisoned");
        inner.closed = true;
        drop(inner);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }
}

/// What one timed pop observed (see [`ShardQueue::pop_batch_timed`]).
enum Popped {
    /// The batch has at least one item.
    Items,
    /// The queue stayed empty past the timeout — run the idle hook.
    Idle,
    /// Closed and empty — the shard loop exits.
    Closed,
}

/// Point-in-time view of the pool (see [`RelicPool::snapshot`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolSnapshot {
    pub shards: usize,
    pub dispatched: u64,
    pub backpressure_stalls: u64,
    pub parked_submits: u64,
    /// Items completed per shard (shard occupancy over the run).
    pub occupancy: Vec<u64>,
    /// Items queued or in processing per shard right now.
    pub in_flight: Vec<usize>,
}

/// Per-shard bookkeeping kept on the admission side. The queue, the
/// counters, and the respawn closure all outlive the shard *thread*,
/// which is the whole point: a dead thread is a replaceable part.
struct Shard<I: Send + 'static> {
    placement: ShardPlacement,
    queue: Arc<ShardQueue<I>>,
    /// Items queued or being processed (incremented at submit,
    /// decremented by the shard after each batch) — the least-loaded
    /// routing signal.
    depth: Arc<AtomicUsize>,
    /// Items the shard has finished.
    completed: Arc<Counter>,
    /// Bumped by the shard loop once per batch — the supervisor's
    /// liveness signal.
    heartbeat: Arc<AtomicU64>,
    /// Handler panics caught at the thread level (the engine's own
    /// containment normally fires first; this is the backstop).
    handler_panics: Arc<Counter>,
    /// Quarantined shards are skipped by routing until the supervisor
    /// clears them. `Arc` so the lease broker can watch it live
    /// (quarantined shards are never offered to a whale request).
    quarantined: Arc<AtomicBool>,
    /// The current thread, if any (`None` transiently during respawn).
    handle: Mutex<Option<JoinHandle<()>>>,
    /// Spawns a fresh thread on the same queue (factory/handler
    /// clones live in here; `Mutex` because they need not be `Sync`).
    respawn: Mutex<Box<dyn FnMut() -> JoinHandle<()> + Send>>,
    /// Times this shard has been respawned.
    restarts: AtomicU32,
}

/// A pool of pair-shards processing items of type `I`.
pub struct RelicPool<I: Send + 'static> {
    shards: Vec<Shard<I>>,
    stats: PoolStats,
    /// Per-shard admission-queue bound (for load-factor reporting).
    channel_capacity: usize,
    park_timeout: Duration,
}

impl<I: Send + 'static> RelicPool<I> {
    /// Spawn a pool per `config`. `factory` runs once on each shard
    /// thread (after pinning) to build the shard's state — this is
    /// where a `Coordinator`, and with it the shard's `Relic` pair, is
    /// created, so the state never crosses threads. `handler` processes
    /// each drained batch against that state.
    pub fn new<S, F, H>(config: &PoolConfig, factory: F, handler: H) -> Self
    where
        S: 'static,
        F: Fn(&ShardPlacement) -> S + Send + Clone + 'static,
        H: Fn(&mut S, Vec<I>) + Send + Clone + 'static,
    {
        let placements = discover_placements(config.shards, config.pin);
        Self::with_placements(placements, config, factory, handler)
    }

    /// [`new`](Self::new) with explicit placements (the admission layer
    /// above may need the shard count before spawning, e.g. to set up
    /// per-shard metrics).
    pub fn with_placements<S, F, H>(
        placements: Vec<ShardPlacement>,
        config: &PoolConfig,
        factory: F,
        handler: H,
    ) -> Self
    where
        S: 'static,
        F: Fn(&ShardPlacement) -> S + Send + Clone + 'static,
        H: Fn(&mut S, Vec<I>) + Send + Clone + 'static,
    {
        Self::with_placements_idle(placements, config, factory, handler, None)
    }

    /// [`with_placements`](Self::with_placements) plus an optional
    /// per-shard idle hook: when a shard's queue stays empty past the
    /// idle poll interval the hook runs with the shard's state and a
    /// `should_return` predicate (new work / quarantine / shutdown).
    /// This is how a shard lends itself to cross-shard leases without
    /// ever touching its admission fast path — `None` makes this
    /// byte-for-byte the plain blocking loop.
    pub fn with_placements_idle<S, F, H>(
        placements: Vec<ShardPlacement>,
        config: &PoolConfig,
        factory: F,
        handler: H,
        idle: Option<IdleHook<S>>,
    ) -> Self
    where
        S: 'static,
        F: Fn(&ShardPlacement) -> S + Send + Clone + 'static,
        H: Fn(&mut S, Vec<I>) + Send + Clone + 'static,
    {
        assert!(!placements.is_empty(), "RelicPool needs at least one shard");
        let max_batch = config.max_batch.max(1);
        let capacity = config.channel_capacity.max(1);
        let mut shards = Vec::with_capacity(placements.len());
        for placement in placements {
            let queue = Arc::new(ShardQueue::new(capacity));
            let depth = Arc::new(AtomicUsize::new(0));
            let completed = Arc::new(Counter::new());
            let heartbeat = Arc::new(AtomicU64::new(0));
            let handler_panics = Arc::new(Counter::new());
            let quarantined = Arc::new(AtomicBool::new(false));
            // One closure both spawns the initial thread and respawns
            // replacements: every thread of this shard runs the same
            // loop on the same queue.
            let mut respawn: Box<dyn FnMut() -> JoinHandle<()> + Send> = {
                let queue = Arc::clone(&queue);
                let depth = Arc::clone(&depth);
                let completed = Arc::clone(&completed);
                let heartbeat = Arc::clone(&heartbeat);
                let handler_panics = Arc::clone(&handler_panics);
                let quarantined = Arc::clone(&quarantined);
                let factory = factory.clone();
                let handler = handler.clone();
                let placement = placement.clone();
                let fault = config.fault.clone();
                let idle = idle.clone();
                Box::new(move || {
                    spawn_shard_thread(
                        placement.clone(),
                        Arc::clone(&queue),
                        Arc::clone(&depth),
                        Arc::clone(&completed),
                        Arc::clone(&heartbeat),
                        Arc::clone(&handler_panics),
                        Arc::clone(&quarantined),
                        factory.clone(),
                        handler.clone(),
                        max_batch,
                        fault.clone(),
                        idle.clone(),
                    )
                })
            };
            let handle = respawn();
            shards.push(Shard {
                placement,
                queue,
                depth,
                completed,
                heartbeat,
                handler_panics,
                quarantined,
                handle: Mutex::new(Some(handle)),
                respawn: Mutex::new(respawn),
                restarts: AtomicU32::new(0),
            });
        }
        RelicPool {
            shards,
            stats: PoolStats::default(),
            channel_capacity: capacity,
            park_timeout: config.park_timeout,
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Placement of shard `i`.
    pub fn placement(&self, shard: usize) -> &ShardPlacement {
        &self.shards[shard].placement
    }

    /// The non-quarantined shard with the fewest items queued or in
    /// processing (ties go to the lowest index). Falls back to the
    /// global least-loaded shard when everything is quarantined, so
    /// raw-pool callers keep the old total behavior.
    pub fn least_loaded(&self) -> usize {
        let mut best = None;
        let mut best_any = (0, usize::MAX);
        for (i, s) in self.shards.iter().enumerate() {
            let d = s.depth.load(Ordering::Acquire);
            if d < best_any.1 {
                best_any = (i, d);
            }
            if s.quarantined.load(Ordering::Acquire) {
                continue;
            }
            if best.is_none_or(|(_, bd)| d < bd) {
                best = Some((i, d));
            }
        }
        best.unwrap_or(best_any).0
    }

    /// Dispatch `item` to the least-loaded shard; returns the shard
    /// index it went to. Blocks (and counts a backpressure stall) when
    /// that shard's queue is full — items are never dropped, and
    /// per-shard FIFO order is preserved.
    pub fn submit(&self, item: I) -> usize {
        let shard = self.least_loaded();
        self.submit_to(shard, item);
        shard
    }

    /// Dispatch `item` to a specific shard (same backpressure rules as
    /// [`submit`](Self::submit)).
    pub fn submit_to(&self, shard: usize, item: I) {
        self.shards[shard].depth.fetch_add(1, Ordering::AcqRel);
        self.stats.dispatched.inc();
        match self.shards[shard].queue.try_push(item) {
            Ok(()) => {}
            Err(item) => {
                self.stats.backpressure_stalls.inc();
                self.shards[shard]
                    .queue
                    .push_blocking(item)
                    .unwrap_or_else(|_| panic!("relic pool shard {shard} queue closed"));
            }
        }
    }

    /// Non-blocking dispatch to a specific shard. `Ok(())` means the
    /// item is queued (counted, same FIFO guarantees as
    /// [`submit_to`](Self::submit_to)); a full queue hands the item
    /// back unchanged and counts nothing, so the caller can retry,
    /// park, or shed it without losing it.
    pub fn try_submit_to(&self, shard: usize, item: I) -> Result<(), I> {
        // Depth goes up *before* the push so a concurrent consumer
        // finishing the item can never decrement first (which would
        // wrap the unsigned depth and wreck least-loaded routing).
        self.shards[shard].depth.fetch_add(1, Ordering::AcqRel);
        match self.shards[shard].queue.try_push(item) {
            Ok(()) => {
                self.stats.dispatched.inc();
                Ok(())
            }
            Err(item) => {
                self.shards[shard].depth.fetch_sub(1, Ordering::AcqRel);
                Err(item)
            }
        }
    }

    /// Dispatch to a specific shard, parking on the queue's `not_full`
    /// condvar when it is full: the producer sleeps until the consumer
    /// frees capacity instead of spinning or blocking inside the
    /// queue. Returns `Ok(true)` when it had to park (counted in
    /// [`PoolStats::parked_submits`]), `Ok(false)` on immediate
    /// delivery, and [`ShardDead`] — with the item handed back for
    /// re-routing — when the shard's thread is found dead on a park
    /// timeout ([`PoolConfig::park_timeout`]).
    pub fn submit_or_park_to(&self, shard: usize, item: I) -> Result<bool, ShardDead<I>> {
        self.shards[shard].depth.fetch_add(1, Ordering::AcqRel);
        let item = match self.shards[shard].queue.try_push(item) {
            Ok(()) => {
                self.stats.dispatched.inc();
                return Ok(false);
            }
            Err(item) => item,
        };
        self.stats.parked_submits.inc();
        match self.shards[shard].queue.push_parked(item, self.park_timeout, || {
            self.shard_dead(shard)
        }) {
            Ok(()) => {
                self.stats.dispatched.inc();
                Ok(true)
            }
            Err(item) => {
                self.shards[shard].depth.fetch_sub(1, Ordering::AcqRel);
                Err(ShardDead { shard, item })
            }
        }
    }

    /// Items queued or in processing on one shard right now.
    pub fn depth(&self, shard: usize) -> usize {
        self.shards[shard].depth.load(Ordering::Acquire)
    }

    /// Shared handle to shard `i`'s depth counter. The lease broker
    /// binds this so eligibility ("queue shallow enough to borrow?")
    /// reads live state with no pool call on the serving path.
    pub fn depth_handle(&self, shard: usize) -> Arc<AtomicUsize> {
        Arc::clone(&self.shards[shard].depth)
    }

    /// Shared handle to shard `i`'s quarantine flag (the lease broker
    /// binds this — quarantined shards are never offered).
    pub fn quarantined_handle(&self, shard: usize) -> Arc<AtomicBool> {
        Arc::clone(&self.shards[shard].quarantined)
    }

    /// Per-shard depths (the least-loaded / least-slack routing input).
    pub fn depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth.load(Ordering::Acquire)).collect()
    }

    /// [`depths`](Self::depths) without the allocation — what the
    /// engine's per-request routing reads.
    pub fn depths_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.shards.iter().map(|s| s.depth.load(Ordering::Acquire))
    }

    /// Per-shard admission-queue bound.
    pub fn channel_capacity(&self) -> usize {
        self.channel_capacity
    }

    /// Fraction of total admission capacity currently claimed. Depth
    /// counts items *in processing* as well as queued, so sustained
    /// overload reads above 1.0 — the load-factor shed policy treats
    /// its threshold as "queued work per queue slot", not a percentage.
    pub fn load_factor(&self) -> f32 {
        let total: usize = self.shards.iter().map(|s| s.depth.load(Ordering::Acquire)).sum();
        total as f32 / (self.shards.len() * self.channel_capacity) as f32
    }

    /// Admission counters.
    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Whether shard `i`'s thread has exited (panicked factory, a
    /// double fault past handler containment, or an injected kill).
    /// Its queue survives — items are stealable and the shard is
    /// respawnable.
    pub fn shard_dead(&self, shard: usize) -> bool {
        self.shards[shard]
            .handle
            .lock()
            .expect("shard handle poisoned")
            .as_ref()
            .is_none_or(|h| h.is_finished())
    }

    /// Shards whose threads have exited. Admission layers poll this
    /// (or run a [`Supervisor`]) instead of blocking forever on them.
    pub fn dead_shards(&self) -> Vec<usize> {
        (0..self.shards.len()).filter(|&i| self.shard_dead(i)).collect()
    }

    /// The shard-loop liveness counter (bumped once per batch).
    pub fn heartbeat(&self, shard: usize) -> u64 {
        self.shards[shard].heartbeat.load(Ordering::Acquire)
    }

    /// Handler panics caught at the thread level, across all shards.
    pub fn handler_panics(&self) -> u64 {
        self.shards.iter().map(|s| s.handler_panics.get()).sum()
    }

    /// Whether routing should skip shard `i`.
    pub fn is_quarantined(&self, shard: usize) -> bool {
        self.shards[shard].quarantined.load(Ordering::Acquire)
    }

    /// Mark or clear quarantine on shard `i` (supervisor's decision;
    /// quarantined shards get no new traffic but keep draining).
    pub fn set_quarantined(&self, shard: usize, quarantined: bool) {
        self.shards[shard].quarantined.store(quarantined, Ordering::Release);
    }

    /// Number of shards currently quarantined.
    pub fn quarantined_count(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.quarantined.load(Ordering::Acquire))
            .count()
    }

    /// Times shard `i` has been respawned.
    pub fn restarts(&self, shard: usize) -> u32 {
        self.shards[shard].restarts.load(Ordering::Acquire)
    }

    /// Hand shard `i` one restart credit back (decrement its restart
    /// count, floored at zero). Returns whether a credit was actually
    /// restored — false when the shard never restarted, so budget decay
    /// is a strict no-op on a fault-free pool. Called by the
    /// supervisor's health-streak decay, never from hot paths.
    pub fn restore_restart_credit(&self, shard: usize) -> bool {
        let restarts = &self.shards[shard].restarts;
        let mut current = restarts.load(Ordering::Acquire);
        while current > 0 {
            match restarts.compare_exchange_weak(
                current,
                current - 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(seen) => current = seen,
            }
        }
        false
    }

    /// Zero shard `i`'s restart count — the `rebuild`
    /// budget-exhausted policy's reset, giving the reconstructed shard
    /// a full budget again.
    pub fn reset_restart_count(&self, shard: usize) {
        self.shards[shard].restarts.store(0, Ordering::Release);
    }

    /// Take every queued-but-unprocessed item off shard `i` for
    /// redirection. At-most-once: the queue's mutex means an item is
    /// either stolen here or popped by the consumer, never both.
    pub fn steal_queued(&self, shard: usize) -> Vec<I> {
        let items = self.shards[shard].queue.steal_all();
        if !items.is_empty() {
            self.shards[shard].depth.fetch_sub(items.len(), Ordering::AcqRel);
        }
        items
    }

    /// Replace a dead shard thread with a fresh one on the same queue.
    /// No-op (returns false) while the current thread is still alive.
    pub fn respawn_shard(&self, shard: usize) -> bool {
        let s = &self.shards[shard];
        let mut handle = s.handle.lock().expect("shard handle poisoned");
        if handle.as_ref().is_some_and(|h| !h.is_finished()) {
            return false;
        }
        if let Some(old) = handle.take() {
            let _ = old.join();
        }
        *handle = Some((s.respawn.lock().expect("shard respawn poisoned"))());
        s.restarts.fetch_add(1, Ordering::AcqRel);
        true
    }

    /// Point-in-time counters for reporting.
    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            shards: self.shards.len(),
            dispatched: self.stats.dispatched.get(),
            backpressure_stalls: self.stats.backpressure_stalls.get(),
            parked_submits: self.stats.parked_submits.get(),
            occupancy: self.shards.iter().map(|s| s.completed.get()).collect(),
            in_flight: self.shards.iter().map(|s| s.depth.load(Ordering::Acquire)).collect(),
        }
    }
}

impl<I: Send + 'static> Drop for RelicPool<I> {
    fn drop(&mut self) {
        // Closing the queues ends each shard loop after it drains its
        // remaining items; joining flushes all in-flight work.
        for s in &self.shards {
            s.queue.close();
        }
        for s in &self.shards {
            if let Some(h) = s.handle.lock().expect("shard handle poisoned").take() {
                let _ = h.join();
            }
        }
    }
}

/// Spawn one shard thread running [`shard_loop`] on the given queue.
#[allow(clippy::too_many_arguments)]
fn spawn_shard_thread<I, S, F, H>(
    placement: ShardPlacement,
    queue: Arc<ShardQueue<I>>,
    depth: Arc<AtomicUsize>,
    completed: Arc<Counter>,
    heartbeat: Arc<AtomicU64>,
    handler_panics: Arc<Counter>,
    quarantined: Arc<AtomicBool>,
    factory: F,
    handler: H,
    max_batch: usize,
    fault: Option<Arc<FaultPlan>>,
    idle: Option<IdleHook<S>>,
) -> JoinHandle<()>
where
    I: Send + 'static,
    S: 'static,
    F: Fn(&ShardPlacement) -> S + Send + 'static,
    H: Fn(&mut S, Vec<I>) + Send + 'static,
{
    std::thread::Builder::new()
        .name(format!("relic-shard-{}", placement.shard))
        .spawn(move || {
            shard_loop(
                &queue,
                &placement,
                factory,
                handler,
                &depth,
                &completed,
                &heartbeat,
                &handler_panics,
                &quarantined,
                max_batch,
                fault.as_deref(),
                idle,
            )
        })
        .expect("failed to spawn relic pool shard")
}

/// A shard's inner loop: pin, build state, then drain the queue in
/// small batches. Blocking on the first item of a batch and draining
/// the rest without waiting gives natural micro-batching — under load
/// the handler sees multi-request batches (so a `Coordinator`-backed
/// handler still pairs requests on the SMT core), while a lone request
/// is processed immediately.
///
/// Fault isolation: a panicking handler is caught (`catch_unwind`) and
/// counted; the batch's depth/completed accounting still runs, so the
/// admission layer above can reconcile and synthesize failure
/// responses. The injected-kill fault requeues its batch before
/// exiting, so even a dying thread loses no items.
#[allow(clippy::too_many_arguments)]
fn shard_loop<I, S, F, H>(
    queue: &ShardQueue<I>,
    placement: &ShardPlacement,
    factory: F,
    handler: H,
    depth: &AtomicUsize,
    completed: &Counter,
    heartbeat: &AtomicU64,
    handler_panics: &Counter,
    quarantined: &AtomicBool,
    max_batch: usize,
    fault: Option<&FaultPlan>,
    idle: Option<IdleHook<S>>,
) where
    F: Fn(&ShardPlacement) -> S,
    H: Fn(&mut S, Vec<I>),
{
    if let Some(cpu) = placement.main_cpu {
        pin_to_cpu(cpu);
    }
    let mut state = factory(placement);
    loop {
        let mut batch = Vec::with_capacity(max_batch);
        match &idle {
            // No idle hook: block on the queue exactly as before.
            None => {
                if !queue.pop_batch(max_batch, &mut batch) {
                    break;
                }
            }
            // Idle hook: a bounded wait, then go lend this pair to a
            // posted lease. `should_return` is what makes the lease
            // revocable — it trips on new local work (depth rises at
            // submit, *before* the push), quarantine, or shutdown.
            Some(hook) => match queue.pop_batch_timed(max_batch, &mut batch, IDLE_POLL) {
                Popped::Closed => break,
                Popped::Idle => {
                    let should_return = || {
                        depth.load(Ordering::Acquire) > 0
                            || quarantined.load(Ordering::Acquire)
                            || queue.is_closed()
                    };
                    hook(&mut state, &should_return);
                    continue;
                }
                Popped::Items => {}
            },
        }
        if let Some(plan) = fault {
            if plan.should_kill(placement.shard) {
                // Injected thread death: put the batch back (FIFO
                // intact) and exit. The supervisor will steal and
                // respawn.
                queue.requeue_front(batch);
                return;
            }
            if let Some(stall) = plan.stall_duration(placement.shard) {
                // Injected wedge: the heartbeat goes stale while depth
                // stays up, which is exactly the watchdog's Stuck
                // signature.
                std::thread::sleep(stall);
            }
        }
        heartbeat.fetch_add(1, Ordering::Release);
        let n = batch.len();
        if catch_unwind(AssertUnwindSafe(|| handler(&mut state, batch))).is_err() {
            handler_panics.inc();
        }
        depth.fetch_sub(n, Ordering::AcqRel);
        completed.add(n as u64);
    }
}

/// How the watchdog reads one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// Heartbeat advancing (or idle with an empty queue).
    Healthy,
    /// Thread alive but its heartbeat has been stale for longer than
    /// [`SupervisorConfig::stuck_after`] while work is pending.
    Stuck,
    /// Thread exited.
    Dead,
}

impl ShardHealth {
    /// Stable lower-case name for reports and JSON artifacts.
    pub fn name(&self) -> &'static str {
        match self {
            ShardHealth::Healthy => "healthy",
            ShardHealth::Stuck => "stuck",
            ShardHealth::Dead => "dead",
        }
    }
}

/// What the engine should do when a dead shard has exhausted its
/// restart budget. The default, [`BudgetPolicy::Quarantine`], is the
/// pre-HA behavior bit-for-bit: the shard stays quarantined and the
/// engine degrades around it forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BudgetPolicy {
    /// Leave the shard quarantined; keep serving around it.
    #[default]
    Quarantine,
    /// Finish flushing in-flight work (every queued request still gets
    /// a typed verdict), then ask the process to exit nonzero so an
    /// external orchestrator can restart it cleanly.
    DrainAndExit,
    /// Tear the dead shard down and reconstruct it once, with a fresh
    /// restart budget. A second exhaustion falls back to quarantine.
    Rebuild,
}

impl BudgetPolicy {
    /// Parse a config/CLI name (`quarantine|drain_and_exit|rebuild`;
    /// hyphens accepted).
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "quarantine" => Some(BudgetPolicy::Quarantine),
            "drain_and_exit" | "drain-and-exit" => Some(BudgetPolicy::DrainAndExit),
            "rebuild" => Some(BudgetPolicy::Rebuild),
            _ => None,
        }
    }

    /// Stable name for reports and JSON artifacts.
    pub fn name(&self) -> &'static str {
        match self {
            BudgetPolicy::Quarantine => "quarantine",
            BudgetPolicy::DrainAndExit => "drain_and_exit",
            BudgetPolicy::Rebuild => "rebuild",
        }
    }
}

/// Watchdog and recovery policy knobs.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Master switch. Off = PR 5 behavior exactly: no quarantine, no
    /// respawn, dead shards are fatal to the admission layer above.
    pub enabled: bool,
    /// Heartbeat staleness (with pending work) before a live shard is
    /// classified [`ShardHealth::Stuck`] and quarantined.
    pub stuck_after: Duration,
    /// Restart budget per shard; beyond it a dead shard stays
    /// quarantined and the engine degrades around it.
    pub max_restarts: u32,
    /// First respawn backoff; doubles per restart of that shard.
    pub backoff_base: Duration,
    /// Cap on concurrent inline executions while the engine is degraded
    /// (every shard quarantined). `0` = auto: one permit per shard, so
    /// degraded throughput never oversubscribes the physical cores the
    /// shards were pinned to.
    pub degraded_max_inflight: usize,
    /// Consecutive `Healthy` supervisor ticks after which a shard that
    /// has restarted earns one restart credit back (and resets its
    /// respawn backoff), so a transient bad hour doesn't permanently
    /// exhaust `max_restarts`. `0` disables decay. A shard that never
    /// restarted has nothing to earn back — on a fault-free pool the
    /// decay is a strict no-op.
    pub heal_after_ticks: u32,
    /// What to do when a dead shard has exhausted `max_restarts`.
    /// The default keeps the pre-HA behavior: stay quarantined.
    pub on_budget_exhausted: BudgetPolicy,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            enabled: true,
            stuck_after: Duration::from_millis(200),
            max_restarts: 3,
            backoff_base: Duration::from_millis(25),
            degraded_max_inflight: 0,
            heal_after_ticks: 32,
            on_budget_exhausted: BudgetPolicy::Quarantine,
        }
    }
}

/// What one [`Supervisor::check`] pass decided.
#[derive(Debug)]
pub struct SupervisorVerdict<I> {
    /// Per-shard classification this pass.
    pub health: Vec<ShardHealth>,
    /// Items stolen from quarantined shards; the caller must re-route
    /// them (at-most-once is already guaranteed — they were never
    /// popped by a consumer).
    pub redirected: Vec<I>,
    /// Shards respawned this pass.
    pub restarted: usize,
    /// Shards newly quarantined this pass (watchdog trips).
    pub trips: usize,
    /// Time spent in quarantine by each shard released this pass.
    pub released: Vec<Duration>,
    /// Restart credits handed back by budget decay this pass.
    pub credits_restored: usize,
    /// Shards observed dead with an exhausted restart budget for the
    /// first time this pass — the caller applies its
    /// [`SupervisorConfig::on_budget_exhausted`] policy to these.
    pub budget_exhausted: Vec<usize>,
}

/// Per-shard watchdog memory.
#[derive(Debug, Clone)]
struct BeatState {
    last_beat: u64,
    changed_at: Instant,
    quarantined_since: Option<Instant>,
    next_restart_at: Option<Instant>,
    /// Consecutive `Healthy` classifications (budget-decay streak).
    healthy_ticks: u32,
    /// Budget exhaustion already surfaced in a verdict (report once).
    exhausted_reported: bool,
}

/// Read-only view of one shard's supervision state, for the health
/// surface ([`Supervisor::peek`]). Unlike a [`SupervisorVerdict`] this
/// carries no recovery actions — peeking never quarantines, steals, or
/// respawns.
#[derive(Debug, Clone)]
pub struct ShardStatus {
    /// What a watchdog pass *would* classify this shard as right now.
    pub health: ShardHealth,
    /// Time since the shard's heartbeat last advanced (zero when it
    /// has advanced since the last `check`).
    pub heartbeat_age: Duration,
    /// How long the shard has been in its current quarantine, if any.
    pub quarantined_for: Option<Duration>,
    /// Restart credits consumed so far.
    pub restarts_used: u32,
    /// A respawn is owed but waiting out its exponential backoff.
    pub backoff_pending: bool,
}

/// The pool's watchdog: classifies shards from heartbeats and thread
/// liveness, quarantines `Stuck`/`Dead` shards (stealing their queued
/// items for redirection), respawns dead shards within a restart
/// budget (exponential backoff), and releases recovered shards.
///
/// The supervisor is *driven*, not threaded: the admission layer calls
/// [`check`](Supervisor::check) from its drain-timeout path, so with a
/// healthy pool the supervisor costs nothing on the hot path.
#[derive(Debug)]
pub struct Supervisor {
    config: SupervisorConfig,
    beats: Vec<BeatState>,
}

impl Supervisor {
    /// A supervisor for a pool of `shards` shards.
    pub fn new(config: SupervisorConfig, shards: usize) -> Self {
        let now = Instant::now();
        Supervisor {
            config,
            beats: vec![
                BeatState {
                    last_beat: 0,
                    changed_at: now,
                    quarantined_since: None,
                    next_restart_at: None,
                    healthy_ticks: 0,
                    exhausted_reported: false,
                };
                shards
            ],
        }
    }

    /// The active policy.
    pub fn config(&self) -> &SupervisorConfig {
        &self.config
    }

    /// One watchdog pass over `pool`: classify, quarantine, steal,
    /// respawn, release. Call this from the admission layer's idle /
    /// timeout path.
    pub fn check<I: Send + 'static>(&mut self, pool: &RelicPool<I>) -> SupervisorVerdict<I> {
        let now = Instant::now();
        let mut verdict = SupervisorVerdict {
            health: Vec::with_capacity(pool.shard_count()),
            redirected: Vec::new(),
            restarted: 0,
            trips: 0,
            released: Vec::new(),
            credits_restored: 0,
            budget_exhausted: Vec::new(),
        };
        for shard in 0..pool.shard_count() {
            let beat = pool.heartbeat(shard);
            let state = &mut self.beats[shard];
            if beat != state.last_beat {
                state.last_beat = beat;
                state.changed_at = now;
            }
            let health = if pool.shard_dead(shard) {
                ShardHealth::Dead
            } else if pool.depth(shard) > 0
                && now.duration_since(state.changed_at) >= self.config.stuck_after
            {
                ShardHealth::Stuck
            } else {
                ShardHealth::Healthy
            };
            verdict.health.push(health);
            match health {
                ShardHealth::Healthy => {
                    if let Some(since) = state.quarantined_since.take() {
                        pool.set_quarantined(shard, false);
                        state.next_restart_at = None;
                        verdict.released.push(now.duration_since(since));
                    }
                    // Budget decay: a sustained healthy streak earns
                    // one restart credit back. No-op while the shard's
                    // restart count is zero, so a fault-free pool is
                    // bit-for-bit unaffected.
                    state.healthy_ticks = state.healthy_ticks.saturating_add(1);
                    if self.config.heal_after_ticks > 0
                        && state.healthy_ticks >= self.config.heal_after_ticks
                    {
                        state.healthy_ticks = 0;
                        if pool.restore_restart_credit(shard) {
                            state.next_restart_at = None;
                            state.exhausted_reported = false;
                            verdict.credits_restored += 1;
                        }
                    }
                }
                ShardHealth::Stuck | ShardHealth::Dead => {
                    state.healthy_ticks = 0;
                    if state.quarantined_since.is_none() {
                        state.quarantined_since = Some(now);
                        pool.set_quarantined(shard, true);
                        verdict.trips += 1;
                    }
                    verdict.redirected.extend(pool.steal_queued(shard));
                    if health == ShardHealth::Dead {
                        let restarts = pool.restarts(shard);
                        let backoff_over =
                            state.next_restart_at.is_none_or(|t| now >= t);
                        if restarts >= self.config.max_restarts {
                            // Out of budget: surface it exactly once so
                            // the engine can apply its
                            // `on_budget_exhausted` policy.
                            if !state.exhausted_reported {
                                state.exhausted_reported = true;
                                verdict.budget_exhausted.push(shard);
                            }
                        } else if backoff_over && pool.respawn_shard(shard) {
                            verdict.restarted += 1;
                            // Exponential backoff for the *next*
                            // respawn of this shard.
                            let exp = restarts.min(10);
                            state.next_restart_at =
                                Some(now + self.config.backoff_base * (1u32 << exp));
                            // Fresh thread, fresh liveness baseline;
                            // release it immediately — its queue is
                            // intact and it can take traffic.
                            state.changed_at = now;
                            pool.set_quarantined(shard, false);
                            if let Some(since) = state.quarantined_since.take() {
                                verdict.released.push(now.duration_since(since));
                            }
                        }
                    }
                }
            }
        }
        verdict
    }

    /// Read-only classification of every shard, for the health surface:
    /// what a watchdog pass would decide *right now*, without
    /// quarantining, stealing, respawning, or advancing any beat
    /// state. Safe to call between (or without) `check` passes.
    pub fn peek<I: Send + 'static>(&self, pool: &RelicPool<I>) -> Vec<ShardStatus> {
        let now = Instant::now();
        (0..pool.shard_count())
            .map(|shard| {
                let state = &self.beats[shard];
                let advanced = pool.heartbeat(shard) != state.last_beat;
                let heartbeat_age = if advanced {
                    Duration::ZERO
                } else {
                    now.duration_since(state.changed_at)
                };
                let health = if pool.shard_dead(shard) {
                    ShardHealth::Dead
                } else if !advanced
                    && pool.depth(shard) > 0
                    && heartbeat_age >= self.config.stuck_after
                {
                    ShardHealth::Stuck
                } else {
                    ShardHealth::Healthy
                };
                ShardStatus {
                    health,
                    heartbeat_age,
                    quarantined_for: state.quarantined_since.map(|s| now.duration_since(s)),
                    restarts_used: pool.restarts(shard),
                    backoff_pending: state.next_restart_at.is_some_and(|t| now < t),
                }
            })
            .collect()
    }

    /// Forget shard `i`'s failure history — the `rebuild` policy calls
    /// this after reconstructing a budget-exhausted shard so the fresh
    /// thread starts with a clean slate (no backoff, no streak, and
    /// budget exhaustion is reportable again).
    pub fn forgive(&mut self, shard: usize) {
        let state = &mut self.beats[shard];
        state.changed_at = Instant::now();
        state.quarantined_since = None;
        state.next_restart_at = None;
        state.healthy_ticks = 0;
        state.exhausted_reported = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn sibling_pairs_parse_fixture_lists() {
        // A 4-core/8-thread topology: each pair appears twice (once per
        // sibling), in whatever order sysfs enumerates CPUs.
        let lists = ["0,4\n", "1,5\n", "2,6\n", "3,7\n", "4,0\n", "5,1\n", "6,2\n", "7,3\n"];
        assert_eq!(
            sibling_pairs_from_lists(lists),
            vec![(0, 4), (1, 5), (2, 6), (3, 7)]
        );
        // Range form (adjacent sibling numbering), deduplicated.
        let lists = ["0-1\n", "0-1\n", "2-3\n", "2-3\n"];
        assert_eq!(sibling_pairs_from_lists(lists), vec![(0, 1), (2, 3)]);
        // No SMT: one CPU per list → no pairs.
        let lists = ["0\n", "1\n", "2\n", "3\n"];
        assert!(sibling_pairs_from_lists(lists).is_empty());
        // Garbage and empties are skipped, valid entries survive.
        let lists = ["", "oops\n", "2,6\n"];
        assert_eq!(sibling_pairs_from_lists(lists), vec![(2, 6)]);
    }

    #[test]
    fn fallback_pairs_adjacent() {
        assert_eq!(fallback_pairs(8), vec![(0, 1), (2, 3), (4, 5), (6, 7)]);
        assert_eq!(fallback_pairs(3), vec![(0, 1)]);
        assert!(fallback_pairs(1).is_empty());
    }

    #[test]
    fn placements_respect_want_and_pin() {
        let unpinned = discover_placements(Some(3), false);
        assert_eq!(unpinned.len(), 3);
        for (i, p) in unpinned.iter().enumerate() {
            assert_eq!(p.shard, i);
            assert_eq!(p.main_cpu, None);
            assert_eq!(p.assistant_cpu, None);
        }
        // Auto sizing always yields at least one shard, even hostless.
        assert!(!discover_placements(None, true).is_empty());
        assert!(!discover_placements(None, false).is_empty());
        // Asking for more shards than the host has cores still works
        // (the surplus runs unpinned).
        assert_eq!(discover_placements(Some(64), true).len(), 64);
    }

    #[test]
    fn pool_processes_every_item_in_per_shard_fifo_order() {
        let (tx, rx) = mpsc::channel::<(usize, u64)>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(3), false),
            &PoolConfig { shards: Some(3), pin: false, ..PoolConfig::default() },
            |p: &ShardPlacement| p.shard,
            move |shard: &mut usize, batch: Vec<u64>| {
                for item in batch {
                    tx.send((*shard, item)).unwrap();
                }
            },
        );
        for i in 0..200u64 {
            pool.submit(i);
        }
        drop(pool); // joins shards: everything flushed
        let mut last_per_shard = [None::<u64>; 3];
        let mut seen = 0usize;
        while let Ok((shard, item)) = rx.recv() {
            if let Some(prev) = last_per_shard[shard] {
                assert!(prev < item, "shard {shard} reordered: {prev} before {item}");
            }
            last_per_shard[shard] = Some(item);
            seen += 1;
        }
        assert_eq!(seen, 200, "no item dropped");
    }

    #[test]
    fn backpressure_blocks_but_never_drops() {
        let (tx, rx) = mpsc::channel::<u64>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 1,
                max_batch: 1,
                ..PoolConfig::default()
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                // Slow consumer: force the capacity-1 queue to fill.
                std::thread::sleep(Duration::from_millis(1));
                for item in batch {
                    tx.send(item).unwrap();
                }
            },
        );
        for i in 0..32u64 {
            pool.submit(i);
        }
        let stalls = pool.stats().backpressure_stalls.get();
        assert!(stalls > 0, "capacity-1 queue must have stalled at least once");
        drop(pool);
        let got: Vec<u64> = rx.iter().collect();
        assert_eq!(got, (0..32).collect::<Vec<_>>(), "FIFO, nothing dropped");
    }

    #[test]
    fn least_loaded_routing_spreads_across_busy_shards() {
        // Handlers consume one gate token per item: every submitted
        // item keeps its shard's depth raised until the test releases
        // it, so the routing assertions below are deterministic — no
        // sleeps, no scheduler timing.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = std::sync::Mutex::new(gate_rx);
        let gate = Arc::new(gate);
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(2), false),
            &PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for _ in &batch {
                    gate.lock().unwrap().recv().unwrap();
                }
            },
        );
        // Depths at submit time: (0,0) → shard 0; (1,0) → shard 1;
        // (1,1) → shard 0 again (tie goes low).
        assert_eq!(pool.submit(1), 0);
        assert_eq!(pool.submit(2), 1);
        assert_eq!(pool.submit(3), 0);
        let snap = pool.snapshot();
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.dispatched, 3);
        // Release every held item before join.
        for _ in 0..3 {
            gate_tx.send(()).unwrap();
        }
        drop(pool);
    }

    /// A 1-shard pool whose handler consumes one gate token per item,
    /// so tests can hold the queue deterministically full.
    fn gated_pool(
        capacity: usize,
    ) -> (RelicPool<u64>, mpsc::Sender<()>, mpsc::Receiver<u64>) {
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let (out_tx, out_rx) = mpsc::channel::<u64>();
        let gate = Arc::new(std::sync::Mutex::new(gate_rx));
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: capacity,
                max_batch: 1,
                ..PoolConfig::default()
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for item in batch {
                    gate.lock().unwrap().recv().unwrap();
                    out_tx.send(item).unwrap();
                }
            },
        );
        (pool, gate_tx, out_rx)
    }

    #[test]
    fn try_submit_returns_item_on_full_channel() {
        let (pool, gate_tx, out_rx) = gated_pool(2);
        // Fill: one item may be held by the shard (blocked on the
        // gate), two sit in the capacity-2 queue. Stuff until full.
        let mut queued = 0u64;
        let mut bounced = None;
        for i in 0..64u64 {
            match pool.try_submit_to(0, i) {
                Ok(()) => queued += 1,
                Err(item) => {
                    bounced = Some(item);
                    break;
                }
            }
        }
        let bounced = bounced.expect("a bounded queue must fill");
        assert_eq!(bounced, queued, "the bounced item comes back unchanged");
        assert!(queued >= 2, "at least the queue capacity was accepted");
        // Depth only counts accepted items (the bounce was rolled back).
        assert_eq!(pool.depth(0), queued as usize);
        assert_eq!(pool.stats().dispatched.get(), queued);
        // Release everything; nothing was dropped, order preserved.
        for _ in 0..queued {
            gate_tx.send(()).unwrap();
        }
        drop(pool);
        let got: Vec<u64> = out_rx.iter().collect();
        assert_eq!(got, (0..queued).collect::<Vec<_>>());
    }

    #[test]
    fn parked_submit_delivers_after_drain() {
        let (pool, gate_tx, out_rx) = gated_pool(1);
        let pool = Arc::new(pool);
        // Fill the capacity-1 queue (plus the item the shard holds).
        let mut queued = 0u64;
        while pool.try_submit_to(0, queued).is_ok() {
            queued += 1;
        }
        // Park a producer on the full queue from another thread.
        let parked = {
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || pool.submit_or_park_to(0, queued))
        };
        // Release the gate: the consumer drains, notifies, and the
        // parked producer must deliver. (One token per item, items
        // 0..=queued.)
        for _ in 0..=queued {
            gate_tx.send(()).unwrap();
        }
        assert!(
            parked.join().unwrap().expect("shard is alive"),
            "producer reported parking"
        );
        assert_eq!(pool.stats().parked_submits.get(), 1);
        let pool = Arc::try_unwrap(pool).unwrap_or_else(|_| panic!("sole owner"));
        drop(pool);
        let got: Vec<u64> = out_rx.iter().collect();
        assert_eq!(got, (0..=queued).collect::<Vec<_>>(), "FIFO, parked item included");
    }

    #[test]
    fn parked_producer_never_loses_wakeup_under_churn() {
        // Capacity-1 stress loop: every submit races the consumer's
        // drain-notify. A lost wakeup deadlocks this test (bounded by
        // the park path's dead-shard timeout checks, it would still
        // hang — CI's timeout is the net).
        let (tx, rx) = mpsc::channel::<u64>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 1,
                max_batch: 1,
                ..PoolConfig::default()
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for item in batch {
                    tx.send(item).unwrap();
                }
            },
        );
        let n = 2000u64;
        for i in 0..n {
            pool.submit_or_park_to(0, i).expect("shard is alive");
        }
        assert!(
            pool.stats().parked_submits.get() > 0,
            "a capacity-1 queue under a tight submit loop must park at least once"
        );
        drop(pool);
        let got: Vec<u64> = rx.iter().collect();
        assert_eq!(got, (0..n).collect::<Vec<_>>(), "FIFO, nothing dropped");
    }

    #[test]
    fn depths_and_load_factor_track_in_flight_items() {
        let (pool, gate_tx, out_rx) = gated_pool(4);
        assert_eq!(pool.depths(), vec![0]);
        assert_eq!(pool.load_factor(), 0.0);
        assert_eq!(pool.channel_capacity(), 4);
        for i in 0..4u64 {
            pool.submit_to(0, i);
        }
        // All four are queued or held at the gate.
        assert_eq!(pool.depth(0), 4);
        assert!((pool.load_factor() - 1.0).abs() < f32::EPSILON);
        for _ in 0..4 {
            gate_tx.send(()).unwrap();
        }
        drop(pool);
        assert_eq!(out_rx.iter().count(), 4);
    }

    #[test]
    fn snapshot_counts_occupancy() {
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(2), false),
            &PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            |_: &mut (), _batch: Vec<u64>| {},
        );
        for i in 0..50 {
            pool.submit(i);
        }
        // Wait for the shards to drain so occupancy is stable.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let snap = pool.snapshot();
            if snap.occupancy.iter().sum::<u64>() == 50
                && snap.in_flight.iter().sum::<usize>() == 0
            {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "pool never drained");
            std::thread::yield_now();
        }
    }

    #[test]
    fn handler_panic_is_contained_and_the_shard_survives() {
        let (tx, rx) = mpsc::channel::<u64>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 8,
                max_batch: 1,
                ..PoolConfig::default()
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for item in batch {
                    if item == 3 {
                        panic!("poisoned item");
                    }
                    tx.send(item).unwrap();
                }
            },
        );
        for i in 0..8u64 {
            pool.submit_to(0, i);
        }
        // Wait for the shard to chew through everything.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.depth(0) > 0 {
            assert!(std::time::Instant::now() < deadline, "shard never drained");
            std::thread::yield_now();
        }
        assert!(!pool.shard_dead(0), "panic must not kill the shard thread");
        assert_eq!(pool.handler_panics(), 1);
        drop(pool);
        let got: Vec<u64> = rx.iter().collect();
        assert_eq!(got, vec![0, 1, 2, 4, 5, 6, 7], "only the poisoned item is missing");
    }

    #[test]
    fn steal_queued_takes_only_unprocessed_items_and_fixes_depth() {
        let (pool, gate_tx, out_rx) = gated_pool(8);
        for i in 0..6u64 {
            pool.submit_to(0, i);
        }
        // The shard holds item 0 at the gate; give it a beat to pop it
        // so the steal below can't race the first pop.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.depth(0) == 6 && pool.heartbeat(0) == 0 {
            assert!(std::time::Instant::now() < deadline, "shard never started");
            std::thread::yield_now();
        }
        let stolen = pool.steal_queued(0);
        // Item 0 was popped (at the gate); everything else is stolen.
        assert_eq!(stolen, vec![1, 2, 3, 4, 5]);
        assert_eq!(pool.depth(0), 1, "depth drops by the stolen count");
        gate_tx.send(()).unwrap();
        drop(pool);
        assert_eq!(out_rx.iter().collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn idle_hook_runs_when_empty_and_yields_to_new_work() {
        let idle_runs = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel::<u64>();
        let hook: IdleHook<()> = {
            let idle_runs = Arc::clone(&idle_runs);
            Arc::new(move |_state: &mut (), should_return: &(dyn Fn() -> bool + Sync)| {
                idle_runs.fetch_add(1, Ordering::Relaxed);
                // Sit in the hook like a lease would, until work
                // arrives or shutdown closes the queue.
                while !should_return() {
                    std::thread::yield_now();
                }
                true
            })
        };
        let pool = RelicPool::<u64>::with_placements_idle(
            discover_placements(Some(1), false),
            &PoolConfig { shards: Some(1), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for item in batch {
                    tx.send(item).unwrap();
                }
            },
            Some(hook),
        );
        // The empty queue must hand the shard to the idle hook.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while idle_runs.load(Ordering::Relaxed) == 0 {
            assert!(std::time::Instant::now() < deadline, "idle hook never ran");
            std::thread::yield_now();
        }
        // New work pulls the shard back out of the hook and is served
        // in order — the hook never costs an item or reorders one.
        for i in 0..16u64 {
            pool.submit_to(0, i);
        }
        drop(pool);
        assert_eq!(rx.iter().collect::<Vec<_>>(), (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn supervisor_respawns_a_killed_shard_and_work_completes() {
        let (tx, rx) = mpsc::channel::<u64>();
        let fault = Arc::new(FaultPlan::new().with_kill(0, 1));
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 16,
                max_batch: 4,
                fault: Some(fault),
                ..PoolConfig::default()
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for item in batch {
                    tx.send(item).unwrap();
                }
            },
        );
        let mut supervisor = Supervisor::new(
            SupervisorConfig {
                backoff_base: Duration::from_millis(1),
                ..SupervisorConfig::default()
            },
            pool.shard_count(),
        );
        for i in 0..8u64 {
            pool.submit_to(0, i);
        }
        // The first batch trips the kill (requeued, thread exits); the
        // supervisor must steal + respawn until everything drains.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut restarts = 0usize;
        while pool.depth(0) > 0 {
            assert!(std::time::Instant::now() < deadline, "pool never recovered");
            let verdict = supervisor.check(&pool);
            restarts += verdict.restarted;
            // Single-shard pool: redirect back onto the (respawned)
            // shard itself.
            for item in verdict.redirected {
                pool.submit_to(0, item);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(restarts >= 1, "the dead shard must have been respawned");
        assert_eq!(pool.restarts(0), restarts as u32);
        assert!(!pool.shard_dead(0));
        drop(pool);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>(), "every item processed exactly once");
    }

    #[test]
    fn parked_submit_reports_shard_dead_instead_of_hanging() {
        // A shard that dies before its first batch, with a full queue:
        // the parked producer must get the item back with ShardDead.
        let fault = Arc::new(FaultPlan::new().with_kill(0, 1));
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 2,
                max_batch: 1,
                park_timeout: Duration::from_millis(5),
                fault: Some(fault),
            },
            |_: &ShardPlacement| (),
            |_: &mut (), _batch: Vec<u64>| {},
        );
        // First submit wakes the shard, which requeues and dies.
        pool.submit_to(0, 0);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !pool.shard_dead(0) {
            assert!(std::time::Instant::now() < deadline, "kill fault never fired");
            std::thread::yield_now();
        }
        // Fill the remaining capacity, then park on the full queue.
        pool.submit_to(0, 1);
        let err = pool
            .submit_or_park_to(0, 2)
            .expect_err("parking on a dead shard must fail");
        assert_eq!(err.shard, 0);
        assert_eq!(err.item, 2);
        assert_eq!(pool.depth(0), 2, "the failed park rolled its depth back");
        // The queued items are still stealable — nothing was lost.
        assert_eq!(pool.steal_queued(0), vec![0, 1]);
    }

    #[test]
    fn quarantine_steers_least_loaded_routing_away() {
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(2), false),
            &PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            |_: &mut (), _batch: Vec<u64>| {},
        );
        assert_eq!(pool.quarantined_count(), 0);
        pool.set_quarantined(0, true);
        assert!(pool.is_quarantined(0));
        assert_eq!(pool.quarantined_count(), 1);
        // Shard 0 is idle (depth 0) but quarantined: routing must pick
        // shard 1 regardless.
        assert_eq!(pool.least_loaded(), 1);
        // Everything quarantined: fall back to the global minimum.
        pool.set_quarantined(1, true);
        assert_eq!(pool.least_loaded(), 0);
        pool.set_quarantined(0, false);
        assert_eq!(pool.quarantined_count(), 1);
    }
}
