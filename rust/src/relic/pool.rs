//! **RelicPool** — a pool of pinned pair-shards: one Relic SMT pair per
//! physical core.
//!
//! The paper scopes Relic to *one* SMT core: one main (producer) thread
//! and one assistant (consumer) thread over a lock-free SPSC queue.
//! Scaling that to a whole machine could widen the queue to MPMC — but
//! that would forfeit exactly what makes Relic fast: the single-producer
//! single-consumer invariant is what lets `push`/`pop` run lock-free
//! with one release store and no CAS on the hot path, and the pair's
//! cache affinity (both threads on one core's L1/L2) is the paper's
//! whole premise. So the pool **replicates the pair instead of widening
//! it** (the FastFlow lesson: SPSC channels compose into larger
//! topologies without giving up their guarantees):
//!
//! * topology discovery parses
//!   `/sys/devices/system/cpu/cpu*/topology/thread_siblings_list` into
//!   SMT sibling pairs (with a portable adjacent-CPU fallback pairing);
//! * one **shard** per physical core: a dedicated main thread, pinned
//!   to the pair's first logical CPU, that *owns* its shard state —
//!   typically a [`crate::coordinator::Coordinator`], whose embedded
//!   [`super::Relic`] pins its assistant to the sibling. Each Relic is
//!   created on, and only ever submitted to from, its shard thread, so
//!   the single-producer invariant holds *by construction*;
//! * an **admission layer**: items are dispatched to shards over
//!   per-shard bounded channels with least-loaded routing, through
//!   three flavors sharing the same counters and ordering guarantees:
//!   [`RelicPool::submit_to`] blocks on the full channel (backpressure
//!   — counted, never dropped, never reordered within a shard),
//!   [`RelicPool::try_submit_to`] returns the item on a full channel
//!   instead of waiting, and [`RelicPool::submit_or_park_to`] parks the
//!   producer on the shard's **drain signal** — a condvar the shard's
//!   consumer notifies every time it frees channel capacity — so a
//!   stalled producer sleeps until woken instead of spinning on
//!   `try_send`.
//!
//!   The waker protocol is lost-wakeup-free by construction: the
//!   producer re-checks `try_send` *while holding the signal lock*
//!   before every wait, and the consumer can only notify under that
//!   same lock, so capacity freed between the producer's failed check
//!   and its wait still produces a wakeup. A full channel
//!   also implies the consumer has items to drain, so the notify that
//!   releases the producer is always coming — and a parked producer
//!   still times out periodically to detect a dead (panicked) shard
//!   rather than waiting forever;
//! * a shard's inner loop drains its channel into small batches, so a
//!   batch handler built on `Coordinator::process_batch` still gets to
//!   pair requests two-at-a-time and run the odd leftover with
//!   intra-request fork-join — the paper's fine-grained scenario is
//!   preserved *inside* every shard.
//!
//! The pool is generic over the item type `I` and the shard state `S`
//! (built on the shard thread by a factory, so `S` need not be `Send`);
//! [`crate::coordinator::Engine`] instantiates it with
//! `I = sequenced Request`, `S = Coordinator`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::metrics::Counter;

use super::affinity::{num_cpus, parse_cpulist, pin_to_cpu, sibling_lists};

/// Default bound of each shard's admission channel.
pub const DEFAULT_CHANNEL_CAPACITY: usize = 64;

/// Default maximum items a shard's inner loop hands its batch handler.
pub const DEFAULT_MAX_BATCH: usize = 32;

/// Pool sizing and placement knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Number of shards; `None` = one per detected physical core.
    pub shards: Option<usize>,
    /// Pin shard main threads (and their Relic assistants) to sibling
    /// pairs. Disable on hosts where affinity calls are denied.
    pub pin: bool,
    /// Per-shard bounded channel depth (admission backpressure point).
    pub channel_capacity: usize,
    /// Maximum items per batch handed to the shard's inner loop.
    pub max_batch: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: None,
            pin: true,
            channel_capacity: DEFAULT_CHANNEL_CAPACITY,
            max_batch: DEFAULT_MAX_BATCH,
        }
    }
}

/// Where one shard runs: its main thread's CPU and its Relic
/// assistant's CPU (`None` = unpinned).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlacement {
    pub shard: usize,
    pub main_cpu: Option<usize>,
    pub assistant_cpu: Option<usize>,
}

/// Parse sysfs `thread_siblings_list` contents into deduplicated SMT
/// sibling pairs, sorted by first CPU. Each sibling's file names the
/// same pair, so the raw list contains every pair twice; lists with
/// fewer than two CPUs (no SMT) and unparsable entries are skipped.
pub fn sibling_pairs_from_lists<'a, I>(lists: I) -> Vec<(usize, usize)>
where
    I: IntoIterator<Item = &'a str>,
{
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for text in lists {
        let cpus = parse_cpulist(text);
        if cpus.len() >= 2 {
            let key = (cpus[0].min(cpus[1]), cpus[0].max(cpus[1]));
            if !pairs.contains(&key) {
                pairs.push(key);
            }
        }
    }
    pairs.sort_unstable();
    pairs
}

/// Portable fallback pairing when sysfs exposes no sibling topology:
/// adjacent logical CPUs `(2i, 2i+1)`. Not true SMT siblings, but the
/// pinning still gives each shard two stable, distinct CPUs.
pub fn fallback_pairs(cpus: usize) -> Vec<(usize, usize)> {
    (0..cpus / 2).map(|i| (2 * i, 2 * i + 1)).collect()
}

/// The host's physical-core pairs: sysfs SMT siblings where available,
/// otherwise the adjacent-CPU fallback (which may be empty on a
/// single-CPU host — callers fall back to unpinned shards).
pub fn physical_core_pairs() -> Vec<(usize, usize)> {
    let lists = sibling_lists();
    let pairs = sibling_pairs_from_lists(lists.iter().map(String::as_str));
    if pairs.is_empty() {
        fallback_pairs(num_cpus())
    } else {
        pairs
    }
}

/// Decide shard placements: `want` shards (default: one per physical
/// core, minimum one), pinned onto the discovered pairs in order.
/// Shards beyond the available pairs — or all shards when `pin` is
/// false — run unpinned.
pub fn discover_placements(want: Option<usize>, pin: bool) -> Vec<ShardPlacement> {
    let pairs = if pin { physical_core_pairs() } else { Vec::new() };
    let n = want.unwrap_or_else(|| pairs.len().max(1)).max(1);
    (0..n)
        .map(|shard| match pairs.get(shard) {
            Some(&(a, b)) if pin => ShardPlacement {
                shard,
                main_cpu: Some(a),
                assistant_cpu: Some(b),
            },
            _ => ShardPlacement { shard, main_cpu: None, assistant_cpu: None },
        })
        .collect()
}

/// Pool-level admission counters.
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Items routed to a shard.
    pub dispatched: Counter,
    /// Submissions that found the chosen shard's channel full and had
    /// to block (backpressure events; the item is still delivered).
    pub backpressure_stalls: Counter,
    /// Submissions that found the channel full and parked on the
    /// shard's drain signal (the item is still delivered).
    pub parked_submits: Counter,
}

/// How long a parked producer sleeps between dead-shard checks. Pure
/// liveness insurance: the normal wakeup is the consumer's notify.
const PARK_CHECK_INTERVAL: Duration = Duration::from_millis(50);

/// The consumer-to-producer wakeup slot of one shard: a condvar parked
/// producers wait on. The mutex guards no data — it exists to order
/// the producer's full-channel check against the consumer's notify
/// (the classic lost-wakeup-free Mutex+Condvar shape; producers re-run
/// `try_send` under the lock before every wait).
#[derive(Debug, Default)]
struct DrainSignal {
    lock: Mutex<()>,
    drained: Condvar,
}

impl DrainSignal {
    /// Consumer side: capacity was freed — wake every parked producer.
    /// Taking the lock first is what closes the lost-wakeup window
    /// (see the module docs).
    fn notify(&self) {
        let _guard = self.lock.lock().expect("drain signal poisoned");
        self.drained.notify_all();
    }
}

/// Point-in-time view of the pool (see [`RelicPool::snapshot`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolSnapshot {
    pub shards: usize,
    pub dispatched: u64,
    pub backpressure_stalls: u64,
    pub parked_submits: u64,
    /// Items completed per shard (shard occupancy over the run).
    pub occupancy: Vec<u64>,
    /// Items queued or in processing per shard right now.
    pub in_flight: Vec<usize>,
}

/// Per-shard bookkeeping kept on the admission side.
struct ShardInfo {
    placement: ShardPlacement,
    /// Items queued or being processed (incremented at submit,
    /// decremented by the shard after each batch) — the least-loaded
    /// routing signal.
    depth: Arc<AtomicUsize>,
    /// Items the shard has finished.
    completed: Arc<Counter>,
    /// Wakes producers parked on this shard's full channel.
    signal: Arc<DrainSignal>,
}

/// A pool of pair-shards processing items of type `I`.
pub struct RelicPool<I: Send + 'static> {
    senders: Vec<SyncSender<I>>,
    shards: Vec<ShardInfo>,
    joins: Vec<JoinHandle<()>>,
    stats: PoolStats,
    /// Per-shard admission-channel bound (for load-factor reporting).
    channel_capacity: usize,
}

impl<I: Send + 'static> RelicPool<I> {
    /// Spawn a pool per `config`. `factory` runs once on each shard
    /// thread (after pinning) to build the shard's state — this is
    /// where a `Coordinator`, and with it the shard's `Relic` pair, is
    /// created, so the state never crosses threads. `handler` processes
    /// each drained batch against that state.
    pub fn new<S, F, H>(config: &PoolConfig, factory: F, handler: H) -> Self
    where
        S: 'static,
        F: Fn(&ShardPlacement) -> S + Send + Clone + 'static,
        H: Fn(&mut S, Vec<I>) + Send + Clone + 'static,
    {
        let placements = discover_placements(config.shards, config.pin);
        Self::with_placements(placements, config, factory, handler)
    }

    /// [`new`](Self::new) with explicit placements (the admission layer
    /// above may need the shard count before spawning, e.g. to set up
    /// per-shard metrics).
    pub fn with_placements<S, F, H>(
        placements: Vec<ShardPlacement>,
        config: &PoolConfig,
        factory: F,
        handler: H,
    ) -> Self
    where
        S: 'static,
        F: Fn(&ShardPlacement) -> S + Send + Clone + 'static,
        H: Fn(&mut S, Vec<I>) + Send + Clone + 'static,
    {
        assert!(!placements.is_empty(), "RelicPool needs at least one shard");
        let max_batch = config.max_batch.max(1);
        let capacity = config.channel_capacity.max(1);
        let mut senders = Vec::with_capacity(placements.len());
        let mut shards = Vec::with_capacity(placements.len());
        let mut joins = Vec::with_capacity(placements.len());
        for placement in placements {
            let (tx, rx) = sync_channel::<I>(capacity);
            let depth = Arc::new(AtomicUsize::new(0));
            let completed = Arc::new(Counter::new());
            let signal = Arc::new(DrainSignal::default());
            let join = std::thread::Builder::new()
                .name(format!("relic-shard-{}", placement.shard))
                .spawn({
                    let factory = factory.clone();
                    let handler = handler.clone();
                    let depth = Arc::clone(&depth);
                    let completed = Arc::clone(&completed);
                    let signal = Arc::clone(&signal);
                    let placement = placement.clone();
                    move || {
                        shard_loop(
                            rx, &placement, factory, handler, &depth, &completed, &signal,
                            max_batch,
                        )
                    }
                })
                .expect("failed to spawn relic pool shard");
            senders.push(tx);
            shards.push(ShardInfo { placement, depth, completed, signal });
            joins.push(join);
        }
        RelicPool {
            senders,
            shards,
            joins,
            stats: PoolStats::default(),
            channel_capacity: capacity,
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Placement of shard `i`.
    pub fn placement(&self, shard: usize) -> &ShardPlacement {
        &self.shards[shard].placement
    }

    /// The shard with the fewest items queued or in processing (ties go
    /// to the lowest index).
    pub fn least_loaded(&self) -> usize {
        let mut best = 0;
        let mut best_depth = usize::MAX;
        for (i, s) in self.shards.iter().enumerate() {
            let d = s.depth.load(Ordering::Acquire);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        best
    }

    /// Dispatch `item` to the least-loaded shard; returns the shard
    /// index it went to. Blocks (and counts a backpressure stall) when
    /// that shard's channel is full — items are never dropped, and
    /// per-shard FIFO order is preserved.
    pub fn submit(&self, item: I) -> usize {
        let shard = self.least_loaded();
        self.submit_to(shard, item);
        shard
    }

    /// Dispatch `item` to a specific shard (same backpressure rules as
    /// [`submit`](Self::submit)).
    pub fn submit_to(&self, shard: usize, item: I) {
        self.shards[shard].depth.fetch_add(1, Ordering::AcqRel);
        self.stats.dispatched.inc();
        match self.senders[shard].try_send(item) {
            Ok(()) => {}
            Err(TrySendError::Full(item)) => {
                self.stats.backpressure_stalls.inc();
                self.senders[shard]
                    .send(item)
                    .expect("relic pool shard thread died");
            }
            Err(TrySendError::Disconnected(_)) => {
                panic!("relic pool shard thread died");
            }
        }
    }

    /// Non-blocking dispatch to a specific shard. `Ok(())` means the
    /// item is queued (counted, same FIFO guarantees as
    /// [`submit_to`](Self::submit_to)); a full channel hands the item
    /// back unchanged and counts nothing, so the caller can retry,
    /// park, or shed it without losing it.
    pub fn try_submit_to(&self, shard: usize, item: I) -> Result<(), I> {
        // Depth goes up *before* the send so a concurrent consumer
        // finishing the item can never decrement first (which would
        // wrap the unsigned depth and wreck least-loaded routing).
        self.shards[shard].depth.fetch_add(1, Ordering::AcqRel);
        match self.senders[shard].try_send(item) {
            Ok(()) => {
                self.stats.dispatched.inc();
                Ok(())
            }
            Err(TrySendError::Full(item)) => {
                self.shards[shard].depth.fetch_sub(1, Ordering::AcqRel);
                Err(item)
            }
            Err(TrySendError::Disconnected(_)) => {
                panic!("relic pool shard thread died");
            }
        }
    }

    /// Dispatch to a specific shard, parking on the shard's drain
    /// signal when the channel is full: the producer sleeps until the
    /// consumer frees capacity instead of spinning or blocking inside
    /// the channel. Returns `true` when it had to park (counted in
    /// [`PoolStats::parked_submits`]). Delivery is guaranteed: a parked
    /// producer can only end by enqueueing the item or by panicking on
    /// a dead shard.
    pub fn submit_or_park_to(&self, shard: usize, item: I) -> bool {
        self.shards[shard].depth.fetch_add(1, Ordering::AcqRel);
        self.stats.dispatched.inc();
        let mut item = match self.senders[shard].try_send(item) {
            Ok(()) => return false,
            Err(TrySendError::Full(item)) => item,
            Err(TrySendError::Disconnected(_)) => panic!("relic pool shard thread died"),
        };
        self.stats.parked_submits.inc();
        let signal = &self.shards[shard].signal;
        let mut guard = signal.lock.lock().expect("drain signal poisoned");
        loop {
            // Re-check under the lock: the consumer cannot get the lock
            // to notify between this failure and the wait below, so a
            // wakeup for freed capacity is never lost.
            match self.senders[shard].try_send(item) {
                Ok(()) => return true,
                Err(TrySendError::Full(it)) => item = it,
                Err(TrySendError::Disconnected(_)) => panic!("relic pool shard thread died"),
            }
            let (g, timeout) = signal
                .drained
                .wait_timeout(guard, PARK_CHECK_INTERVAL)
                .expect("drain signal poisoned");
            guard = g;
            if timeout.timed_out() {
                assert!(
                    !self.joins[shard].is_finished(),
                    "relic pool shard {shard} died with a producer parked on it"
                );
            }
        }
    }

    /// Items queued or in processing on one shard right now.
    pub fn depth(&self, shard: usize) -> usize {
        self.shards[shard].depth.load(Ordering::Acquire)
    }

    /// Per-shard depths (the least-loaded / least-slack routing input).
    pub fn depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth.load(Ordering::Acquire)).collect()
    }

    /// [`depths`](Self::depths) without the allocation — what the
    /// engine's per-request routing reads.
    pub fn depths_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.shards.iter().map(|s| s.depth.load(Ordering::Acquire))
    }

    /// Per-shard admission-channel bound.
    pub fn channel_capacity(&self) -> usize {
        self.channel_capacity
    }

    /// Fraction of total admission capacity currently claimed. Depth
    /// counts items *in processing* as well as queued, so sustained
    /// overload reads above 1.0 — the load-factor shed policy treats
    /// its threshold as "queued work per queue slot", not a percentage.
    pub fn load_factor(&self) -> f32 {
        let total: usize = self.shards.iter().map(|s| s.depth.load(Ordering::Acquire)).sum();
        total as f32 / (self.shards.len() * self.channel_capacity) as f32
    }

    /// Admission counters.
    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Shards whose threads have exited. While the pool is alive the
    /// channels are open, so a finished shard thread can only mean its
    /// handler (or factory) panicked — responses routed to it are lost.
    /// Admission layers poll this instead of blocking forever on them.
    pub fn dead_shards(&self) -> Vec<usize> {
        self.joins
            .iter()
            .enumerate()
            .filter(|(_, j)| j.is_finished())
            .map(|(i, _)| i)
            .collect()
    }

    /// Point-in-time counters for reporting.
    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            shards: self.shards.len(),
            dispatched: self.stats.dispatched.get(),
            backpressure_stalls: self.stats.backpressure_stalls.get(),
            parked_submits: self.stats.parked_submits.get(),
            occupancy: self.shards.iter().map(|s| s.completed.get()).collect(),
            in_flight: self.shards.iter().map(|s| s.depth.load(Ordering::Acquire)).collect(),
        }
    }
}

impl<I: Send + 'static> Drop for RelicPool<I> {
    fn drop(&mut self) {
        // Closing the channels ends each shard loop after it drains its
        // remaining items; joining flushes all in-flight work.
        self.senders.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// A shard's inner loop: pin, build state, then drain the channel in
/// small batches. Blocking on the first item of a batch and
/// `try_recv`-draining the rest gives natural micro-batching — under
/// load the handler sees multi-request batches (so a
/// `Coordinator`-backed handler still pairs requests on the SMT core),
/// while a lone request is processed immediately.
#[allow(clippy::too_many_arguments)]
fn shard_loop<I, S, F, H>(
    rx: Receiver<I>,
    placement: &ShardPlacement,
    factory: F,
    handler: H,
    depth: &AtomicUsize,
    completed: &Counter,
    signal: &DrainSignal,
    max_batch: usize,
) where
    F: Fn(&ShardPlacement) -> S,
    H: Fn(&mut S, Vec<I>),
{
    if let Some(cpu) = placement.main_cpu {
        pin_to_cpu(cpu);
    }
    let mut state = factory(placement);
    loop {
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => break,
        };
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(item) => batch.push(item),
                Err(_) => break,
            }
        }
        // Every recv above freed a channel slot: wake parked producers
        // *before* the (potentially long) handler call, so admission
        // refills the queue while this batch is being processed.
        signal.notify();
        let n = batch.len();
        handler(&mut state, batch);
        depth.fetch_sub(n, Ordering::AcqRel);
        completed.add(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn sibling_pairs_parse_fixture_lists() {
        // A 4-core/8-thread topology: each pair appears twice (once per
        // sibling), in whatever order sysfs enumerates CPUs.
        let lists = ["0,4\n", "1,5\n", "2,6\n", "3,7\n", "4,0\n", "5,1\n", "6,2\n", "7,3\n"];
        assert_eq!(
            sibling_pairs_from_lists(lists),
            vec![(0, 4), (1, 5), (2, 6), (3, 7)]
        );
        // Range form (adjacent sibling numbering), deduplicated.
        let lists = ["0-1\n", "0-1\n", "2-3\n", "2-3\n"];
        assert_eq!(sibling_pairs_from_lists(lists), vec![(0, 1), (2, 3)]);
        // No SMT: one CPU per list → no pairs.
        let lists = ["0\n", "1\n", "2\n", "3\n"];
        assert!(sibling_pairs_from_lists(lists).is_empty());
        // Garbage and empties are skipped, valid entries survive.
        let lists = ["", "oops\n", "2,6\n"];
        assert_eq!(sibling_pairs_from_lists(lists), vec![(2, 6)]);
    }

    #[test]
    fn fallback_pairs_adjacent() {
        assert_eq!(fallback_pairs(8), vec![(0, 1), (2, 3), (4, 5), (6, 7)]);
        assert_eq!(fallback_pairs(3), vec![(0, 1)]);
        assert!(fallback_pairs(1).is_empty());
    }

    #[test]
    fn placements_respect_want_and_pin() {
        let unpinned = discover_placements(Some(3), false);
        assert_eq!(unpinned.len(), 3);
        for (i, p) in unpinned.iter().enumerate() {
            assert_eq!(p.shard, i);
            assert_eq!(p.main_cpu, None);
            assert_eq!(p.assistant_cpu, None);
        }
        // Auto sizing always yields at least one shard, even hostless.
        assert!(!discover_placements(None, true).is_empty());
        assert!(!discover_placements(None, false).is_empty());
        // Asking for more shards than the host has cores still works
        // (the surplus runs unpinned).
        assert_eq!(discover_placements(Some(64), true).len(), 64);
    }

    #[test]
    fn pool_processes_every_item_in_per_shard_fifo_order() {
        let (tx, rx) = mpsc::channel::<(usize, u64)>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(3), false),
            &PoolConfig { shards: Some(3), pin: false, ..PoolConfig::default() },
            |p: &ShardPlacement| p.shard,
            move |shard: &mut usize, batch: Vec<u64>| {
                for item in batch {
                    tx.send((*shard, item)).unwrap();
                }
            },
        );
        for i in 0..200u64 {
            pool.submit(i);
        }
        drop(pool); // joins shards: everything flushed
        let mut last_per_shard = [None::<u64>; 3];
        let mut seen = 0usize;
        while let Ok((shard, item)) = rx.recv() {
            if let Some(prev) = last_per_shard[shard] {
                assert!(prev < item, "shard {shard} reordered: {prev} before {item}");
            }
            last_per_shard[shard] = Some(item);
            seen += 1;
        }
        assert_eq!(seen, 200, "no item dropped");
    }

    #[test]
    fn backpressure_blocks_but_never_drops() {
        let (tx, rx) = mpsc::channel::<u64>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 1,
                max_batch: 1,
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                // Slow consumer: force the capacity-1 channel to fill.
                std::thread::sleep(Duration::from_millis(1));
                for item in batch {
                    tx.send(item).unwrap();
                }
            },
        );
        for i in 0..32u64 {
            pool.submit(i);
        }
        let stalls = pool.stats().backpressure_stalls.get();
        assert!(stalls > 0, "capacity-1 channel must have stalled at least once");
        drop(pool);
        let got: Vec<u64> = rx.iter().collect();
        assert_eq!(got, (0..32).collect::<Vec<_>>(), "FIFO, nothing dropped");
    }

    #[test]
    fn least_loaded_routing_spreads_across_busy_shards() {
        // Handlers consume one gate token per item: every submitted
        // item keeps its shard's depth raised until the test releases
        // it, so the routing assertions below are deterministic — no
        // sleeps, no scheduler timing.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate = std::sync::Mutex::new(gate_rx);
        let gate = Arc::new(gate);
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(2), false),
            &PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for _ in &batch {
                    gate.lock().unwrap().recv().unwrap();
                }
            },
        );
        // Depths at submit time: (0,0) → shard 0; (1,0) → shard 1;
        // (1,1) → shard 0 again (tie goes low).
        assert_eq!(pool.submit(1), 0);
        assert_eq!(pool.submit(2), 1);
        assert_eq!(pool.submit(3), 0);
        let snap = pool.snapshot();
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.dispatched, 3);
        // Release every held item before join.
        for _ in 0..3 {
            gate_tx.send(()).unwrap();
        }
        drop(pool);
    }

    /// A 1-shard pool whose handler consumes one gate token per item,
    /// so tests can hold the channel deterministically full.
    fn gated_pool(
        capacity: usize,
    ) -> (RelicPool<u64>, mpsc::Sender<()>, mpsc::Receiver<u64>) {
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let (out_tx, out_rx) = mpsc::channel::<u64>();
        let gate = Arc::new(std::sync::Mutex::new(gate_rx));
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: capacity,
                max_batch: 1,
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for item in batch {
                    gate.lock().unwrap().recv().unwrap();
                    out_tx.send(item).unwrap();
                }
            },
        );
        (pool, gate_tx, out_rx)
    }

    #[test]
    fn try_submit_returns_item_on_full_channel() {
        let (pool, gate_tx, out_rx) = gated_pool(2);
        // Fill: one item may be held by the shard (blocked on the
        // gate), two sit in the capacity-2 channel. Stuff until full.
        let mut queued = 0u64;
        let mut bounced = None;
        for i in 0..64u64 {
            match pool.try_submit_to(0, i) {
                Ok(()) => queued += 1,
                Err(item) => {
                    bounced = Some(item);
                    break;
                }
            }
        }
        let bounced = bounced.expect("a bounded channel must fill");
        assert_eq!(bounced, queued, "the bounced item comes back unchanged");
        assert!(queued >= 2, "at least the channel capacity was accepted");
        // Depth only counts accepted items (the bounce was rolled back).
        assert_eq!(pool.depth(0), queued as usize);
        assert_eq!(pool.stats().dispatched.get(), queued);
        // Release everything; nothing was dropped, order preserved.
        for _ in 0..queued {
            gate_tx.send(()).unwrap();
        }
        drop(pool);
        let got: Vec<u64> = out_rx.iter().collect();
        assert_eq!(got, (0..queued).collect::<Vec<_>>());
    }

    #[test]
    fn parked_submit_delivers_after_drain() {
        let (pool, gate_tx, out_rx) = gated_pool(1);
        let pool = Arc::new(pool);
        // Fill the capacity-1 channel (plus the item the shard holds).
        let mut queued = 0u64;
        while pool.try_submit_to(0, queued).is_ok() {
            queued += 1;
        }
        // Park a producer on the full channel from another thread.
        let parked = {
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || pool.submit_or_park_to(0, queued))
        };
        // Release the gate: the consumer drains, notifies, and the
        // parked producer must deliver. (One token per item, items
        // 0..=queued.)
        for _ in 0..=queued {
            gate_tx.send(()).unwrap();
        }
        assert!(parked.join().unwrap(), "producer reported parking");
        assert_eq!(pool.stats().parked_submits.get(), 1);
        let pool = Arc::try_unwrap(pool).unwrap_or_else(|_| panic!("sole owner"));
        drop(pool);
        let got: Vec<u64> = out_rx.iter().collect();
        assert_eq!(got, (0..=queued).collect::<Vec<_>>(), "FIFO, parked item included");
    }

    #[test]
    fn parked_producer_never_loses_wakeup_under_churn() {
        // Capacity-1 stress loop: every submit races the consumer's
        // drain-notify. A lost wakeup deadlocks this test (bounded by
        // the park path's dead-shard timeout assertions, it would still
        // hang — CI's timeout is the net).
        let (tx, rx) = mpsc::channel::<u64>();
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(1), false),
            &PoolConfig {
                shards: Some(1),
                pin: false,
                channel_capacity: 1,
                max_batch: 1,
            },
            |_: &ShardPlacement| (),
            move |_: &mut (), batch: Vec<u64>| {
                for item in batch {
                    tx.send(item).unwrap();
                }
            },
        );
        let n = 2000u64;
        for i in 0..n {
            pool.submit_or_park_to(0, i);
        }
        assert!(
            pool.stats().parked_submits.get() > 0,
            "a capacity-1 channel under a tight submit loop must park at least once"
        );
        drop(pool);
        let got: Vec<u64> = rx.iter().collect();
        assert_eq!(got, (0..n).collect::<Vec<_>>(), "FIFO, nothing dropped");
    }

    #[test]
    fn depths_and_load_factor_track_in_flight_items() {
        let (pool, gate_tx, out_rx) = gated_pool(4);
        assert_eq!(pool.depths(), vec![0]);
        assert_eq!(pool.load_factor(), 0.0);
        assert_eq!(pool.channel_capacity(), 4);
        for i in 0..4u64 {
            pool.submit_to(0, i);
        }
        // All four are queued or held at the gate.
        assert_eq!(pool.depth(0), 4);
        assert!((pool.load_factor() - 1.0).abs() < f32::EPSILON);
        for _ in 0..4 {
            gate_tx.send(()).unwrap();
        }
        drop(pool);
        assert_eq!(out_rx.iter().count(), 4);
    }

    #[test]
    fn snapshot_counts_occupancy() {
        let pool = RelicPool::<u64>::with_placements(
            discover_placements(Some(2), false),
            &PoolConfig { shards: Some(2), pin: false, ..PoolConfig::default() },
            |_: &ShardPlacement| (),
            |_: &mut (), _batch: Vec<u64>| {},
        );
        for i in 0..50 {
            pool.submit(i);
        }
        // Wait for the shards to drain so occupancy is stable.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let snap = pool.snapshot();
            if snap.occupancy.iter().sum::<u64>() == 50
                && snap.in_flight.iter().sum::<usize>() == 0
            {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "pool never drained");
            std::thread::yield_now();
        }
    }
}
