//! Lock-free single-producer single-consumer ring queue.
//!
//! The paper (§VI-A) uses Boost.Lockfree's SPSC queue with capacity 128;
//! this is the equivalent structure: a power-of-two ring with
//! cache-line-padded head/tail indices, acquire/release publication, and
//! producer/consumer-local cached copies of the opposite index so the
//! common case touches only one shared cache line (Lamport queue with
//! the FastForward-style index caching of [63]).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Pad to a cache line to prevent head/tail false sharing.
#[repr(align(64))]
struct Padded<T>(T);

/// Fixed-capacity lock-free SPSC queue.
///
/// Exactly one thread may call [`push`](Self::push) and exactly one
/// thread may call [`pop`](Self::pop); this is enforced by the owning
/// types ([`crate::relic::Relic`] splits producer and consumer sides),
/// not by this struct itself — hence the `unsafe impl Sync`.
pub struct SpscQueue<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    /// Next slot to write (owned by producer).
    head: Padded<AtomicUsize>,
    /// Producer's cached copy of `tail` (avoids loading the shared line).
    head_cache: UnsafeCell<usize>,
    /// Next slot to read (owned by consumer).
    tail: Padded<AtomicUsize>,
    /// Consumer's cached copy of `head`.
    tail_cache: UnsafeCell<usize>,
}

// SAFETY: single-producer / single-consumer discipline is upheld by the
// owning wrappers; all cross-thread data flows through acquire/release
// pairs on head/tail.
unsafe impl<T: Send> Sync for SpscQueue<T> {}
unsafe impl<T: Send> Send for SpscQueue<T> {}

impl<T> SpscQueue<T> {
    /// Create a queue with capacity rounded up to a power of two
    /// (the paper's configuration is 128 entries).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let buf = (0..cap)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        SpscQueue {
            buf,
            mask: cap - 1,
            head: Padded(AtomicUsize::new(0)),
            head_cache: UnsafeCell::new(0),
            tail: Padded(AtomicUsize::new(0)),
            tail_cache: UnsafeCell::new(0),
        }
    }

    /// Ring capacity.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Producer side: enqueue, or give the value back if full.
    ///
    /// # Safety contract (upheld by wrappers)
    /// Must only ever be called from one thread at a time.
    #[inline]
    pub fn push(&self, value: T) -> Result<(), T> {
        let head = self.head.0.load(Ordering::Relaxed);
        // Fast path: use the cached tail; refresh only when it looks full.
        // SAFETY: head_cache is only touched by the producer thread.
        let cached = unsafe { &mut *self.head_cache.get() };
        if head.wrapping_sub(*cached) > self.mask {
            *cached = self.tail.0.load(Ordering::Acquire);
            if head.wrapping_sub(*cached) > self.mask {
                return Err(value);
            }
        }
        // SAFETY: slot is vacant — consumer is at/behind *cached; index
        // is masked to capacity (get_unchecked keeps the ~70 ns hot path
        // free of bounds checks — EXPERIMENTS.md §Perf).
        unsafe {
            (*self.buf.get_unchecked(head & self.mask).get()).write(value);
        }
        self.head.0.store(head.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Producer side: enqueue a prefix of `values`, publishing the whole
    /// block with a **single** release store on `head` (one cache-line
    /// handoff per batch instead of one per element). Returns how many
    /// values were enqueued — `values.len()` when everything fit, less
    /// when the ring filled up, 0 when full.
    ///
    /// `T: Copy` keeps the batch path a plain slot-by-slot copy; the
    /// non-`Copy` case would need ownership transfer out of the slice.
    ///
    /// # Safety contract (upheld by wrappers)
    /// Must only ever be called from one thread at a time (the producer).
    #[inline]
    pub fn push_many(&self, values: &[T]) -> usize
    where
        T: Copy,
    {
        if values.is_empty() {
            return 0;
        }
        let head = self.head.0.load(Ordering::Relaxed);
        // SAFETY: head_cache is only touched by the producer thread.
        let cached = unsafe { &mut *self.head_cache.get() };
        let mut free = self.capacity() - head.wrapping_sub(*cached);
        // The cached tail underestimates free space; refresh it only
        // when the batch doesn't already fit (same policy as `push`).
        if free < values.len() {
            *cached = self.tail.0.load(Ordering::Acquire);
            free = self.capacity() - head.wrapping_sub(*cached);
        }
        let n = free.min(values.len());
        for (i, v) in values[..n].iter().enumerate() {
            // SAFETY: the n slots starting at head are vacant (consumer
            // is at/behind *cached); indices are masked to capacity.
            unsafe {
                (*self.buf.get_unchecked(head.wrapping_add(i) & self.mask).get()).write(*v);
            }
        }
        if n > 0 {
            self.head.0.store(head.wrapping_add(n), Ordering::Release);
        }
        n
    }

    /// Consumer side: dequeue if non-empty.
    #[inline]
    pub fn pop(&self) -> Option<T> {
        let tail = self.tail.0.load(Ordering::Relaxed);
        // SAFETY: tail_cache is only touched by the consumer thread.
        let cached = unsafe { &mut *self.tail_cache.get() };
        if *cached == tail {
            *cached = self.head.0.load(Ordering::Acquire);
            if *cached == tail {
                return None;
            }
        }
        // SAFETY: slot was published by the release store in push; index
        // is masked to capacity.
        let value =
            unsafe { (*self.buf.get_unchecked(tail & self.mask).get()).assume_init_read() };
        self.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        Some(value)
    }

    /// Approximate occupancy (exact when called from the producer).
    #[inline]
    pub fn len(&self) -> usize {
        self.head
            .0
            .load(Ordering::Acquire)
            .wrapping_sub(self.tail.0.load(Ordering::Acquire))
    }

    /// True if currently empty (approximate across threads).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for SpscQueue<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = SpscQueue::new(8);
        for i in 0..8 {
            q.push(i).unwrap();
        }
        assert!(q.push(99).is_err(), "capacity 8 must reject the 9th");
        for i in 0..8 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn capacity_rounds_to_pow2() {
        assert_eq!(SpscQueue::<u8>::new(100).capacity(), 128);
        assert_eq!(SpscQueue::<u8>::new(128).capacity(), 128);
        assert_eq!(SpscQueue::<u8>::new(1).capacity(), 2);
    }

    #[test]
    fn wraparound_many_times() {
        let q = SpscQueue::new(4);
        for round in 0u64..1000 {
            q.push(round).unwrap();
            assert_eq!(q.pop(), Some(round));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn cross_thread_transfers_everything_in_order() {
        let q = Arc::new(SpscQueue::new(128));
        let n = 20_000u64;
        let prod = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..n {
                    let mut v = i;
                    loop {
                        match q.push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            })
        };
        let mut expected = 0u64;
        while expected < n {
            if let Some(v) = q.pop() {
                assert_eq!(v, expected, "FIFO violated");
                expected += 1;
            } else {
                std::thread::yield_now();
            }
        }
        prod.join().unwrap();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn drops_remaining_items() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        {
            let q = SpscQueue::new(8);
            for _ in 0..5 {
                assert!(q.push(D).is_ok());
            }
            let _ = q.pop();
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn push_many_fifo_and_partial_fill() {
        let q = SpscQueue::new(8);
        assert_eq!(q.push_many(&[] as &[u64]), 0, "empty batch is a no-op");
        assert_eq!(q.push_many(&[1u64, 2, 3]), 3);
        // Only 5 slots left: the batch is cut to the free space.
        assert_eq!(q.push_many(&[4, 5, 6, 7, 8, 9, 10]), 5);
        assert_eq!(q.push_many(&[99]), 0, "full queue accepts nothing");
        for want in 1..=8u64 {
            assert_eq!(q.pop(), Some(want), "FIFO across batch boundaries");
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn push_many_wraps_around_the_ring() {
        let q = SpscQueue::new(4);
        // Offset the indices so batches straddle the ring boundary.
        q.push(0u64).unwrap();
        assert_eq!(q.pop(), Some(0));
        for round in 0..100u64 {
            let base = round * 3 + 1;
            assert_eq!(q.push_many(&[base, base + 1, base + 2]), 3);
            for k in 0..3 {
                assert_eq!(q.pop(), Some(base + k));
            }
        }
        assert!(q.is_empty());
    }

    #[test]
    fn push_many_cross_thread_in_order() {
        let q = Arc::new(SpscQueue::new(16));
        let n = 10_000u64;
        let prod = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut next = 0u64;
                while next < n {
                    let batch: Vec<u64> = (next..(next + 7).min(n)).collect();
                    let pushed = q.push_many(&batch);
                    next += pushed as u64;
                    if pushed == 0 {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let mut expected = 0u64;
        while expected < n {
            if let Some(v) = q.pop() {
                assert_eq!(v, expected, "FIFO violated");
                expected += 1;
            } else {
                std::thread::yield_now();
            }
        }
        prod.join().unwrap();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn property_push_many_random_batches_preserve_fifo() {
        crate::testutil::check(30, |rng| {
            let q = SpscQueue::new(16);
            let (mut next_in, mut next_out) = (0u64, 0u64);
            for _ in 0..1500 {
                if rng.chance(0.5) {
                    let len = rng.range(0, 24);
                    let batch: Vec<u64> = (next_in..next_in + len as u64).collect();
                    let pushed = q.push_many(&batch);
                    if pushed > batch.len() {
                        return Err(format!("pushed {pushed} > batch {}", batch.len()));
                    }
                    next_in += pushed as u64;
                } else if let Some(v) = q.pop() {
                    if v != next_out {
                        return Err(format!("got {v}, want {next_out}"));
                    }
                    next_out += 1;
                }
            }
            while let Some(v) = q.pop() {
                if v != next_out {
                    return Err(format!("drain got {v}, want {next_out}"));
                }
                next_out += 1;
            }
            if next_out != next_in {
                return Err(format!("lost items: in {next_in}, out {next_out}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_random_interleaving_preserves_fifo() {
        crate::testutil::check(30, |rng| {
            let q = SpscQueue::new(16);
            let (mut next_in, mut next_out) = (0u64, 0u64);
            for _ in 0..2000 {
                if rng.chance(0.55) {
                    if q.push(next_in).is_ok() {
                        next_in += 1;
                    }
                } else if let Some(v) = q.pop() {
                    if v != next_out {
                        return Err(format!("got {v}, want {next_out}"));
                    }
                    next_out += 1;
                }
            }
            while let Some(v) = q.pop() {
                if v != next_out {
                    return Err(format!("drain got {v}, want {next_out}"));
                }
                next_out += 1;
            }
            if next_out != next_in {
                return Err(format!("lost items: in {next_in}, out {next_out}"));
            }
            Ok(())
        });
    }
}
