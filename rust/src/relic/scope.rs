//! Intra-kernel fork-join on the SMT pair: `Relic::scope` + range
//! splitting.
//!
//! The paper's benchmarks pair two *whole* kernel instances on the two
//! logical threads. This layer moves the parallelism *inside* one
//! kernel: a [`Scope`] statically splits an index range into a
//! main-thread half and a handful of assistant chunks — the
//! "worksharing tasks" idea of Maroñas et al. (arXiv:2004.03258),
//! amortizing per-task overhead by collapsing a loop into O(1) chunk
//! tasks rather than one task per iteration.
//!
//! Design constraints, matching the rest of Relic:
//! * **zero allocation** — chunk descriptors live on the caller's stack
//!   and travel through the SPSC queue as raw pointers;
//! * **no nesting** — Relic has one assistant and no work stealing, so
//!   a scope inside a scope could only deadlock or serialize; nesting
//!   is rejected at runtime (and mostly prevented at compile time:
//!   chunk bodies must be `Sync`, which a captured `&Relic` is not);
//! * **never block the producer** — if the SPSC queue is full the
//!   chunk runs inline on the main thread;
//! * **help, don't idle** — after finishing its own half the main
//!   thread *claims* assistant chunks that have not started yet
//!   (claim-flag CAS) and runs them inline, so a descheduled assistant
//!   degrades to serial execution instead of a stall.
//!
//! ```
//! use relic_smt::relic::Relic;
//! use std::sync::atomic::{AtomicU64, Ordering};
//!
//! let relic = Relic::new();
//! let hits = AtomicU64::new(0);
//! relic.scope(|s| {
//!     s.split(0..1000, 64, |sub| {
//!         hits.fetch_add(sub.len() as u64, Ordering::Relaxed);
//!     });
//! });
//! assert_eq!(hits.load(Ordering::Relaxed), 1000);
//! ```

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};

use super::framework::Relic;

/// Maximum number of assistant-side chunks one `split` produces. Small
/// by design: chunks exist only so the queue-overflow fallback and the
/// main thread's help-claiming stay reasonably granular — more chunks
/// would just add submit/claim overhead on µs-scale loops.
pub const MAX_ASSIST_CHUNKS: usize = 8;

/// Total chunk-index slots a single `split_indexed` can touch: the
/// assistant chunks plus the main thread's half.
pub const MAX_CHUNK_SLOTS: usize = MAX_ASSIST_CHUNKS + 1;

/// Spin iterations between yields while waiting on chunk completion
/// (mirrors the framework's degraded-host escape hatch).
const YIELD_THRESHOLD: u32 = 10_000;

/// One stack-resident chunk of a split range.
///
/// `claimed` decides *who* runs the chunk (assistant task vs helping
/// main thread); `done` records that its body finished. Both are needed:
/// a chunk the main thread claimed still has its queue task pending, and
/// the final [`Relic::wait`] in `scope` keeps this struct alive until
/// the assistant has popped (and skipped) that task.
struct ChunkDesc<F> {
    lo: usize,
    hi: usize,
    index: usize,
    body: *const F,
    claimed: AtomicBool,
    done: AtomicBool,
    /// Set when the body panicked on the assistant thread; the main
    /// thread re-raises after the join so the panic surfaces instead of
    /// hanging the completion spin (the payload itself stays on the
    /// assistant — crossing it over would need an allocation slot).
    panicked: AtomicBool,
}

/// Assistant-side trampoline: claim the chunk, run the body, mark done.
/// A chunk the main thread already claimed (help path) is skipped — the
/// pop itself still counts toward the completion counter.
unsafe fn run_chunk<F: Fn(usize, Range<usize>) + Sync>(data: *const (), _arg: usize) {
    // SAFETY: `data` points at a ChunkDesc<F> kept alive by the
    // `split_indexed` stack frame until `Relic::wait` confirms this task
    // was consumed; `F: Sync` makes the shared `&F` call sound.
    let c = &*(data as *const ChunkDesc<F>);
    if c.claimed.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_ok() {
        // A panicking body must still complete the chunk protocol —
        // letting it unwind would kill the assistant thread with `done`
        // unset and the completion counter forever short, hanging the
        // main thread silently.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (*c.body)(c.index, c.lo..c.hi);
        }));
        if result.is_err() {
            c.panicked.store(true, Ordering::Release);
        }
        c.done.store(true, Ordering::Release);
    }
}

/// An active fork-join section on a [`Relic`] runtime.
///
/// Created by [`Relic::scope`]; not `Send`/`Sync` (it borrows the
/// non-`Sync` runtime), so only the main thread can split ranges —
/// Relic's single-producer rule extends to the fork-join layer by
/// construction.
pub struct Scope<'r> {
    relic: &'r Relic,
}

/// Drop guard: even if a chunk body panics on the main thread, every
/// task submitted to the assistant must be consumed before the chunk
/// descriptors' stack frame dies.
struct WaitGuard<'r>(&'r Relic);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Clears the scope-active flag on exit, unwinding included.
struct ScopeGuard<'r>(&'r Relic);

impl Drop for ScopeGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
        self.0.exit_scope();
    }
}

impl Relic {
    /// Open a fork-join scope: `f` receives a [`Scope`] whose
    /// [`split`](Scope::split) / [`split_indexed`](Scope::split_indexed)
    /// run range chunks on both SMT threads and return only when every
    /// chunk finished. All submitted work is drained before `scope`
    /// returns.
    ///
    /// # Panics
    /// Panics if called while another scope is active on this runtime —
    /// Relic has a single assistant and no recursive task submission
    /// (paper §VI), so nested fork-join cannot make progress in general.
    pub fn scope<R>(&self, f: impl FnOnce(&Scope<'_>) -> R) -> R {
        assert!(
            self.enter_scope(),
            "Relic::scope may not be nested: the runtime has one assistant and \
             no recursive task submission (restructure as a single flat split)"
        );
        let guard = ScopeGuard(self);
        let out = f(&Scope { relic: self });
        drop(guard);
        out
    }
}

impl<'r> Scope<'r> {
    /// Run `body` over every disjoint subrange of `range`, splitting
    /// statically: the back half runs on the calling (main) thread, the
    /// front half is cut into at most [`MAX_ASSIST_CHUNKS`] chunks of at
    /// least `grain` indices each and offered to the assistant. Returns
    /// once the whole range has been processed.
    ///
    /// Ranges shorter than `2 * grain` run entirely on the main thread —
    /// below that, submit-plus-wait overhead exceeds the work.
    pub fn split<F: Fn(Range<usize>) + Sync>(&self, range: Range<usize>, grain: usize, body: F) {
        self.split_indexed(range, grain, |_, sub| body(sub));
    }

    /// [`split`](Self::split), but `body` also receives the chunk index
    /// (`0..` assistant chunks front-to-back, then the main half) —
    /// always `< `[`MAX_CHUNK_SLOTS`]. The reduction helpers in
    /// [`crate::relic::parallel`] use the index to give each chunk a
    /// private output slot without allocation.
    pub fn split_indexed<F>(&self, range: Range<usize>, grain: usize, body: F)
    where
        F: Fn(usize, Range<usize>) + Sync,
    {
        let (lo, hi) = (range.start, range.end);
        let len = hi.saturating_sub(lo);
        if len == 0 {
            return;
        }
        let grain = grain.max(1);
        if len < 2 * grain {
            body(0, lo..hi);
            return;
        }

        // Static split: assistant gets the front half (submitted first so
        // it starts while the main thread works), main gets the back.
        let mid = lo + len / 2;
        let half = mid - lo;
        let k = (half / grain).clamp(1, MAX_ASSIST_CHUNKS);

        // Chunk descriptors on the stack — the zero-allocation invariant.
        // Slots beyond `k` are born claimed+done so they are inert.
        let chunks: [ChunkDesc<F>; MAX_ASSIST_CHUNKS] = std::array::from_fn(|i| {
            let (c_lo, c_hi) = if i < k {
                (lo + half * i / k, lo + half * (i + 1) / k)
            } else {
                (mid, mid)
            };
            ChunkDesc {
                lo: c_lo,
                hi: c_hi,
                index: i,
                body: &body as *const F,
                claimed: AtomicBool::new(i >= k),
                done: AtomicBool::new(i >= k),
                panicked: AtomicBool::new(false),
            }
        });

        // From here on, every early exit (including a panicking body)
        // must drain the queue before `chunks` goes out of scope.
        let guard = WaitGuard(self.relic);

        for c in &chunks[..k] {
            let data = c as *const ChunkDesc<F> as *const ();
            if self.relic.submit_raw(run_chunk::<F>, data).is_err() {
                // Queue full: the producer never blocks — claim and run
                // the chunk inline right away.
                if claim(c) {
                    body(c.index, c.lo..c.hi);
                    c.done.store(true, Ordering::Release);
                }
            }
        }

        // The main thread's half.
        body(k, mid..hi);

        // Help: claim chunks the assistant has not started, back to
        // front (the assistant drains the queue front to back, so the
        // two meet in the middle instead of racing for the same chunk).
        for c in chunks[..k].iter().rev() {
            if claim(c) {
                body(c.index, c.lo..c.hi);
                c.done.store(true, Ordering::Release);
            }
        }

        // Spin on the per-chunk completion flags (they flip as each
        // chunk's body returns)…
        let mut spins = 0u32;
        for c in &chunks[..k] {
            while !c.done.load(Ordering::Acquire) {
                std::hint::spin_loop();
                spins += 1;
                if spins >= YIELD_THRESHOLD {
                    std::thread::yield_now();
                    spins = 0;
                }
            }
        }
        // …then make sure the assistant consumed every submitted task
        // (a claimed-and-skipped chunk is done before its queue entry is
        // popped); the descriptors must outlive their queue entries.
        drop(guard);

        // Re-raise an assistant-side body panic on the main thread: the
        // join is complete, so this propagates like a serial loop panic
        // instead of hanging or being swallowed.
        if chunks[..k].iter().any(|c| c.panicked.load(Ordering::Acquire)) {
            panic!("Relic scope: chunk body panicked on the assistant thread");
        }
    }

    /// The runtime this scope runs on.
    pub fn relic(&self) -> &'r Relic {
        self.relic
    }
}

/// Try to claim a chunk for execution on the calling thread.
fn claim<F>(c: &ChunkDesc<F>) -> bool {
    c.claimed.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relic::RelicConfig;
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[test]
    fn split_covers_every_index_exactly_once() {
        let relic = Relic::new();
        for n in [0usize, 1, 2, 3, 7, 64, 1000] {
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            relic.scope(|s| {
                s.split(0..n, 4, |sub| {
                    for i in sub {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "index {i} of n={n}");
            }
        }
    }

    #[test]
    fn split_indexed_stays_under_slot_bound() {
        let relic = Relic::new();
        let max_seen = AtomicUsize::new(0);
        relic.scope(|s| {
            s.split_indexed(0..10_000, 1, |ci, _| {
                max_seen.fetch_max(ci, Ordering::Relaxed);
            });
        });
        assert!(max_seen.load(Ordering::Relaxed) < MAX_CHUNK_SLOTS);
    }

    #[test]
    fn tiny_ranges_run_on_main_as_one_chunk() {
        let relic = Relic::new();
        let before = relic.stats().submitted;
        let sum = AtomicU64::new(0);
        relic.scope(|s| {
            s.split(10..13, 16, |sub| {
                for i in sub {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                }
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10 + 11 + 12);
        assert_eq!(relic.stats().submitted, before, "no tasks for a sub-grain range");
    }

    #[test]
    fn queue_overflow_falls_back_inline() {
        let relic = Relic::with_config(RelicConfig {
            queue_capacity: 2,
            ..RelicConfig::default()
        });
        let sum = AtomicU64::new(0);
        // Many splits back to back; with capacity 2 some submissions
        // must overflow and run inline — nothing may be lost.
        relic.scope(|s| {
            for _ in 0..50 {
                s.split(0..64, 1, |sub| {
                    for i in sub {
                        sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 50 * (64 * 65 / 2));
    }

    #[test]
    #[should_panic(expected = "may not be nested")]
    fn nested_scope_is_rejected() {
        let relic = Relic::new();
        relic.scope(|_| {
            relic.scope(|_| {});
        });
    }

    #[test]
    fn scope_usable_again_after_nesting_panic() {
        let relic = Relic::new();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            relic.scope(|_| relic.scope(|_| ()));
        }));
        assert!(caught.is_err());
        // The inner panic unwound through the outer scope's guard; the
        // runtime must be reusable.
        let n = AtomicU64::new(0);
        relic.scope(|s| {
            s.split(0..100, 8, |sub| {
                n.fetch_add(sub.len() as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(n.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn chunk_body_panic_propagates_and_runtime_survives() {
        let relic = Relic::new();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            relic.scope(|s| {
                s.split(0..1000, 1, |sub| {
                    // The front half goes to the assistant; whichever
                    // thread claims a front chunk panics.
                    if sub.start < 500 {
                        panic!("boom");
                    }
                });
            });
        }));
        assert!(caught.is_err(), "chunk panic must not be swallowed");
        // The join still completed: the runtime remains serviceable.
        let n = AtomicU64::new(0);
        relic.scope(|s| {
            s.split(0..64, 4, |sub| {
                n.fetch_add(sub.len() as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(n.load(Ordering::Relaxed), 64);
        let stats = relic.stats();
        assert_eq!(stats.submitted, stats.completed);
    }

    #[test]
    fn scope_returns_closure_value_and_mixes_with_pair() {
        let relic = Relic::new();
        let sum = AtomicU64::new(0);
        let got = relic.scope(|s| {
            s.split(0..256, 16, |sub| {
                for i in sub {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                }
            });
            42u32
        });
        assert_eq!(got, 42);
        assert_eq!(sum.load(Ordering::Relaxed), 255 * 256 / 2);
        // The plain pair API still works on the same runtime afterwards.
        let hits = AtomicU64::new(0);
        relic.pair(
            || {
                hits.fetch_add(1, Ordering::Relaxed);
            },
            &|| {
                hits.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn repeated_scopes_reuse_the_runtime() {
        let relic = Relic::new();
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            relic.scope(|s| {
                s.split(0..128, 8, |sub| {
                    total.fetch_add(sub.len() as u64, Ordering::Relaxed);
                });
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 128);
        let stats = relic.stats();
        assert_eq!(stats.submitted, stats.completed, "scope drains all tasks");
    }
}
