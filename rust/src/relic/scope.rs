//! Intra-kernel fork-join on the SMT pair: `Relic::scope` + range
//! splitting.
//!
//! The paper's benchmarks pair two *whole* kernel instances on the two
//! logical threads. This layer moves the parallelism *inside* one
//! kernel: a [`Scope`] statically splits an index range into a
//! main-thread half and a handful of assistant chunks — the
//! "worksharing tasks" idea of Maroñas et al. (arXiv:2004.03258),
//! amortizing per-task overhead by collapsing a loop into O(1) chunk
//! tasks rather than one task per iteration.
//!
//! Two execution modes share the zero-allocation machinery:
//!
//! * **static** ([`Scope::split`] / [`Scope::split_indexed`]) — the
//!   PR 1 partition: back half on the main thread, front half cut into
//!   ≤ [`MAX_ASSIST_CHUNKS`] assistant chunks. Cheapest (one submit per
//!   chunk, one join), but on skewed inputs the thread that draws the
//!   hub vertices finishes last while its sibling idles.
//! * **self-scheduled** ([`Scope::split_dynamic`] /
//!   [`Scope::split_dynamic_by`]) — chunk *boundaries* stay a pure
//!   function of the inputs (determinism by construction survives), but
//!   chunk *assignment* is claimed from a shared atomic cursor by
//!   whichever thread is free, in waves of at most [`MAX_CHUNK_SLOTS`]
//!   chunks so per-chunk output slots stay stack-resident and
//!   reductions can combine partials in ascending chunk-index order.
//!
//! Both modes are the *leaf* of the hierarchy: [`crate::relic::cross`]
//! nests them under a shard-level splitter, so a whale request first
//! carves its range into per-shard leases and each shard then runs one
//! of these pair-level waves over its lease. The constraints below are
//! what make that nesting legal — a lease is claimed whole by one pair,
//! so no scope ever nests *inside* a scope.
//!
//! Design constraints, matching the rest of Relic:
//! * **zero allocation** — chunk descriptors live on the caller's stack
//!   and travel through the SPSC queue as raw pointers;
//! * **no nesting** — Relic has one assistant and no work stealing, so
//!   a scope inside a scope could only deadlock or serialize; nesting
//!   is rejected at runtime (and mostly prevented at compile time:
//!   chunk bodies must be `Sync`, which a captured `&Relic` is not);
//! * **never block the producer** — if the SPSC queue is full the
//!   chunk runs inline on the main thread;
//! * **help, don't idle** — after finishing its own half the main
//!   thread *claims* assistant chunks that have not started yet
//!   (claim-flag CAS) and runs them inline, so a descheduled assistant
//!   degrades to serial execution instead of a stall.
//!
//! ```
//! use relic_smt::relic::Relic;
//! use std::sync::atomic::{AtomicU64, Ordering};
//!
//! let relic = Relic::new();
//! let hits = AtomicU64::new(0);
//! relic.scope(|s| {
//!     s.split(0..1000, 64, |sub| {
//!         hits.fetch_add(sub.len() as u64, Ordering::Relaxed);
//!     });
//! });
//! assert_eq!(hits.load(Ordering::Relaxed), 1000);
//! ```

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use super::framework::Relic;

/// Maximum number of assistant-side chunks one `split` produces. Small
/// by design: chunks exist only so the queue-overflow fallback and the
/// main thread's help-claiming stay reasonably granular — more chunks
/// would just add submit/claim overhead on µs-scale loops.
pub const MAX_ASSIST_CHUNKS: usize = 8;

/// Total chunk-index slots a single `split_indexed` can touch: the
/// assistant chunks plus the main thread's half. Also the wave size of
/// the self-scheduled mode, so one slot array serves both.
pub const MAX_CHUNK_SLOTS: usize = MAX_ASSIST_CHUNKS + 1;

/// Upper bound on chunks one [`Scope::split_dynamic`] produces: four
/// waves of [`MAX_CHUNK_SLOTS`]. Enough that a hub-heavy chunk is at
/// most ~3% of the loop, few enough that the per-wave submit + join
/// overhead stays negligible next to µs-scale kernel loops.
pub const MAX_DYN_CHUNKS: usize = 4 * MAX_CHUNK_SLOTS;

/// Number of self-scheduled chunks a dynamic split of `len` indices at
/// `grain` uses: every chunk carries at least `grain` indices, capped
/// at [`MAX_DYN_CHUNKS`]. Pure in `(len, grain)` — chunk shape, and
/// therefore every reduction's combination tree, is run-to-run
/// deterministic.
pub fn dyn_chunk_count(len: usize, grain: usize) -> usize {
    (len / grain.max(1)).clamp(1, MAX_DYN_CHUNKS)
}

/// Spin iterations between yields while waiting on chunk completion
/// (mirrors the framework's degraded-host escape hatch).
const YIELD_THRESHOLD: u32 = 10_000;

/// One stack-resident chunk of a split range.
///
/// `claimed` decides *who* runs the chunk (assistant task vs helping
/// main thread); `done` records that its body finished. Both are needed:
/// a chunk the main thread claimed still has its queue task pending, and
/// the final [`Relic::wait`] in `scope` keeps this struct alive until
/// the assistant has popped (and skipped) that task.
struct ChunkDesc<F> {
    lo: usize,
    hi: usize,
    index: usize,
    body: *const F,
    claimed: AtomicBool,
    done: AtomicBool,
    /// Set when the body panicked on the assistant thread; the main
    /// thread re-raises after the join so the panic surfaces instead of
    /// hanging the completion spin (the payload itself stays on the
    /// assistant — crossing it over would need an allocation slot).
    panicked: AtomicBool,
}

/// Assistant-side trampoline: claim the chunk, run the body, mark done.
/// A chunk the main thread already claimed (help path) is skipped — the
/// pop itself still counts toward the completion counter.
unsafe fn run_chunk<F: Fn(usize, Range<usize>) + Sync>(data: *const (), _arg: usize) {
    // SAFETY: `data` points at a ChunkDesc<F> kept alive by the
    // `split_indexed` stack frame until `Relic::wait` confirms this task
    // was consumed; `F: Sync` makes the shared `&F` call sound.
    let c = &*(data as *const ChunkDesc<F>);
    if c.claimed.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_ok() {
        // A panicking body must still complete the chunk protocol —
        // letting it unwind would kill the assistant thread with `done`
        // unset and the completion counter forever short, hanging the
        // main thread silently.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (*c.body)(c.index, c.lo..c.hi);
        }));
        if result.is_err() {
            c.panicked.store(true, Ordering::Release);
        }
        c.done.store(true, Ordering::Release);
    }
}

/// An active fork-join section on a [`Relic`] runtime.
///
/// Created by [`Relic::scope`]; not `Send`/`Sync` (it borrows the
/// non-`Sync` runtime), so only the main thread can split ranges —
/// Relic's single-producer rule extends to the fork-join layer by
/// construction.
pub struct Scope<'r> {
    relic: &'r Relic,
}

/// Drop guard: even if a chunk body panics on the main thread, every
/// task submitted to the assistant must be consumed before the chunk
/// descriptors' stack frame dies.
struct WaitGuard<'r>(&'r Relic);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Clears the scope-active flag on exit, unwinding included.
struct ScopeGuard<'r>(&'r Relic);

impl Drop for ScopeGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
        self.0.exit_scope();
    }
}

impl Relic {
    /// Open a fork-join scope: `f` receives a [`Scope`] whose
    /// [`split`](Scope::split) / [`split_indexed`](Scope::split_indexed)
    /// run range chunks on both SMT threads and return only when every
    /// chunk finished. All submitted work is drained before `scope`
    /// returns.
    ///
    /// # Example
    ///
    /// Sum a range across the SMT pair; chunks are disjoint, so each
    /// accumulates into a shared atomic:
    ///
    /// ```
    /// use relic_smt::relic::Relic;
    /// use std::sync::atomic::{AtomicU64, Ordering};
    ///
    /// let relic = Relic::new();
    /// let sum = AtomicU64::new(0);
    /// relic.scope(|s| {
    ///     s.split(0..1000, 64, |chunk| {
    ///         let part: u64 = chunk.map(|i| i as u64).sum();
    ///         sum.fetch_add(part, Ordering::Relaxed);
    ///     });
    /// });
    /// // Every index processed exactly once: 0 + 1 + … + 999.
    /// assert_eq!(sum.load(Ordering::Relaxed), 499_500);
    /// ```
    ///
    /// # Panics
    /// Panics if called while another scope is active on this runtime —
    /// Relic has a single assistant and no recursive task submission
    /// (paper §VI), so nested fork-join cannot make progress in general.
    pub fn scope<R>(&self, f: impl FnOnce(&Scope<'_>) -> R) -> R {
        assert!(
            self.enter_scope(),
            "Relic::scope may not be nested: the runtime has one assistant and \
             no recursive task submission (restructure as a single flat split)"
        );
        let guard = ScopeGuard(self);
        let out = f(&Scope { relic: self });
        drop(guard);
        out
    }
}

impl<'r> Scope<'r> {
    /// Run `body` over every disjoint subrange of `range`, splitting
    /// statically: the back half runs on the calling (main) thread, the
    /// front half is cut into at most [`MAX_ASSIST_CHUNKS`] chunks of at
    /// least `grain` indices each and offered to the assistant. Returns
    /// once the whole range has been processed.
    ///
    /// Ranges shorter than `2 * grain` run entirely on the main thread —
    /// below that, submit-plus-wait overhead exceeds the work.
    pub fn split<F: Fn(Range<usize>) + Sync>(&self, range: Range<usize>, grain: usize, body: F) {
        self.split_indexed(range, grain, |_, sub| body(sub));
    }

    /// [`split`](Self::split), but `body` also receives the chunk index
    /// (`0..` assistant chunks front-to-back, then the main half) —
    /// always `< `[`MAX_CHUNK_SLOTS`]. The reduction helpers in
    /// [`crate::relic::parallel`] use the index to give each chunk a
    /// private output slot without allocation.
    pub fn split_indexed<F>(&self, range: Range<usize>, grain: usize, body: F)
    where
        F: Fn(usize, Range<usize>) + Sync,
    {
        let (lo, hi) = (range.start, range.end);
        let len = hi.saturating_sub(lo);
        if len == 0 {
            return;
        }
        let grain = grain.max(1);
        if len < 2 * grain {
            body(0, lo..hi);
            return;
        }

        // Static split: assistant gets the front half (submitted first so
        // it starts while the main thread works), main gets the back.
        let mid = lo + len / 2;
        let half = mid - lo;
        let k = (half / grain).clamp(1, MAX_ASSIST_CHUNKS);

        // Chunk descriptors on the stack — the zero-allocation invariant.
        // Slots beyond `k` are born claimed+done so they are inert.
        let chunks: [ChunkDesc<F>; MAX_ASSIST_CHUNKS] = std::array::from_fn(|i| {
            let (c_lo, c_hi) = if i < k {
                (lo + half * i / k, lo + half * (i + 1) / k)
            } else {
                (mid, mid)
            };
            ChunkDesc {
                lo: c_lo,
                hi: c_hi,
                index: i,
                body: &body as *const F,
                claimed: AtomicBool::new(i >= k),
                done: AtomicBool::new(i >= k),
                panicked: AtomicBool::new(false),
            }
        });

        // From here on, every early exit (including a panicking body)
        // must drain the queue before `chunks` goes out of scope.
        let guard = WaitGuard(self.relic);

        for c in &chunks[..k] {
            let data = c as *const ChunkDesc<F> as *const ();
            if self.relic.submit_raw(run_chunk::<F>, data).is_err() {
                // Queue full: the producer never blocks — claim and run
                // the chunk inline right away.
                self.relic.note_inline_fallback(1);
                if claim(c) {
                    body(c.index, c.lo..c.hi);
                    c.done.store(true, Ordering::Release);
                }
            }
        }

        // The main thread's half.
        body(k, mid..hi);

        // Help: claim chunks the assistant has not started, back to
        // front (the assistant drains the queue front to back, so the
        // two meet in the middle instead of racing for the same chunk).
        for c in chunks[..k].iter().rev() {
            if claim(c) {
                self.relic.note_helped();
                body(c.index, c.lo..c.hi);
                c.done.store(true, Ordering::Release);
            }
        }

        // Spin on the per-chunk completion flags (they flip as each
        // chunk's body returns)…
        let mut spins = 0u32;
        for c in &chunks[..k] {
            while !c.done.load(Ordering::Acquire) {
                std::hint::spin_loop();
                spins += 1;
                if spins >= YIELD_THRESHOLD {
                    std::thread::yield_now();
                    spins = 0;
                }
            }
        }
        // …then make sure the assistant consumed every submitted task
        // (a claimed-and-skipped chunk is done before its queue entry is
        // popped); the descriptors must outlive their queue entries.
        drop(guard);

        // Re-raise an assistant-side body panic on the main thread: the
        // join is complete, so this propagates like a serial loop panic
        // instead of hanging or being swallowed.
        if chunks[..k].iter().any(|c| c.panicked.load(Ordering::Acquire)) {
            panic!("Relic scope: chunk body panicked on the assistant thread");
        }
    }

    /// The runtime this scope runs on.
    pub fn relic(&self) -> &'r Relic {
        self.relic
    }
}

/// Try to claim a chunk for execution on the calling thread.
fn claim<F>(c: &ChunkDesc<F>) -> bool {
    c.claimed.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_ok()
}

/// One stack-resident self-scheduled wave: up to [`MAX_CHUNK_SLOTS`]
/// chunks whose assignment both threads claim from `cursor`.
///
/// Chunk boundaries are *precomputed* on the main thread into a stack
/// array (`bounds[s]..bounds[s+1]` is chunk `s`, enforced monotone), so
/// disjointness never depends on the caller's boundary closure — two
/// threads can never receive overlapping subranges, even for a
/// misbehaving bound. Kept alive by the `split_dynamic_by` stack frame
/// until the wave's queue task is consumed (same `WaitGuard` discipline
/// as the static chunk descriptors).
struct DynWave<F> {
    /// Next unclaimed wave slot; `fetch_add` is the claim.
    cursor: AtomicUsize,
    /// Chunks whose body has returned (or unwound on the assistant).
    done: AtomicUsize,
    /// Set when a body panicked on the assistant thread.
    panicked: AtomicBool,
    /// Chunks in this wave (≤ [`MAX_CHUNK_SLOTS`]).
    wave_len: usize,
    /// The wave's `wave_len + 1` monotone chunk boundaries, on the
    /// `split_dynamic_by` stack frame.
    bounds: *const usize,
    body: *const F,
}

impl<F: Fn(usize, Range<usize>) + Sync> DynWave<F> {
    /// Run the body of wave slot `slot` on the calling thread and mark
    /// it done.
    ///
    /// # Safety
    /// `bounds` and `body` must still be alive (guaranteed by the
    /// `split_dynamic_by` frame until the wave joins).
    unsafe fn run_slot(&self, slot: usize) {
        let lo = *self.bounds.add(slot);
        let hi = *self.bounds.add(slot + 1);
        (*self.body)(slot, lo..hi);
        self.done.fetch_add(1, Ordering::Release);
    }
}

/// Assistant-side trampoline for a dynamic wave: claim chunks from the
/// shared cursor until it drains. A panicking body still completes the
/// chunk protocol (flag + done count) so the main thread's join cannot
/// hang — mirroring the static `run_chunk`.
unsafe fn run_dyn_wave<F: Fn(usize, Range<usize>) + Sync>(data: *const (), _arg: usize) {
    // SAFETY: `data` points at a DynWave kept alive by the
    // `split_dynamic_by` stack frame until `Relic::wait` confirms this
    // task was consumed; `F: Sync` makes the shared body call sound.
    let wave = &*(data as *const DynWave<F>);
    loop {
        let slot = wave.cursor.fetch_add(1, Ordering::AcqRel);
        if slot >= wave.wave_len {
            break;
        }
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| wave.run_slot(slot)));
        if result.is_err() {
            wave.panicked.store(true, Ordering::Release);
            wave.done.fetch_add(1, Ordering::Release);
        }
    }
}

impl<'r> Scope<'r> {
    /// Self-scheduled variant of [`split`](Self::split): chunk
    /// boundaries are still fixed by `(range, grain)` (see
    /// [`dyn_chunk_count`]), but chunk *assignment* is claimed from a
    /// shared atomic cursor by whichever thread is free — the thread
    /// that draws a hub chunk no longer strands its sibling. Returns
    /// once the whole range has been processed.
    pub fn split_dynamic<F: Fn(Range<usize>) + Sync>(
        &self,
        range: Range<usize>,
        grain: usize,
        body: F,
    ) {
        self.split_dynamic_indexed(range, grain, |_, sub| body(sub), |_| {});
    }

    /// [`split_dynamic`](Self::split_dynamic), but `body` also receives
    /// its wave-slot index (`< `[`MAX_CHUNK_SLOTS`], exclusive to the
    /// chunk within its wave) and `wave_done(n)` runs on the main
    /// thread after each wave of `n` chunks joins — before any slot is
    /// reused — so reductions can drain per-chunk slots in ascending
    /// chunk-index order.
    pub fn split_dynamic_indexed<F, W>(
        &self,
        range: Range<usize>,
        grain: usize,
        body: F,
        wave_done: W,
    ) where
        F: Fn(usize, Range<usize>) + Sync,
        W: FnMut(usize),
    {
        let lo = range.start;
        let len = range.end.saturating_sub(lo);
        if len == 0 {
            return;
        }
        let k = dyn_chunk_count(len, grain);
        self.split_dynamic_by(
            range,
            k,
            move |i, k| lo + ((len as u128 * i as u128) / k as u128) as usize,
            body,
            wave_done,
        );
    }

    /// The self-scheduled core with caller-provided chunk boundaries:
    /// chunk `i` of `n_chunks` covers `bound(i, n) .. bound(i+1, n)`
    /// (`bound(0, n)` and `bound(n, n)` are ignored — the first and
    /// last chunk are pinned to the range ends). The edge-balanced
    /// kernel schedules pass a CSR-offset bisection here so every chunk
    /// carries ~equal *edge* work.
    ///
    /// `bound` is evaluated only on the main thread, and its outputs
    /// are forced monotone (running max, clamped into the range) before
    /// any chunk runs — chunks are disjoint by construction, so a buggy
    /// boundary function can skew the balance but can never hand two
    /// threads overlapping subranges.
    ///
    /// Waves of at most [`MAX_CHUNK_SLOTS`] chunks run back to back;
    /// `wave_done` fires on the main thread after each wave joins. All
    /// bookkeeping lives on this stack frame — the zero-allocation
    /// invariant holds in this mode too.
    pub fn split_dynamic_by<B, F, W>(
        &self,
        range: Range<usize>,
        n_chunks: usize,
        bound: B,
        body: F,
        mut wave_done: W,
    ) where
        B: Fn(usize, usize) -> usize,
        F: Fn(usize, Range<usize>) + Sync,
        W: FnMut(usize),
    {
        let (lo, hi) = (range.start, range.end);
        if hi <= lo {
            return;
        }
        let k = n_chunks.max(1);
        if k == 1 {
            body(0, lo..hi);
            wave_done(1);
            return;
        }

        let mut wave_base = 0usize;
        // Start of the next chunk, carried across waves so coverage is
        // contiguous (and disjoint) whatever `bound` returns.
        let mut next_lo = lo;
        while wave_base < k {
            let wave_len = (k - wave_base).min(MAX_CHUNK_SLOTS);
            // Precompute the wave's boundaries, forced monotone.
            let mut bounds = [hi; MAX_CHUNK_SLOTS + 1];
            bounds[0] = next_lo;
            for s in 1..=wave_len {
                let i = wave_base + s;
                bounds[s] = if i >= k { hi } else { bound(i, k).clamp(bounds[s - 1], hi) };
            }
            next_lo = bounds[wave_len];
            let wave = DynWave {
                cursor: AtomicUsize::new(0),
                done: AtomicUsize::new(0),
                panicked: AtomicBool::new(false),
                wave_len,
                bounds: bounds.as_ptr(),
                body: &body as *const F,
            };
            // Every exit below (including a panicking main-thread body)
            // must drain the queue before `wave` goes out of scope.
            let guard = WaitGuard(self.relic);
            let data = &wave as *const DynWave<F> as *const ();
            let offered = self.relic.submit_raw(run_dyn_wave::<F>, data).is_ok();
            if !offered {
                // Queue full: the whole wave self-schedules onto the
                // main thread alone — never block the producer.
                self.relic.note_inline_fallback(wave_len as u64);
            }
            // Claim chunks alongside the assistant until the cursor
            // drains; the claim *is* the load balancing.
            loop {
                let slot = wave.cursor.fetch_add(1, Ordering::AcqRel);
                if slot >= wave_len {
                    break;
                }
                // SAFETY: `bounds`/`body` outlive this frame's loop.
                unsafe { wave.run_slot(slot) };
                if offered {
                    self.relic.note_helped();
                }
            }
            // Join: the assistant may still be inside its last claim.
            let mut spins = 0u32;
            while wave.done.load(Ordering::Acquire) < wave_len {
                std::hint::spin_loop();
                spins += 1;
                if spins >= YIELD_THRESHOLD {
                    std::thread::yield_now();
                    spins = 0;
                }
            }
            // The wave's queue task must be consumed before `wave` dies.
            drop(guard);
            if wave.panicked.load(Ordering::Acquire) {
                panic!("Relic scope: chunk body panicked on the assistant thread");
            }
            wave_done(wave_len);
            wave_base += wave_len;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relic::RelicConfig;
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    #[test]
    fn split_covers_every_index_exactly_once() {
        let relic = Relic::new();
        for n in [0usize, 1, 2, 3, 7, 64, 1000] {
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            relic.scope(|s| {
                s.split(0..n, 4, |sub| {
                    for i in sub {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "index {i} of n={n}");
            }
        }
    }

    #[test]
    fn split_indexed_stays_under_slot_bound() {
        let relic = Relic::new();
        let max_seen = AtomicUsize::new(0);
        relic.scope(|s| {
            s.split_indexed(0..10_000, 1, |ci, _| {
                max_seen.fetch_max(ci, Ordering::Relaxed);
            });
        });
        assert!(max_seen.load(Ordering::Relaxed) < MAX_CHUNK_SLOTS);
    }

    #[test]
    fn tiny_ranges_run_on_main_as_one_chunk() {
        let relic = Relic::new();
        let before = relic.stats().submitted;
        let sum = AtomicU64::new(0);
        relic.scope(|s| {
            s.split(10..13, 16, |sub| {
                for i in sub {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                }
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10 + 11 + 12);
        assert_eq!(relic.stats().submitted, before, "no tasks for a sub-grain range");
    }

    #[test]
    fn queue_overflow_falls_back_inline() {
        let relic = Relic::with_config(RelicConfig {
            queue_capacity: 2,
            ..RelicConfig::default()
        });
        let sum = AtomicU64::new(0);
        // Many splits back to back; with capacity 2 some submissions
        // must overflow and run inline — nothing may be lost.
        relic.scope(|s| {
            for _ in 0..50 {
                s.split(0..64, 1, |sub| {
                    for i in sub {
                        sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 50 * (64 * 65 / 2));
    }

    #[test]
    #[should_panic(expected = "may not be nested")]
    fn nested_scope_is_rejected() {
        let relic = Relic::new();
        relic.scope(|_| {
            relic.scope(|_| {});
        });
    }

    #[test]
    fn scope_usable_again_after_nesting_panic() {
        let relic = Relic::new();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            relic.scope(|_| relic.scope(|_| ()));
        }));
        assert!(caught.is_err());
        // The inner panic unwound through the outer scope's guard; the
        // runtime must be reusable.
        let n = AtomicU64::new(0);
        relic.scope(|s| {
            s.split(0..100, 8, |sub| {
                n.fetch_add(sub.len() as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(n.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn chunk_body_panic_propagates_and_runtime_survives() {
        let relic = Relic::new();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            relic.scope(|s| {
                s.split(0..1000, 1, |sub| {
                    // The front half goes to the assistant; whichever
                    // thread claims a front chunk panics.
                    if sub.start < 500 {
                        panic!("boom");
                    }
                });
            });
        }));
        assert!(caught.is_err(), "chunk panic must not be swallowed");
        // The join still completed: the runtime remains serviceable.
        let n = AtomicU64::new(0);
        relic.scope(|s| {
            s.split(0..64, 4, |sub| {
                n.fetch_add(sub.len() as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(n.load(Ordering::Relaxed), 64);
        let stats = relic.stats();
        assert_eq!(stats.submitted, stats.completed);
    }

    #[test]
    fn scope_returns_closure_value_and_mixes_with_pair() {
        let relic = Relic::new();
        let sum = AtomicU64::new(0);
        let got = relic.scope(|s| {
            s.split(0..256, 16, |sub| {
                for i in sub {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                }
            });
            42u32
        });
        assert_eq!(got, 42);
        assert_eq!(sum.load(Ordering::Relaxed), 255 * 256 / 2);
        // The plain pair API still works on the same runtime afterwards.
        let hits = AtomicU64::new(0);
        relic.pair(
            || {
                hits.fetch_add(1, Ordering::Relaxed);
            },
            &|| {
                hits.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn dyn_chunk_count_bounds() {
        assert_eq!(dyn_chunk_count(0, 16), 1);
        assert_eq!(dyn_chunk_count(15, 16), 1);
        assert_eq!(dyn_chunk_count(32, 16), 2);
        assert_eq!(dyn_chunk_count(100, 16), 6, "chunks never dip below the grain");
        assert_eq!(dyn_chunk_count(1_000_000, 1), MAX_DYN_CHUNKS);
        assert_eq!(dyn_chunk_count(64, 0), MAX_DYN_CHUNKS.min(64), "grain 0 behaves as 1");
    }

    #[test]
    fn split_dynamic_covers_every_index_exactly_once() {
        let relic = Relic::new();
        // Sizes straddling the wave boundaries: single chunk, one wave,
        // several waves, and the MAX_DYN_CHUNKS cap.
        for n in [0usize, 1, 2, 7, 9, 64, 100, 1000, 10_000] {
            for grain in [1usize, 4, 64] {
                let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                relic.scope(|s| {
                    s.split_dynamic(0..n, grain, |sub| {
                        for i in sub {
                            counts[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                });
                for (i, c) in counts.iter().enumerate() {
                    assert_eq!(c.load(Ordering::Relaxed), 1, "index {i} of n={n} grain={grain}");
                }
            }
        }
        let stats = relic.stats();
        assert_eq!(stats.submitted, stats.completed, "every wave task consumed");
    }

    #[test]
    fn split_dynamic_indexed_slots_stay_wave_local_and_waves_ascend() {
        let relic = Relic::new();
        let max_slot = AtomicUsize::new(0);
        let mut wave_sizes = Vec::new();
        relic.scope(|s| {
            s.split_dynamic_indexed(
                0..10_000,
                1,
                |slot, _| {
                    max_slot.fetch_max(slot, Ordering::Relaxed);
                },
                |n| wave_sizes.push(n),
            );
        });
        assert!(max_slot.load(Ordering::Relaxed) < MAX_CHUNK_SLOTS);
        // 10_000 indices at grain 1 cap at MAX_DYN_CHUNKS chunks: four
        // full waves, joined in order.
        assert_eq!(wave_sizes.iter().sum::<usize>(), MAX_DYN_CHUNKS);
        assert!(wave_sizes.iter().all(|&n| n <= MAX_CHUNK_SLOTS));
    }

    #[test]
    fn split_dynamic_by_respects_custom_boundaries() {
        let relic = Relic::new();
        let n = 1000usize;
        // Quadratically skewed boundaries: early chunks narrow, late
        // chunks wide — still a disjoint cover of the range.
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        relic.scope(|s| {
            s.split_dynamic_by(
                0..n,
                12,
                |i, k| n * i * i / (k * k),
                |_, sub| {
                    for i in sub {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
                |_| {},
            );
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn split_dynamic_by_tames_non_monotone_bounds() {
        // A buggy (non-monotone) boundary function may skew the balance
        // but must never produce overlapping chunks — overlap would
        // hand two threads the same `map_into` elements (a data race
        // reachable from safe code). Coverage must stay exactly-once.
        let relic = Relic::new();
        let n = 500usize;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        relic.scope(|s| {
            s.split_dynamic_by(
                0..n,
                12,
                |i, k| if i % 2 == 0 { n * i / k } else { n - n * i / k },
                |_, sub| {
                    for i in sub {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
                |_| {},
            );
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn split_dynamic_queue_overflow_falls_back_inline() {
        let relic = Relic::with_config(RelicConfig {
            queue_capacity: 2,
            ..RelicConfig::default()
        });
        let sum = AtomicU64::new(0);
        relic.scope(|s| {
            for _ in 0..50 {
                s.split_dynamic(0..64, 1, |sub| {
                    for i in sub {
                        sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 50 * (64 * 65 / 2));
    }

    #[test]
    fn split_dynamic_body_panic_propagates_and_runtime_survives() {
        let relic = Relic::new();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            relic.scope(|s| {
                s.split_dynamic(0..1000, 1, |sub| {
                    if sub.start >= 500 {
                        panic!("boom");
                    }
                });
            });
        }));
        assert!(caught.is_err(), "dynamic chunk panic must not be swallowed");
        let n = AtomicU64::new(0);
        relic.scope(|s| {
            s.split_dynamic(0..64, 4, |sub| {
                n.fetch_add(sub.len() as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(n.load(Ordering::Relaxed), 64);
        let stats = relic.stats();
        assert_eq!(stats.submitted, stats.completed);
    }

    #[test]
    fn helped_chunks_counted_when_main_claims() {
        // Park the assistant behind a task that spins on a gate: the
        // main thread must claim at least the first chunk itself.
        static GATE: AtomicBool = AtomicBool::new(false);
        fn gated(_: usize) {
            while !GATE.load(Ordering::Acquire) {
                std::hint::spin_loop();
            }
        }
        let relic = Relic::new();
        relic.submit(gated, 0).unwrap();
        let sum = AtomicU64::new(0);
        relic.scope(|s| {
            s.split_dynamic(0..1000, 10, |sub| {
                GATE.store(true, Ordering::Release);
                sum.fetch_add(sub.len() as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1000);
        assert!(relic.stats().helped_chunks >= 1, "main-thread claims must be counted");
    }

    #[test]
    fn inline_fallback_counted_when_queue_is_full() {
        static GATE: AtomicBool = AtomicBool::new(false);
        fn gated(_: usize) {
            while !GATE.load(Ordering::Acquire) {
                std::hint::spin_loop();
            }
        }
        let relic = Relic::with_config(RelicConfig {
            queue_capacity: 2,
            ..RelicConfig::default()
        });
        // One gated task occupies the assistant; two more fill the
        // 2-slot queue, so the first wave's submit must fail.
        for _ in 0..3 {
            while relic.submit(gated, 0).is_err() {
                std::thread::yield_now();
            }
        }
        let sum = AtomicU64::new(0);
        relic.scope(|s| {
            s.split_dynamic(0..360, 10, |sub| {
                GATE.store(true, Ordering::Release);
                sum.fetch_add(sub.len() as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 360);
        assert!(relic.stats().inline_fallback >= 1, "queue-full waves must be counted");
    }

    #[test]
    fn repeated_scopes_reuse_the_runtime() {
        let relic = Relic::new();
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            relic.scope(|s| {
                s.split(0..128, 8, |sub| {
                    total.fetch_add(sub.len() as u64, Ordering::Relaxed);
                });
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 128);
        let stats = relic.stats();
        assert_eq!(stats.submitted, stats.completed, "scope drains all tasks");
    }
}
