//! CPU affinity helpers.
//!
//! The paper (§VI-B) deliberately leaves CPU pinning to the application:
//! "We do not implement the CPU pinning algorithms in Relic and expect
//! users of the framework to set the CPU affinities for both the main
//! and assistant threads." These helpers are the utilities an
//! application would use: pin the calling thread, and discover an SMT
//! sibling pair from sysfs topology.

use std::fs;

/// Pin the calling thread to one logical CPU. Returns `false` (without
/// panicking) when the host refuses — e.g. single-CPU containers.
pub fn pin_to_cpu(cpu: usize) -> bool {
    // SAFETY: plain libc affinity call on the calling thread with a
    // properly zeroed cpu_set_t.
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(cpu, &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) == 0
    }
}

/// Number of online logical CPUs.
pub fn num_cpus() -> usize {
    // SAFETY: sysconf is always safe to call.
    let n = unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) };
    if n < 1 {
        1
    } else {
        n as usize
    }
}

/// Parse a sysfs cpulist like `"0,6"` / `"0-1"` / `"2"` into CPU ids.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse(), hi.trim().parse()) {
                let (lo, hi): (usize, usize) = (lo, hi);
                out.extend(lo..=hi);
            }
        } else if let Ok(v) = part.trim().parse() {
            out.push(v);
        }
    }
    out
}

/// Read every online CPU's `thread_siblings_list` from sysfs — the one
/// raw topology scan shared by [`smt_sibling_pair`] (first pair) and
/// `relic::pool` (all physical-core pairs). Empty on hosts without the
/// sysfs topology tree.
pub fn sibling_lists() -> Vec<String> {
    let mut out = Vec::new();
    for cpu in 0..num_cpus() {
        let path =
            format!("/sys/devices/system/cpu/cpu{cpu}/topology/thread_siblings_list");
        if let Ok(text) = fs::read_to_string(&path) {
            out.push(text);
        }
    }
    out
}

/// Find a pair of logical CPUs that are SMT siblings of one physical
/// core, from sysfs. `None` when the host has no SMT (the common case in
/// CI containers — callers fall back to unpinned threads or the
/// simulator; see DESIGN.md §2).
pub fn smt_sibling_pair() -> Option<(usize, usize)> {
    sibling_lists()
        .iter()
        .map(|text| parse_cpulist(text))
        .find(|cpus| cpus.len() >= 2)
        .map(|cpus| (cpus[0], cpus[1]))
}

/// Describe the host topology for logs/reports.
pub fn topology_summary() -> String {
    match smt_sibling_pair() {
        Some((a, b)) => format!(
            "{} logical CPUs; SMT sibling pair ({a}, {b}) available",
            num_cpus()
        ),
        None => format!("{} logical CPUs; no SMT siblings detected", num_cpus()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_cpulist_forms() {
        assert_eq!(parse_cpulist("0,6"), vec![0, 6]);
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist("0-1,4-5"), vec![0, 1, 4, 5]);
        assert_eq!(parse_cpulist(" 2 , 3 "), vec![2, 3]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
    }

    #[test]
    fn num_cpus_positive() {
        assert!(num_cpus() >= 1);
    }

    #[test]
    fn pin_to_cpu0_usually_works() {
        // CPU 0 always exists; pinning may be denied in exotic sandboxes,
        // so only assert the call doesn't crash.
        let _ = pin_to_cpu(0);
    }

    #[test]
    fn topology_summary_mentions_cpus() {
        assert!(topology_summary().contains("logical CPUs"));
    }
}
