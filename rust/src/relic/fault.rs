//! **Deterministic fault injection** — the chaos hooks behind the
//! engine's fault-isolation layer.
//!
//! A [`FaultPlan`] is a small, config-driven script of failures to
//! inject into an otherwise healthy pool: *panic on the nth request of
//! a kernel* (exercises panic containment in the coordinator), *stall
//! the nth batch of a shard* (exercises the watchdog's `Stuck`
//! classification and queue redirect), *drop the nth response of a
//! shard* (exercises the engine's lost-response sweeper), and *kill a
//! shard's thread on its nth batch* (exercises supervised respawn).
//!
//! The plan is compiled in but **default-off and zero-cost when
//! disabled**: every hook lives behind an `Option<Arc<FaultPlan>>`
//! that is `None` in production paths, so the disabled cost is one
//! branch per batch. Each injection point is a one-shot `nth` counter
//! (fire exactly when the counter reaches its target), which keeps
//! chaos tests and the `repro faults` sweep deterministic: the same
//! plan against the same request stream trips at the same points.
//!
//! Nothing in this module executes faults by itself — the pool's shard
//! loop, the engine's batch handler, and the coordinator's kernel
//! paths each consult the plan at their own seam (see
//! `ARCHITECTURE.md` §Failure domains & recovery for the map).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Why a request failed instead of completing — the typed cause
/// carried by `RequestResult::Failed`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The kernel panicked; the panic was caught and contained.
    Panic,
    /// The shard thread died while the request was in flight.
    ShardDead,
    /// The request was executed but its response never arrived
    /// (detected by the engine's idle sweeper).
    ResponseLost,
}

impl FaultKind {
    /// Stable lower-case name for reports and JSON artifacts.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::ShardDead => "shard-dead",
            FaultKind::ResponseLost => "response-lost",
        }
    }
}

/// One-shot occurrence counter: `fire` returns `true` exactly once,
/// when the `target`-th observation arrives (1-based).
#[derive(Debug)]
struct Nth {
    target: u64,
    seen: AtomicU64,
}

impl Nth {
    fn new(target: u64) -> Self {
        Nth { target: target.max(1), seen: AtomicU64::new(0) }
    }

    fn fire(&self) -> bool {
        self.seen.fetch_add(1, Ordering::AcqRel) + 1 == self.target
    }
}

/// A shard-scoped one-shot trigger.
#[derive(Debug)]
struct ShardNth {
    shard: usize,
    nth: Nth,
}

impl ShardNth {
    fn fire(&self, shard: usize) -> bool {
        shard == self.shard && self.nth.fire()
    }
}

/// A deterministic script of failures to inject. Build with the
/// `with_*` constructors; all injections default to off.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Panic inside kernel execution on the nth request of a kernel
    /// (matched by `GraphKernel::artifact_name`).
    panic_on: Option<(String, Nth)>,
    /// Sleep the shard thread for a duration before its nth batch.
    stall: Option<(ShardNth, Duration)>,
    /// Suppress the shard's nth response send.
    drop_response: Option<ShardNth>,
    /// Exit the shard thread before its nth batch (the batch is
    /// requeued, so no item is lost — only the thread).
    kill: Option<ShardNth>,
}

impl FaultPlan {
    /// An empty plan (no faults). Prefer `Option::None` over an empty
    /// plan on hot paths.
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Panic on the `nth` (1-based) request of `kernel` (artifact
    /// name, e.g. `"bfs"`).
    pub fn with_panic_on(mut self, kernel: &str, nth: u64) -> Self {
        self.panic_on = Some((kernel.to_string(), Nth::new(nth)));
        self
    }

    /// Stall shard `shard` for `duration` before its `nth` batch.
    pub fn with_stall(mut self, shard: usize, nth: u64, duration: Duration) -> Self {
        self.stall = Some((ShardNth { shard, nth: Nth::new(nth) }, duration));
        self
    }

    /// Drop the `nth` response sent by shard `shard`.
    pub fn with_drop_response(mut self, shard: usize, nth: u64) -> Self {
        self.drop_response = Some(ShardNth { shard, nth: Nth::new(nth) });
        self
    }

    /// Kill shard `shard`'s thread before its `nth` batch.
    pub fn with_kill(mut self, shard: usize, nth: u64) -> Self {
        self.kill = Some(ShardNth { shard, nth: Nth::new(nth) });
        self
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.panic_on.is_none()
            && self.stall.is_none()
            && self.drop_response.is_none()
            && self.kill.is_none()
    }

    /// Coordinator hook: should this request of `kernel` panic?
    pub fn should_panic(&self, kernel: &str) -> bool {
        match &self.panic_on {
            Some((name, nth)) if name == kernel => nth.fire(),
            _ => false,
        }
    }

    /// Shard-loop hook: how long (if at all) should this batch stall?
    pub fn stall_duration(&self, shard: usize) -> Option<Duration> {
        match &self.stall {
            Some((target, dur)) if target.fire(shard) => Some(*dur),
            _ => None,
        }
    }

    /// Engine-handler hook: should this response be suppressed?
    pub fn should_drop_response(&self, shard: usize) -> bool {
        matches!(&self.drop_response, Some(target) if target.fire(shard))
    }

    /// Shard-loop hook: should the thread exit before this batch?
    pub fn should_kill(&self, shard: usize) -> bool {
        matches!(&self.kill, Some(target) if target.fire(shard))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_injects_nothing() {
        let plan = FaultPlan::new();
        assert!(plan.is_empty());
        for _ in 0..8 {
            assert!(!plan.should_panic("bfs"));
            assert!(plan.stall_duration(0).is_none());
            assert!(!plan.should_drop_response(0));
            assert!(!plan.should_kill(0));
        }
    }

    #[test]
    fn panic_fires_exactly_once_on_the_nth_matching_request() {
        let plan = FaultPlan::new().with_panic_on("bfs", 3);
        assert!(!plan.is_empty());
        // Non-matching kernels never consume the counter.
        assert!(!plan.should_panic("pagerank"));
        assert!(!plan.should_panic("bfs")); // 1st
        assert!(!plan.should_panic("bfs")); // 2nd
        assert!(plan.should_panic("bfs")); // 3rd: fire
        assert!(!plan.should_panic("bfs")); // one-shot
    }

    #[test]
    fn shard_faults_fire_once_on_their_shard_only() {
        let plan = FaultPlan::new()
            .with_stall(1, 2, Duration::from_millis(5))
            .with_drop_response(0, 1)
            .with_kill(2, 1);
        assert!(plan.stall_duration(0).is_none()); // wrong shard
        assert!(plan.stall_duration(1).is_none()); // 1st batch
        assert_eq!(plan.stall_duration(1), Some(Duration::from_millis(5)));
        assert!(plan.stall_duration(1).is_none()); // one-shot
        assert!(plan.should_drop_response(0));
        assert!(!plan.should_drop_response(0));
        assert!(!plan.should_kill(0));
        assert!(plan.should_kill(2));
        assert!(!plan.should_kill(2));
    }

    #[test]
    fn nth_zero_clamps_to_first() {
        let plan = FaultPlan::new().with_panic_on("tc", 0);
        assert!(plan.should_panic("tc"));
        assert!(!plan.should_panic("tc"));
    }
}
