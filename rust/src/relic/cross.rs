//! Cross-shard cooperative parallelism: the shard-level half of the
//! hierarchical two-level fork-join.
//!
//! One *whale* request (a full PageRank/BC run over a large graph) used
//! to be capped at one SMT pair's worth of parallelism — its shard's
//! two hardware threads — while sibling shards sat idle. This module
//! lets the request's owning shard **borrow** idle pair-shards for the
//! duration of the request:
//!
//! * the owner opens a [`with_lease`] session, which asks the
//!   [`LeaseBroker`] to reserve up to `max_borrow` *eligible* shards
//!   (queue depth ≤ `offer_depth`, not quarantined, not itself);
//! * each parallel loop inside the kernel becomes a `CrossJob`: the
//!   index range is carved at deterministic boundaries (even splits, or
//!   the edge-balanced boundaries the `_by` entry points provide) into
//!   at most [`MAX_CROSS_CHUNKS`] chunks behind a shared atomic cursor;
//! * the owner *and* every attached borrower run the existing
//!   pair-level wave protocol ([`Relic::pair`]) over the cursor, so the
//!   request fans out to `2 × (1 + borrowed)` hardware threads;
//! * a borrower re-checks a revocation predicate before every chunk
//!   claim: the moment its own queue has work (or it is quarantined, or
//!   the pool is shutting down) it finishes the chunk in hand and
//!   returns to its queue — revocation is chunk-granular;
//! * chunks execute **exactly once** (the cursor hands each index out
//!   once; a claimed chunk always runs to completion, panic or not),
//!   and chunk boundaries are a pure function of `(range, schedule)` —
//!   independent of which shards participate — so results are bitwise
//!   identical to the serial and single-pair paths no matter how the
//!   race for chunks resolves.
//!
//! With `max_borrow = 0` the session never reserves anything and the
//! caller gets a plain pair-scheduled [`Par`] back: the degenerate mode
//! is structurally the single-pair engine, bit for bit.
//!
//! Safety model: a session's `LeaseChannel` and each loop's
//! `CrossJob` live on the owner's stack. The owner never pops those
//! frames while a borrower can still reach them — jobs are retired with
//! a null-swap + busy-count drain (seqlock-style hazard check), and the
//! session close waits for every reserved slot to return to `EMPTY`
//! before `with_lease` returns.

use std::cell::UnsafeCell;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use super::framework::Relic;
use super::parallel::{Par, Schedule};

/// Upper bound on shard-level chunks per parallel loop. Large enough to
/// keep `2 × shards` hardware threads busy with headroom for dynamic
/// load balancing, small enough that the per-chunk atomics stay noise.
pub const MAX_CROSS_CHUNKS: usize = 64;

/// Shard-level chunk count for a loop of `len` indices at pair-level
/// grain `grain`: one chunk per grain's worth of work, clamped to
/// `[1, MAX_CROSS_CHUNKS]`. Pure — the same `(len, grain)` always
/// yields the same count, which is what keeps chunk *boundaries*
/// deterministic regardless of how many shards end up participating.
pub fn cross_chunk_count(len: usize, grain: usize) -> usize {
    (len / grain.max(1)).clamp(1, MAX_CROSS_CHUNKS)
}

/// Write the `k + 1` even chunk boundaries of `range` into `bounds`
/// (index `i`'s chunk is `bounds[i]..bounds[i + 1]`). Remainder indices
/// go to the leading chunks, matching the pair-level splitter.
pub(crate) fn even_bounds(range: &Range<usize>, k: usize, bounds: &mut [usize]) {
    let len = range.end - range.start;
    let base = len / k;
    let extra = len % k;
    let mut at = range.start;
    for (i, b) in bounds.iter_mut().enumerate().take(k) {
        *b = at;
        at += base + usize::from(i < extra);
    }
    bounds[k] = range.end;
}

/// Write `k + 1` weighted boundaries from a caller-supplied `bound`
/// closure (the edge-balanced CSR boundaries), forced monotone and
/// clamped into `range` exactly like the pair-level `split_dynamic_by`.
pub(crate) fn bounds_by(
    range: &Range<usize>,
    k: usize,
    bound: &dyn Fn(usize, usize) -> usize,
    bounds: &mut [usize],
) {
    bounds[0] = range.start;
    for i in 1..k {
        bounds[i] = bound(i, k).clamp(bounds[i - 1], range.end);
    }
    bounds[k] = range.end;
}

/// One shard-level fork-join loop: deterministic chunk boundaries, a
/// shared claim cursor, and a type-erased chunk body. Lives on the
/// owner's stack for the duration of the loop.
pub(crate) struct CrossJob {
    /// `n_chunks + 1` monotone boundaries.
    bounds: *const usize,
    n_chunks: usize,
    /// Type-erased `&F where F: Fn(usize, Range<usize>) + Sync`.
    body: *const (),
    run: unsafe fn(*const (), usize, usize, usize),
    /// Next unclaimed chunk index; claims are `fetch_add(1)`.
    cursor: AtomicUsize,
    /// Chunks fully executed (panicked ones included — a claimed chunk
    /// is always *accounted*, so the owner's join cannot hang).
    completed: AtomicUsize,
    /// Some chunk body panicked; the owner re-raises after the join.
    panicked: AtomicBool,
}

// SAFETY: the raw pointers reference the owner's stack frame, which
// outlives every access — the owner joins (completed == n_chunks, busy
// drained) before popping the frame. The body is `Fn + Sync`.
unsafe impl Sync for CrossJob {}

/// Monomorphic trampoline: recover `F` and run one chunk.
///
/// # Safety
/// `body` must point to a live `F` and `lo..hi` must be a chunk the
/// cursor handed out exactly once.
unsafe fn run_chunk_body<F: Fn(usize, Range<usize>) + Sync>(
    body: *const (),
    ci: usize,
    lo: usize,
    hi: usize,
) {
    (*(body as *const F))(ci, lo..hi);
}

impl CrossJob {
    fn new<F: Fn(usize, Range<usize>) + Sync>(bounds: &[usize], body: &F) -> CrossJob {
        debug_assert!(bounds.len() >= 2);
        CrossJob {
            bounds: bounds.as_ptr(),
            n_chunks: bounds.len() - 1,
            body: body as *const F as *const (),
            run: run_chunk_body::<F>,
            cursor: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        }
    }
}

/// Claim chunks from `job` until the cursor is exhausted (or `stop`
/// asks for revocation — checked *before* each claim, never after, so a
/// claimed chunk always completes). Returns the number of chunks run.
fn run_chunks(job: &CrossJob, stop: Option<&(dyn Fn() -> bool + Sync)>) -> usize {
    let mut served = 0;
    loop {
        if stop.is_some_and(|s| s()) {
            break;
        }
        let ci = job.cursor.fetch_add(1, Ordering::AcqRel);
        if ci >= job.n_chunks {
            break;
        }
        // SAFETY: ci < n_chunks, bounds has n_chunks + 1 entries, and
        // the job (bounds, body) is alive until the owner's join.
        let (lo, hi) = unsafe { (*job.bounds.add(ci), *job.bounds.add(ci + 1)) };
        let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (job.run)(job.body, ci, lo, hi) }));
        if ok.is_err() {
            job.panicked.store(true, Ordering::Release);
        }
        // Account the chunk even on panic: exactly-once accounting is
        // what lets the owner's join terminate under contained faults.
        job.completed.fetch_add(1, Ordering::AcqRel);
        served += 1;
    }
    served
}

/// Spin (then yield) until every chunk of `job` is accounted.
fn wait_all(job: &CrossJob) {
    let mut spins = 0u32;
    while job.completed.load(Ordering::Acquire) < job.n_chunks {
        spins += 1;
        if spins >= 10_000 {
            std::thread::yield_now();
        } else {
            std::hint::spin_loop();
        }
    }
}

/// The request-scoped mailbox between one lease owner and its attached
/// borrowers: the currently published job (null between loops), a
/// hazard counter guarding job dereferences, and the session-closed
/// flag. Lives on the owner's stack for the whole request.
pub(crate) struct LeaseChannel {
    job: AtomicPtr<CrossJob>,
    /// Borrowers currently holding a reference to the published job.
    busy: AtomicUsize,
    closed: AtomicBool,
}

impl LeaseChannel {
    fn new() -> LeaseChannel {
        LeaseChannel {
            job: AtomicPtr::new(std::ptr::null_mut()),
            busy: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        }
    }

    fn publish(&self, job: &CrossJob) {
        self.job.store(job as *const CrossJob as *mut CrossJob, Ordering::SeqCst);
    }

    /// Unpublish the current job and wait out every borrower that may
    /// still hold a reference to it — after this returns the job's
    /// stack frame is unreachable and safe to pop.
    fn retire(&self) {
        self.job.store(std::ptr::null_mut(), Ordering::SeqCst);
        let mut spins = 0u32;
        while self.busy.load(Ordering::SeqCst) != 0 {
            spins += 1;
            if spins >= 10_000 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }
}

// SAFETY: all fields are atomics; the raw job pointer is only
// dereferenced under the busy-count hazard protocol.
unsafe impl Sync for LeaseChannel {}

/// A live cross-shard session handle, carried inside
/// [`Par::Cross`](super::parallel::Par) so the parallel-for helpers can
/// fan loops out to the borrowed shards. Constructed only by
/// [`with_lease`]; the pair-level path is the automatic fallback
/// whenever no shard could be borrowed.
pub struct CrossSession<'a> {
    channel: &'a LeaseChannel,
}

impl CrossSession<'_> {
    /// Run one shard-level fork-join loop: publish the job, join the
    /// claim race with this shard's own pair, wait for every chunk,
    /// retire the job, and re-raise any contained chunk panic.
    pub(crate) fn run<F: Fn(usize, Range<usize>) + Sync>(
        &self,
        relic: &Relic,
        bounds: &[usize],
        body: &F,
    ) {
        let job = CrossJob::new(bounds, body);
        self.channel.publish(&job);
        let assist = || {
            run_chunks(&job, None);
        };
        relic.pair(
            || {
                run_chunks(&job, None);
            },
            &assist,
        );
        wait_all(&job);
        self.channel.retire();
        if job.panicked.load(Ordering::Acquire) {
            panic!("cross-shard chunk panicked");
        }
    }
}

/// Slot states for the per-shard lease mailboxes.
const EMPTY: u8 = 0;
/// Owner is writing the channel pointer (transient, single-threaded).
const SETUP: u8 = 1;
/// A lease offer is posted; the shard may attach.
const POSTED: u8 = 2;
/// The shard is attached and serving the lease.
const TAKEN: u8 = 3;

/// One shard's lease mailbox.
struct BrokerSlot {
    state: AtomicU8,
    /// Valid while `state` is `POSTED`/`TAKEN`; written under `SETUP`.
    channel: UnsafeCell<*const LeaseChannel>,
}

// SAFETY: `channel` is written only by the reserving thread while it
// holds the slot in `SETUP`, and read only after an acquire CAS
// observes `POSTED` — the state machine is the synchronization.
unsafe impl Sync for BrokerSlot {}
unsafe impl Send for BrokerSlot {}

/// Per-shard eligibility handles, bound once the pool exists.
struct ShardHooks {
    depth: Arc<AtomicUsize>,
    quarantined: Arc<AtomicBool>,
}

/// Lease-traffic counters (see [`LeaseBroker::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LeaseStats {
    /// Leases a borrower actually attached to.
    pub served: u64,
    /// Leases a borrower returned early (revocation predicate fired
    /// while the session was still open).
    pub revoked: u64,
    /// Chunks executed by borrowers (owner-run chunks not counted).
    pub chunks_lent: u64,
}

/// The broker through which an owner shard offers a whale request's
/// work to idle siblings. One instance per
/// [`Engine`](crate::coordinator::Engine); every shard's coordinator
/// holds it through its [`CrossCtx`].
pub struct LeaseBroker {
    slots: Vec<BrokerSlot>,
    hooks: Vec<OnceLock<ShardHooks>>,
    served: AtomicU64,
    revoked: AtomicU64,
    chunks_lent: AtomicU64,
}

impl LeaseBroker {
    /// Broker for `shards` shards, all slots empty and no eligibility
    /// handles bound yet (an unbound shard is never offered).
    pub fn new(shards: usize) -> LeaseBroker {
        LeaseBroker {
            slots: (0..shards)
                .map(|_| BrokerSlot {
                    state: AtomicU8::new(EMPTY),
                    channel: UnsafeCell::new(std::ptr::null()),
                })
                .collect(),
            hooks: (0..shards).map(|_| OnceLock::new()).collect(),
            served: AtomicU64::new(0),
            revoked: AtomicU64::new(0),
            chunks_lent: AtomicU64::new(0),
        }
    }

    /// Number of shard slots.
    pub fn shard_count(&self) -> usize {
        self.slots.len()
    }

    /// Bind a shard's live-eligibility handles (queue depth and
    /// quarantine flag, shared with the pool). Idempotent-ish: only the
    /// first bind per shard takes effect.
    pub fn bind(&self, shard: usize, depth: Arc<AtomicUsize>, quarantined: Arc<AtomicBool>) {
        let _ = self.hooks[shard].set(ShardHooks { depth, quarantined });
    }

    /// Whether `shard` currently has a lease posted or taken — the
    /// router folds this into its wait estimate so small requests are
    /// not piled onto a shard serving a whale.
    pub fn is_leased(&self, shard: usize) -> bool {
        self.slots[shard].state.load(Ordering::Acquire) != EMPTY
    }

    /// Lease-traffic counters.
    pub fn stats(&self) -> LeaseStats {
        LeaseStats {
            served: self.served.load(Ordering::Relaxed),
            revoked: self.revoked.load(Ordering::Relaxed),
            chunks_lent: self.chunks_lent.load(Ordering::Relaxed),
        }
    }

    /// Reserve up to `max_borrow` eligible shards for `channel`:
    /// bound, not quarantined, queue depth ≤ `offer_depth`, not the
    /// owner itself, slot empty. Returns the reserved shard indices
    /// (possibly empty — borrowing is best-effort by design).
    pub(crate) fn reserve(
        &self,
        home: usize,
        max_borrow: usize,
        offer_depth: usize,
        channel: &LeaseChannel,
    ) -> Vec<usize> {
        let mut reserved = Vec::new();
        for (s, slot) in self.slots.iter().enumerate() {
            if reserved.len() >= max_borrow {
                break;
            }
            if s == home {
                continue;
            }
            let Some(hooks) = self.hooks[s].get() else { continue };
            if hooks.quarantined.load(Ordering::Acquire)
                || hooks.depth.load(Ordering::Acquire) > offer_depth
            {
                continue;
            }
            if slot
                .state
                .compare_exchange(EMPTY, SETUP, Ordering::AcqRel, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            // SAFETY: we hold the slot in SETUP — no other thread
            // touches the pointer until we publish POSTED below.
            unsafe { *slot.channel.get() = channel as *const LeaseChannel };
            slot.state.store(POSTED, Ordering::Release);
            reserved.push(s);
        }
        reserved
    }

    /// Close a session: flag the channel closed, cancel every still
    /// un-taken offer, and wait for attached borrowers to detach. After
    /// this returns no borrower holds a reference to the channel.
    pub(crate) fn close(&self, channel: &LeaseChannel, reserved: &[usize]) {
        channel.closed.store(true, Ordering::SeqCst);
        for &s in reserved {
            let slot = &self.slots[s];
            let mut spins = 0u32;
            loop {
                match slot.state.compare_exchange(
                    POSTED,
                    EMPTY,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => break,
                    Err(state) if state == EMPTY => break,
                    // TAKEN: the borrower saw `closed` (or its
                    // revocation predicate) and is detaching.
                    Err(_) => {
                        spins += 1;
                        if spins >= 10_000 {
                            std::thread::yield_now();
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                }
            }
        }
    }

    /// Serve a lease posted to `shard`, if any: attach, run published
    /// jobs through this shard's own pair-level wave protocol, and
    /// detach when the session closes or `should_return` fires (new
    /// work on our own queue, quarantine, shutdown). Returns whether a
    /// lease was served at all. Called from the pool's idle hook — the
    /// shard's queue is empty when we get here, and `should_return` is
    /// re-checked before every chunk claim, so the shard is back on its
    /// own queue within one chunk of new work arriving.
    pub fn serve(
        &self,
        shard: usize,
        relic: &Relic,
        should_return: &(dyn Fn() -> bool + Sync),
    ) -> bool {
        let slot = &self.slots[shard];
        if slot
            .state
            .compare_exchange(POSTED, TAKEN, Ordering::AcqRel, Ordering::Relaxed)
            .is_err()
        {
            return false;
        }
        // SAFETY: the acquire CAS on POSTED synchronizes with the
        // owner's release store, so the pointer written under SETUP is
        // visible; the owner keeps the channel alive until every slot
        // it reserved is EMPTY again (we store EMPTY last, below).
        let chan = unsafe { &**slot.channel.get() };
        self.served.fetch_add(1, Ordering::Relaxed);
        let mut revoked = false;
        let mut spins = 0u32;
        loop {
            if chan.closed.load(Ordering::SeqCst) {
                break;
            }
            if should_return() {
                revoked = true;
                break;
            }
            let p = chan.job.load(Ordering::SeqCst);
            if p.is_null() {
                // Between loops of the owner's kernel: stay attached,
                // spin lightly (we are an idle core by definition).
                spins += 1;
                if spins >= 10_000 {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
                continue;
            }
            spins = 0;
            // Hazard protocol: register interest, then re-check the
            // pointer. If the owner retired the job in between, back
            // off without dereferencing it; otherwise the owner's
            // retire() is now waiting on our busy count.
            chan.busy.fetch_add(1, Ordering::SeqCst);
            if chan.job.load(Ordering::SeqCst) != p {
                chan.busy.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            // SAFETY: guarded by the busy count — the owner cannot pop
            // the job's frame until we decrement.
            let job = unsafe { &*p };
            if job.cursor.load(Ordering::Acquire) < job.n_chunks {
                let count = AtomicU64::new(0);
                let assist = || {
                    count.fetch_add(run_chunks(job, Some(should_return)) as u64, Ordering::Relaxed);
                };
                relic.pair(
                    || {
                        count.fetch_add(
                            run_chunks(job, Some(should_return)) as u64,
                            Ordering::Relaxed,
                        );
                    },
                    &assist,
                );
                self.chunks_lent.fetch_add(count.load(Ordering::Relaxed), Ordering::Relaxed);
            } else {
                std::hint::spin_loop();
            }
            chan.busy.fetch_sub(1, Ordering::SeqCst);
        }
        if revoked {
            self.revoked.fetch_add(1, Ordering::Relaxed);
        }
        slot.state.store(EMPTY, Ordering::Release);
        true
    }
}

impl std::fmt::Debug for LeaseBroker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LeaseBroker")
            .field("shards", &self.slots.len())
            .field("stats", &self.stats())
            .finish()
    }
}

/// Everything a shard's coordinator needs to open cross-shard sessions:
/// the engine-wide broker, its own shard index (never offered to
/// itself), and the borrowing policy knobs (`[relic] max_borrow`,
/// `[pool] offer_depth`).
#[derive(Clone, Debug)]
pub struct CrossCtx {
    /// The engine-wide lease broker.
    pub broker: Arc<LeaseBroker>,
    /// The owning shard's index.
    pub shard: usize,
    /// Maximum shards to borrow per request (0 = borrowing off).
    pub max_borrow: usize,
    /// Maximum queue depth at which a shard is still offered.
    pub offer_depth: usize,
}

/// Open a cross-shard session around one request's kernel run: reserve
/// idle shards, hand `f` a [`Par`] that fans parallel loops out to them
/// (or the plain pair-scheduled `Par` when nothing could be borrowed —
/// including always when `max_borrow == 0`), and tear the session down
/// before returning, even if `f` panics. The teardown waits for every
/// borrower to detach, so nothing dangles.
pub fn with_lease<R>(
    ctx: &CrossCtx,
    relic: &Relic,
    schedule: Schedule,
    f: impl FnOnce(&Par<'_>) -> R,
) -> R {
    let channel = LeaseChannel::new();
    let reserved = if ctx.max_borrow == 0 {
        Vec::new()
    } else {
        ctx.broker.reserve(ctx.shard, ctx.max_borrow, ctx.offer_depth, &channel)
    };
    let session = CrossSession { channel: &channel };
    let result = catch_unwind(AssertUnwindSafe(|| {
        let par = if reserved.is_empty() {
            Par::Scheduled(relic, schedule)
        } else {
            Par::Cross(relic, schedule, &session)
        };
        f(&par)
    }));
    ctx.broker.close(&channel, &reserved);
    match result {
        Ok(r) => r,
        Err(payload) => resume_unwind(payload),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn chunk_count_is_pure_and_clamped() {
        assert_eq!(cross_chunk_count(0, 16), 1);
        assert_eq!(cross_chunk_count(15, 16), 1);
        assert_eq!(cross_chunk_count(32, 16), 2);
        assert_eq!(cross_chunk_count(1 << 20, 16), MAX_CROSS_CHUNKS);
        assert_eq!(cross_chunk_count(100, 0), MAX_CROSS_CHUNKS.min(100));
        // Same inputs, same count — boundaries are schedule-pure.
        assert_eq!(cross_chunk_count(777, 16), cross_chunk_count(777, 16));
    }

    #[test]
    fn even_bounds_cover_range_exactly() {
        let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
        for (lo, hi, k) in [(0usize, 32usize, 2usize), (5, 100, 7), (0, 64, 64), (3, 4, 1)] {
            even_bounds(&(lo..hi), k, &mut bounds);
            assert_eq!(bounds[0], lo);
            assert_eq!(bounds[k], hi);
            let total: usize = (0..k).map(|i| bounds[i + 1] - bounds[i]).sum();
            assert_eq!(total, hi - lo, "chunks partition the range");
            for i in 0..k {
                assert!(bounds[i] <= bounds[i + 1], "monotone");
            }
        }
    }

    #[test]
    fn bounds_by_forces_monotone_and_clamps() {
        let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
        // A deliberately non-monotone, out-of-range bound closure.
        bounds_by(&(10..50), 4, &|i, _k| [0, 45, 20, 999][i], &mut bounds);
        assert_eq!(bounds[0], 10);
        assert_eq!(bounds[4], 50);
        for i in 0..4 {
            assert!(bounds[i] <= bounds[i + 1]);
            assert!(bounds[i] >= 10 && bounds[i] <= 50);
        }
    }

    #[test]
    fn unreserved_session_degrades_to_pair_schedule() {
        // max_borrow = 0: the session hands back a plain scheduled Par
        // and posts nothing — the PR 6 path, structurally.
        let relic = Relic::new();
        let broker = Arc::new(LeaseBroker::new(2));
        let ctx = CrossCtx { broker: Arc::clone(&broker), shard: 0, max_borrow: 0, offer_depth: 0 };
        let hits = AtomicU32::new(0);
        with_lease(&ctx, &relic, Schedule::Dynamic, |par| {
            assert!(matches!(par, Par::Scheduled(_, Schedule::Dynamic)));
            par.for_each_index(0..64, 16, |_i| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 64);
        assert!(!broker.is_leased(0));
        assert!(!broker.is_leased(1));
        assert_eq!(broker.stats(), LeaseStats::default());
    }

    #[test]
    fn borrowed_shard_serves_chunks_exactly_once() {
        let broker = Arc::new(LeaseBroker::new(2));
        let depth = Arc::new(AtomicUsize::new(0));
        let quarantined = Arc::new(AtomicBool::new(false));
        broker.bind(1, Arc::clone(&depth), Arc::clone(&quarantined));
        let done = Arc::new(AtomicBool::new(false));
        let borrower = {
            let broker = Arc::clone(&broker);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let relic = Relic::new();
                while !done.load(Ordering::Acquire) {
                    if !broker.serve(1, &relic, &|| false) {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let relic = Relic::new();
        let ctx = CrossCtx { broker: Arc::clone(&broker), shard: 0, max_borrow: 1, offer_depth: 0 };
        const N: usize = 1024;
        let hits: Vec<AtomicU32> = (0..N).map(|_| AtomicU32::new(0)).collect();
        with_lease(&ctx, &relic, Schedule::Dynamic, |par| {
            assert!(matches!(par, Par::Cross(..)), "shard 1 was idle and eligible");
            // Wait for the borrower to attach so lending is exercised
            // deterministically, then run several loops through one
            // session (the per-request shape).
            while broker.stats().served == 0 {
                std::hint::spin_loop();
            }
            for _ in 0..4 {
                par.for_each_index(0..N, 16, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        done.store(true, Ordering::Release);
        borrower.join().unwrap();
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 4, "index {i} ran exactly once per loop");
        }
        assert_eq!(broker.stats().served, 1, "one lease attach for the whole session");
        assert!(!broker.is_leased(1), "slot returned to EMPTY");
    }

    #[test]
    fn revocation_loses_and_duplicates_nothing() {
        let broker = Arc::new(LeaseBroker::new(2));
        broker.bind(1, Arc::new(AtomicUsize::new(0)), Arc::new(AtomicBool::new(false)));
        let revoke = Arc::new(AtomicBool::new(false));
        let done = Arc::new(AtomicBool::new(false));
        let borrower = {
            let broker = Arc::clone(&broker);
            let revoke = Arc::clone(&revoke);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let relic = Relic::new();
                while !done.load(Ordering::Acquire) {
                    let should_return = || revoke.load(Ordering::Acquire);
                    if !broker.serve(1, &relic, &should_return) {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let relic = Relic::new();
        let ctx = CrossCtx { broker: Arc::clone(&broker), shard: 0, max_borrow: 1, offer_depth: 0 };
        const N: usize = 2048;
        let hits: Vec<AtomicU32> = (0..N).map(|_| AtomicU32::new(0)).collect();
        with_lease(&ctx, &relic, Schedule::Dynamic, |par| {
            while broker.stats().served == 0 {
                std::hint::spin_loop();
            }
            // Revoke mid-kernel: the borrower finishes at most the
            // chunk in hand and detaches; the owner pair completes the
            // rest. Nothing may be lost or run twice.
            par.for_each_index(0..N, 16, |i| {
                if i == N / 4 {
                    revoke.store(true, Ordering::Release);
                }
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        done.store(true, Ordering::Release);
        borrower.join().unwrap();
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} exactly once under revocation");
        }
        let stats = broker.stats();
        assert!(stats.revoked >= 1, "the revocation was counted: {stats:?}");
    }

    #[test]
    fn quarantined_and_busy_shards_are_never_offered() {
        let relic = Relic::new();
        let broker = Arc::new(LeaseBroker::new(3));
        // Shard 1: quarantined. Shard 2: deep queue. Shard 0 is home.
        broker.bind(1, Arc::new(AtomicUsize::new(0)), Arc::new(AtomicBool::new(true)));
        broker.bind(2, Arc::new(AtomicUsize::new(5)), Arc::new(AtomicBool::new(false)));
        let ctx = Arc::new(CrossCtx {
            broker: Arc::clone(&broker),
            shard: 0,
            max_borrow: 2,
            offer_depth: 0,
        });
        with_lease(&ctx, &relic, Schedule::Static, |par| {
            assert!(
                matches!(par, Par::Scheduled(..)),
                "nothing eligible → pair fallback, no posts"
            );
        });
        assert!(!broker.is_leased(1));
        assert!(!broker.is_leased(2));
        // Raising the offer threshold makes the deep-queue shard
        // eligible again (shallow-queue offers are a policy knob).
        let ctx = CrossCtx { broker: Arc::clone(&broker), shard: 0, max_borrow: 2, offer_depth: 5 };
        with_lease(&ctx, &relic, Schedule::Static, |par| {
            assert!(matches!(par, Par::Cross(..)));
            assert!(broker.is_leased(2), "posted offers count as leased for the router");
            assert!(!broker.is_leased(1), "quarantined shards are never offered");
        });
        assert!(!broker.is_leased(2), "un-taken offers are cancelled at close");
    }

    #[test]
    fn chunk_panic_is_contained_and_reraised_after_join() {
        let relic = Relic::new();
        let broker = Arc::new(LeaseBroker::new(1));
        let ctx = CrossCtx { broker, shard: 0, max_borrow: 0, offer_depth: 0 };
        let ran = Arc::new(AtomicU32::new(0));
        let result = {
            let ran = Arc::clone(&ran);
            catch_unwind(AssertUnwindSafe(move || {
                // Drive the job machinery directly (max_borrow = 0
                // would hand back the pair path, bypassing CrossJob).
                let channel = LeaseChannel::new();
                let session = CrossSession { channel: &channel };
                let mut bounds = [0usize; MAX_CROSS_CHUNKS + 1];
                even_bounds(&(0..64), 4, &mut bounds);
                session.run(&relic, &bounds[..5], &|ci, sub| {
                    ran.fetch_add(sub.len() as u32, Ordering::Relaxed);
                    if ci == 2 {
                        panic!("injected");
                    }
                });
            }))
        };
        assert!(result.is_err(), "the chunk panic re-raises after the join");
        assert_eq!(ran.load(Ordering::Relaxed), 64, "every chunk still ran (exactly once)");
        let _ = ctx; // silence unused when asserts compile out
    }
}
