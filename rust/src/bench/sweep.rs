//! Granularity sweep: the experiment the paper implies but never shows —
//! how each runtime's speedup responds to *task size*, holding the
//! workload shape constant.
//!
//! The paper evaluates seven kernels at fixed (tiny) sizes; this sweep
//! varies a single kernel's trace length from ~0.25 µs to ~16 µs and
//! plots speedup vs granularity per runtime. It makes the crossovers
//! explicit: every parking runtime has a task size below which it
//! degrades (its wake latency), every spinning runtime converges to the
//! co-run ceiling, and Relic's advantage concentrates in the sub-2 µs
//! regime the paper targets.

use crate::smtsim::{self, CoreConfig, Trace};

use super::workloads::{calibrated_trace, Workload};

/// One sweep data point.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub runtime: String,
    pub task_micros: f64,
    pub speedup: f64,
}

/// Default sweep sizes in microseconds.
pub const DEFAULT_MICROS: [f64; 7] = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];

/// Sweep task granularity for one kernel across all runtimes + relic.
pub fn granularity_sweep(kernel: &str, micros: &[f64], cfg: &CoreConfig) -> Vec<SweepPoint> {
    let w = Workload::new(kernel);
    let raw_a = w.raw_trace(0);
    let raw_b = w.raw_trace(1);
    let mut points = Vec::new();
    for &us in micros {
        let target = (us * cfg.freq_ghz * 1000.0) as u64;
        let a: Trace = calibrated_trace(&raw_a, target, cfg);
        let b: Trace = calibrated_trace(&raw_b, target, cfg);
        for rt in smtsim::model_names() {
            points.push(SweepPoint {
                runtime: rt.to_string(),
                task_micros: us,
                speedup: smtsim::speedup(rt, &a, &b, cfg),
            });
        }
    }
    points
}

/// The task size where `runtime` first reaches `threshold` speedup
/// (linear scan over the sweep; `None` if never).
pub fn breakeven_micros(points: &[SweepPoint], runtime: &str, threshold: f64) -> Option<f64> {
    points
        .iter()
        .filter(|p| p.runtime == runtime && p.speedup >= threshold)
        .map(|p| p.task_micros)
        .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.min(v))))
}

/// Render the sweep as a text table (runtimes x sizes).
pub fn render(points: &[SweepPoint]) -> String {
    let mut sizes: Vec<f64> = Vec::new();
    for p in points {
        if !sizes.contains(&p.task_micros) {
            sizes.push(p.task_micros);
        }
    }
    let mut out = format!("{:<14}", "runtime");
    for s in &sizes {
        out += &format!("{:>9}", format!("{s}µs"));
    }
    out += "\n";
    for rt in smtsim::model_names() {
        out += &format!("{rt:<14}");
        for s in &sizes {
            let v = points
                .iter()
                .find(|p| p.runtime == rt && p.task_micros == *s)
                .map(|p| p.speedup)
                .unwrap_or(f64::NAN);
            out += &format!("{v:>9.3}");
        }
        out += "\n";
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shows_the_granularity_story() {
        let cfg = CoreConfig::default();
        let points = granularity_sweep("tc", &[0.5, 4.0, 16.0], &cfg);
        let get = |rt: &str, us: f64| {
            points
                .iter()
                .find(|p| p.runtime == rt && p.task_micros == us)
                .unwrap()
                .speedup
        };
        // GNU (parking) degrades on fine tasks, recovers on coarse ones.
        assert!(get("gnu-openmp", 0.5) < 1.0);
        assert!(get("gnu-openmp", 16.0) > 1.2);
        // Speedup grows with granularity for every runtime.
        for rt in smtsim::model_names() {
            assert!(
                get(rt, 16.0) >= get(rt, 0.5) - 0.05,
                "{rt}: coarse {:.3} < fine {:.3}",
                get(rt, 16.0),
                get(rt, 0.5)
            );
        }
        // Relic dominates at the finest granularity.
        for rt in smtsim::model_names() {
            if rt != "relic" {
                assert!(
                    get("relic", 0.5) >= get(rt, 0.5) - 1e-9,
                    "relic must win at 0.5µs vs {rt}"
                );
            }
        }
    }

    #[test]
    fn breakeven_reports_first_crossing() {
        let points = vec![
            SweepPoint { runtime: "x".into(), task_micros: 0.5, speedup: 0.8 },
            SweepPoint { runtime: "x".into(), task_micros: 1.0, speedup: 1.1 },
            SweepPoint { runtime: "x".into(), task_micros: 2.0, speedup: 1.4 },
        ];
        assert_eq!(breakeven_micros(&points, "x", 1.0), Some(1.0));
        assert_eq!(breakeven_micros(&points, "x", 1.5), None);
        assert_eq!(breakeven_micros(&points, "y", 1.0), None);
    }

    #[test]
    fn render_contains_all_runtimes() {
        let cfg = CoreConfig::default();
        let points = granularity_sweep("cc", &[1.0], &cfg);
        let table = render(&points);
        for rt in smtsim::model_names() {
            assert!(table.contains(rt), "{rt} missing");
        }
    }
}
