//! The paper's benchmark workloads (§IV): the six GAP kernels on the
//! 32-node Kronecker input plus RapidJSON-style parsing of the widget
//! document — as native closures (wall-clock mode) and as calibrated
//! simulator traces (sim mode).
//!
//! ## Granularity calibration
//!
//! The paper reports each kernel's serial task time on its i7-8700
//! (§IV: BC 1.1 µs, BFS 0.5 µs, CC 0.4 µs, PR 4.3 µs, SSSP 6.4 µs,
//! TC 1.3 µs, JSON 1.1 µs). Trace-level simulation reproduces each
//! kernel's *operation mix* but not its exact machine IPC, so raw trace
//! lengths land within ~0.2–7x of those times. [`calibrated_trace`]
//! closes the gap: it repeats (whole copies) or truncates (prefix) the
//! recorded trace until the simulated solo runtime matches the paper's
//! reported granularity, preserving the mix. The scale factor per
//! kernel is recorded in EXPERIMENTS.md §Calibration.

use crate::graph::{bc, bfs, cc, kronecker::paper_graph, pr, sssp, tc, CsrGraph};
use crate::json;
use crate::probe::Probe;
use crate::relic::Par;
use crate::smtsim::{self, CoreConfig, Trace, TraceProbe};

/// Benchmark kernel names in the paper's figure order.
pub const KERNEL_NAMES: [&str; 7] = ["bc", "bfs", "cc", "pr", "sssp", "tc", "json"];

/// The paper's measured serial task granularities in microseconds (§IV).
pub fn paper_task_micros(kernel: &str) -> f64 {
    match kernel {
        "bc" => 1.1,
        "bfs" => 0.5,
        "cc" => 0.4,
        "pr" => 4.3,
        "sssp" => 6.4,
        "tc" => 1.3,
        "json" => 1.1,
        _ => panic!("unknown kernel {kernel}"),
    }
}

/// One benchmark workload: can run natively (with a checksum) and can
/// record its operation trace.
pub struct Workload {
    pub name: &'static str,
    graph: CsrGraph,
    json_doc: &'static [u8],
}

impl Workload {
    /// Instantiate a paper workload by name.
    pub fn new(name: &str) -> Self {
        let name = KERNEL_NAMES
            .iter()
            .find(|k| **k == name)
            .unwrap_or_else(|| panic!("unknown kernel {name}"));
        Workload { name, graph: paper_graph(), json_doc: json::WIDGET }
    }

    /// All seven paper workloads.
    pub fn all() -> Vec<Workload> {
        KERNEL_NAMES.iter().map(|k| Workload::new(k)).collect()
    }

    /// Run one task instance natively, returning a work checksum (the
    /// value also defends against dead-code elimination in benches).
    pub fn run_native(&self) -> u64 {
        self.run_probed(&mut crate::probe::NoProbe)
    }

    /// Run one task instance with the kernel's hot loops fork-joined
    /// over the SMT pair (`Par::Relic`) or plain serial (`Par::Serial`).
    /// The parallel kernels are deterministic by construction, so the
    /// checksum always equals [`run_native`](Self::run_native)'s.
    ///
    /// JSON is the exception that proves the granularity rule: one DOM
    /// parse is a sequential dependence chain, so the single-document
    /// workload runs serially here — document-*batch* splitting is
    /// exercised by the coordinator and `benches/parallel_for.rs`.
    pub fn run_native_par(&self, par: &Par) -> u64 {
        use crate::coordinator::{run_native_kernel_par, GraphKernel};
        match self.name {
            "json" => json::parse_batch_par(&[self.json_doc], par)
                .pop()
                .expect("one result")
                .expect("widget parses")
                .node_count() as u64,
            // The six graph kernels share one dispatch with the
            // coordinator service (same source 0 as `run_native`).
            name => {
                let kernel = GraphKernel::parse(name).expect("graph kernel name");
                run_native_kernel_par(kernel, &self.graph, 0, par)
            }
        }
    }

    /// Run one task instance through a probe (trace recording or no-op).
    pub fn run_probed<P: Probe>(&self, probe: &mut P) -> u64 {
        match self.name {
            "bc" => bc::checksum(&bc::brandes_single_source(&self.graph, 0, probe)),
            "bfs" => bfs::checksum(&bfs::bfs(&self.graph, 0, probe)),
            "cc" => cc::checksum(&cc::shiloach_vishkin(&self.graph, probe)),
            "pr" => pr::checksum(&pr::pagerank(&self.graph, pr::MAX_ITERS, pr::TOLERANCE, probe)),
            "sssp" => {
                sssp::checksum(&sssp::delta_stepping(&self.graph, 0, sssp::DEFAULT_DELTA, probe))
            }
            "tc" => tc::checksum(tc::triangle_count(&self.graph, probe)),
            "json" => json::parse_probed(self.json_doc, probe)
                .expect("widget parses")
                .node_count() as u64,
            _ => unreachable!(),
        }
    }

    /// Record the raw (uncalibrated) trace of one task instance.
    pub fn raw_trace(&self, instance: u64) -> Trace {
        let mut probe = TraceProbe::with_offset(instance);
        self.run_probed(&mut probe);
        probe.finish()
    }

    /// Record the calibrated trace: solo simulated runtime matches the
    /// paper's reported granularity within ±5%. Results for the default
    /// `CoreConfig` are memoized process-wide (calibration reruns the
    /// simulator several times).
    pub fn trace(&self, instance: u64, cfg: &CoreConfig) -> Trace {
        let default_cfg = *cfg == CoreConfig::default();
        if default_cfg {
            if let Some(hit) = trace_cache().lock().unwrap().get(&(self.name, instance)) {
                return hit.clone();
            }
        }
        let raw = self.raw_trace(instance);
        let target = (paper_task_micros(self.name) * cfg.freq_ghz * 1000.0) as u64;
        let out = calibrated_trace(&raw, target, cfg);
        if default_cfg {
            trace_cache().lock().unwrap().insert((self.name, instance), out.clone());
        }
        out
    }
}

/// The service-bench request plan: `n` requests cycling the six graph
/// kernels over 32 sources. [`crate::bench::figures::pool_scaling`]
/// and [`crate::bench::figures::admission_sweep`] share this plan, so
/// their throughput rows measure the same workload and stay
/// comparable.
pub fn mixed_request_plan(n: usize) -> Vec<(crate::coordinator::GraphKernel, u32)> {
    let kernels = crate::coordinator::GraphKernel::all();
    (0..n).map(|i| (kernels[i % kernels.len()], (i % 32) as u32)).collect()
}

type TraceCache = std::sync::Mutex<std::collections::HashMap<(&'static str, u64), Trace>>;

fn trace_cache() -> &'static TraceCache {
    static CACHE: std::sync::OnceLock<TraceCache> = std::sync::OnceLock::new();
    CACHE.get_or_init(Default::default)
}

/// Solo simulated cycles of a trace (context 1 idle, warm caches).
pub fn solo_cycles(trace: &Trace, cfg: &CoreConfig) -> u64 {
    smtsim::SmtCore::new(*cfg).run_warm(&trace.ops, &[]).cycles
}

/// Scale `raw` (by whole-trace repetition and/or prefix truncation,
/// preserving the op mix) until its solo simulated runtime is within
/// ±5% of `target_cycles`. Returns the calibrated trace.
pub fn calibrated_trace(raw: &Trace, target_cycles: u64, cfg: &CoreConfig) -> Trace {
    assert!(!raw.ops.is_empty(), "empty trace");
    // Grow by repetition until one run covers the target.
    let mut work = raw.clone();
    let mut solo = solo_cycles(&work, cfg);
    while solo < target_cycles {
        work.extend(raw);
        let next = solo_cycles(&work, cfg);
        assert!(next > solo, "trace repetition must increase runtime");
        solo = next;
    }
    if within(solo, target_cycles, 0.05) {
        return work;
    }
    // Binary-search a prefix length whose solo time hits the target.
    let (mut lo, mut hi) = (1usize, work.ops.len());
    while lo < hi {
        let mid = (lo + hi) / 2;
        let t = Trace { ops: work.ops[..mid].to_vec() };
        let c = solo_cycles(&t, cfg);
        if within(c, target_cycles, 0.05) {
            return t;
        }
        if c < target_cycles {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    Trace { ops: work.ops[..lo.max(1)].to_vec() }
}

fn within(value: u64, target: u64, tol: f64) -> bool {
    (value as f64 - target as f64).abs() <= tol * target as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_workloads_run_natively() {
        for w in Workload::all() {
            let c1 = w.run_native();
            let c2 = w.run_native();
            assert_eq!(c1, c2, "{} checksum must be deterministic", w.name);
        }
    }

    #[test]
    fn parallel_checksums_equal_serial_on_all_workloads() {
        // The acceptance bar for the fork-join layer: every
        // parallelized kernel reproduces its serial checksum on the
        // paper's 32-node Kronecker input, repeatedly, under every
        // chunk-assignment schedule.
        let relic = crate::relic::Relic::new();
        for w in Workload::all() {
            let serial = w.run_native();
            assert_eq!(w.run_native_par(&Par::Serial), serial, "{} Par::Serial", w.name);
            for schedule in crate::relic::Schedule::all() {
                let par = Par::Relic(&relic).with_schedule(schedule);
                for round in 0..5 {
                    assert_eq!(
                        w.run_native_par(&par),
                        serial,
                        "{} under {} round {round}",
                        w.name,
                        schedule.name()
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_checksums_survive_queue_overflow() {
        // A 2-slot queue forces constant submit overflow; the inline
        // fallback must preserve every checksum.
        let relic = crate::relic::Relic::with_config(crate::relic::RelicConfig {
            queue_capacity: 2,
            ..Default::default()
        });
        for w in Workload::all() {
            assert_eq!(
                w.run_native_par(&Par::Relic(&relic)),
                w.run_native(),
                "{} under queue pressure",
                w.name
            );
        }
    }

    #[test]
    fn mixed_request_plan_cycles_kernels_and_sources() {
        use crate::coordinator::GraphKernel;
        let plan = mixed_request_plan(14);
        assert_eq!(plan.len(), 14);
        assert_eq!(plan[0].0, GraphKernel::all()[0]);
        assert_eq!(plan[6].0, plan[0].0, "six kernels cycle");
        assert_eq!(plan[0].1, 0);
        assert_eq!(plan[13].1, 13, "sources walk 0..32");
        assert!(mixed_request_plan(0).is_empty());
    }

    #[test]
    fn native_and_traced_checksums_agree() {
        // The probe must not change kernel results (same code path).
        for w in Workload::all() {
            let native = w.run_native();
            let mut probe = TraceProbe::new();
            let traced = w.run_probed(&mut probe);
            assert_eq!(native, traced, "{}", w.name);
            assert!(!probe.is_empty(), "{} records ops", w.name);
        }
    }

    #[test]
    fn calibration_hits_paper_granularity() {
        let cfg = CoreConfig::default();
        for w in Workload::all() {
            let t = w.trace(0, &cfg);
            let target = (paper_task_micros(w.name) * cfg.freq_ghz * 1000.0) as u64;
            let got = solo_cycles(&t, &cfg);
            assert!(
                within(got, target, 0.07),
                "{}: calibrated {got} vs target {target}",
                w.name
            );
        }
    }

    #[test]
    fn granularity_ordering_matches_paper() {
        // SSSP > PR > TC > BC ~ JSON > BFS > CC after calibration.
        let cfg = CoreConfig::default();
        let us = |k: &str| {
            let w = Workload::new(k);
            solo_cycles(&w.trace(0, &cfg), &cfg) as f64 / (cfg.freq_ghz * 1000.0)
        };
        let (sssp, pr, tc, bfs, cc) = (us("sssp"), us("pr"), us("tc"), us("bfs"), us("cc"));
        assert!(sssp > pr && pr > tc && tc > bfs && bfs > cc);
    }
}
