//! Wall-clock measurement harness (the paper's protocol: repeat the
//! two-instance experiment 10^5 times and average).
//!
//! On hosts with a real SMT pair this reproduces the paper's actual
//! methodology; on the 1-CPU CI host the numbers are not meaningful
//! (DESIGN.md §2) and sim mode is authoritative — `repro` warns when
//! pinning is unavailable.

use std::time::Instant;

use crate::runtimes::TaskRuntime;

use super::workloads::Workload;

/// Summary statistics over repeated timed iterations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub iterations: u64,
    pub mean_ns: f64,
    pub min_ns: u64,
    pub max_ns: u64,
}

/// Time `f` over `iters` iterations (after `warmup` discarded ones),
/// timing the whole block and dividing — matching the paper's
/// "average over 10^5 iterations" (per-iteration clocking would distort
/// sub-µs tasks).
pub fn measure<F: FnMut()>(iters: u64, warmup: u64, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    // Block timing for the mean…
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let total = t0.elapsed().as_nanos() as u64;
    // …plus a short sampled pass for min/max (diagnostic only).
    let sample = iters.min(256);
    let (mut min_ns, mut max_ns) = (u64::MAX, 0u64);
    for _ in 0..sample {
        let t = Instant::now();
        f();
        let ns = t.elapsed().as_nanos() as u64;
        min_ns = min_ns.min(ns);
        max_ns = max_ns.max(ns);
    }
    Stats { iterations: iters, mean_ns: total as f64 / iters as f64, min_ns, max_ns }
}

/// Wall-clock speedup of `runtime` over serial for one workload, per
/// the paper's two-instance protocol.
pub fn wallclock_speedup(
    runtime: &mut dyn TaskRuntime,
    workload: &Workload,
    iters: u64,
    warmup: u64,
) -> f64 {
    let sink = std::sync::atomic::AtomicU64::new(0);
    let task = || {
        sink.fetch_add(workload.run_native(), std::sync::atomic::Ordering::Relaxed);
    };
    // Serial baseline: both instances on the calling thread.
    let serial = measure(iters, warmup, || {
        task();
        task();
    });
    // Parallel: one instance per logical thread via the runtime.
    let parallel = measure(iters, warmup, || {
        runtime.run_pair(&task, &task);
    });
    std::hint::black_box(sink.load(std::sync::atomic::Ordering::Relaxed));
    serial.mean_ns / parallel.mean_ns
}

/// Geometric mean.
pub fn geomean(values: impl IntoIterator<Item = f64>) -> f64 {
    let (mut log_sum, mut n) = (0.0f64, 0u32);
    for v in values {
        assert!(v > 0.0, "geomean needs positive values");
        log_sum += v.ln();
        n += 1;
    }
    if n == 0 {
        return 1.0;
    }
    (log_sum / n as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let mut n = 0u64;
        let s = measure(100, 10, || n += 1);
        assert_eq!(s.iterations, 100);
        assert!(n >= 110); // warmup + timed + sampled
        assert!(s.mean_ns >= 0.0);
        assert!(s.min_ns <= s.max_ns);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean([2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean([1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert_eq!(geomean(std::iter::empty()), 1.0);
    }

    #[test]
    fn wallclock_speedup_runs_serial_runtime() {
        // With the serial "runtime", speedup must be ~1 (same work).
        let mut rt = crate::runtimes::serial::Serial;
        let w = Workload::new("cc");
        let s = wallclock_speedup(&mut rt, &w, 50, 5);
        assert!(s > 0.3 && s < 3.0, "serial-vs-serial speedup {s} far from 1");
    }
}
