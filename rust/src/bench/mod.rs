//! The experiment harness: the paper's benchmark workloads
//! ([`workloads`]), wall-clock measurement ([`harness`]), and figure
//! regeneration ([`figures`]) in simulator and wall-clock modes.

pub mod ablation;
pub mod figures;
pub mod harness;
pub mod svg;
pub mod sweep;
pub mod workloads;

pub use figures::{
    fig1, fig3, fig4, granularity, intra_kernel, pool_scaling, render_pool_scaling,
    section5_geomeans, Cell, IntraRow, PoolScalingRow, SummaryRow,
};
pub use harness::{geomean, measure, wallclock_speedup, Stats};
pub use workloads::{calibrated_trace, paper_task_micros, solo_cycles, Workload, KERNEL_NAMES};
