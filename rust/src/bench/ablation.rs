//! Ablation studies for the design choices DESIGN.md §6 calls out:
//!
//! * **A1 — SPSC queue capacity** (paper fixes 128): sweep 16…1024 and
//!   measure Relic's simulated speedup; depth-1 pairs shouldn't care,
//!   batch submission saturates small queues.
//! * **A2 — waiting mechanism** (paper §VI-B): spin vs spin+pause vs
//!   hybrid vs park for Relic's assistant.
//! * **A3 — SMT fetch policy** sensitivity of the simulator itself
//!   (round-robin vs ICOUNT).

use crate::smtsim::{self, CoreConfig, FetchPolicy, PollKind};

use super::workloads::Workload;

/// One ablation data point.
#[derive(Debug, Clone, PartialEq)]
pub struct AblationRow {
    pub setting: String,
    pub kernel: String,
    pub speedup: f64,
}

/// A2: sweep the assistant's waiting mechanism in the Relic model.
///
/// Scenario per §VI-B: the application runs a *serial phase* (only the
/// main thread has work) before each parallel section — the idle
/// assistant's waiting mechanism determines both how much it disturbs
/// the serial phase (naked spinning steals issue slots) and how fast it
/// reacts to the submit (parking pays the futex wake).
pub fn waiting_mechanism(cfg: &CoreConfig) -> Vec<AblationRow> {
    let variants: [(&str, PollKind); 4] = [
        ("spin", PollKind::Spin),
        ("spin+pause", PollKind::SpinPause),
        ("hybrid", PollKind::HybridPark(64)),
        ("park", PollKind::Park),
    ];
    // Serial main-only phase preceding the parallel section (~1 µs of
    // ALU work at 3 uops/cycle).
    let prelude = smtsim::Op::Compute(4000);
    let mut rows = Vec::new();
    for w in Workload::all() {
        let (a, b) = (w.trace(0, cfg), w.trace(1, cfg));
        let mut serial_prog = vec![prelude];
        serial_prog.extend_from_slice(&a.ops);
        serial_prog.extend_from_slice(&b.ops);
        let serial =
            smtsim::SmtCore::new(*cfg).run_warm(&serial_prog, &[]).cycles as f64;
        for (name, kind) in variants {
            let mut m = smtsim::model("relic").unwrap();
            m.assistant_wait = kind;
            let (mut main, assist) = smtsim::parallel_programs(&m, &a, &b);
            main.insert(0, prelude);
            let par = smtsim::SmtCore::new(*cfg).run_warm(&main, &assist).cycles as f64;
            rows.push(AblationRow {
                setting: name.to_string(),
                kernel: w.name.to_string(),
                speedup: serial / par,
            });
        }
    }
    rows
}

/// A1: queue capacity sweep under *batched* submission (`batch` tasks
/// per iteration, mirroring `Relic::run_batch`): small queues force
/// inline fallbacks, modeled as the producer executing overflow tasks.
pub fn queue_capacity(cfg: &CoreConfig, capacities: &[usize]) -> Vec<AblationRow> {
    // Use the finest kernel (CC) where queue effects are proportionally
    // largest; 16 tasks per batch.
    let w = Workload::new("cc");
    let batch = 16usize;
    let (a, b) = (w.trace(0, cfg), w.trace(1, cfg));
    let m = smtsim::model("relic").unwrap();
    let mut rows = Vec::new();
    // Serial: all batch tasks on one context.
    let mut serial_prog = Vec::new();
    for i in 0..batch {
        serial_prog.extend_from_slice(if i % 2 == 0 { &a.ops } else { &b.ops });
    }
    let serial = smtsim::SmtCore::new(*cfg).run_warm(&serial_prog, &[]).cycles as f64;
    for &cap in capacities {
        // Producer submits up to `cap` tasks (SPSC holds them), runs the
        // overflow inline; assistant drains the queued ones.
        let queued = batch.min(cap) / 1; // tasks the assistant executes
        let inline = batch - queued;
        let mut main = Vec::new();
        let mut assist = Vec::new();
        for _ in 0..queued {
            main.extend_from_slice(&m.submit);
        }
        main.push(smtsim::Op::SetFlag(smtsim::flags::TASK_READY));
        for i in 0..inline {
            main.extend_from_slice(if i % 2 == 0 { &a.ops } else { &b.ops });
        }
        main.push(smtsim::Op::WaitFlag(smtsim::flags::TASK_DONE, m.main_wait));
        assist.push(smtsim::Op::WaitFlag(smtsim::flags::TASK_READY, m.assistant_wait));
        for i in 0..queued {
            assist.extend_from_slice(&m.dispatch);
            assist.extend_from_slice(if i % 2 == 0 { &b.ops } else { &a.ops });
            assist.extend_from_slice(&m.complete);
        }
        assist.push(smtsim::Op::SetFlag(smtsim::flags::TASK_DONE));
        let par = smtsim::SmtCore::new(*cfg).run_warm(&main, &assist).cycles as f64;
        rows.push(AblationRow {
            setting: format!("cap={cap}"),
            kernel: "cc-batch16".into(),
            speedup: serial / par,
        });
    }
    rows
}

/// A3: fetch-policy sensitivity — all kernels, Relic model, RR vs ICOUNT.
pub fn fetch_policy(cfg: &CoreConfig) -> Vec<AblationRow> {
    let mut rows = Vec::new();
    for policy in [FetchPolicy::RoundRobin, FetchPolicy::Icount] {
        let mut c = *cfg;
        c.fetch = policy;
        for w in Workload::all() {
            let (a, b) = (w.trace(0, &c), w.trace(1, &c));
            rows.push(AblationRow {
                setting: format!("{policy:?}"),
                kernel: w.name.to_string(),
                speedup: smtsim::speedup("relic", &a, &b, &c),
            });
        }
    }
    rows
}

/// Render ablation rows grouped by setting.
pub fn render(rows: &[AblationRow], label: &str) -> String {
    let mut out = format!("{label}\n{:<14}{:<12}{:>10}\n", "setting", "kernel", "speedup");
    for r in rows {
        out += &format!("{:<14}{:<12}{:>10.3}\n", r.setting, r.kernel, r.speedup);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pause_beats_naked_spin_and_park_on_fine_tasks() {
        // The paper's §VI-B design argument, quantified: for µs tasks the
        // assistant should spin with pause — naked spin steals sibling
        // slots, parking pays wake latency.
        let cfg = CoreConfig::default();
        let rows = waiting_mechanism(&cfg);
        let get = |setting: &str, kernel: &str| {
            rows.iter()
                .find(|r| r.setting == setting && r.kernel == kernel)
                .unwrap()
                .speedup
        };
        for kernel in ["cc", "bfs", "tc"] {
            let pause = get("spin+pause", kernel);
            let spin = get("spin", kernel);
            let park = get("park", kernel);
            assert!(pause >= spin, "{kernel}: pause {pause} < spin {spin}");
            assert!(pause > park, "{kernel}: pause {pause} <= park {park}");
        }
    }

    #[test]
    fn queue_capacity_sweep_peaks_at_balance() {
        // run_batch pushes every queued task to the assistant, so the
        // best capacity for a batch of 16 is ~8 (half the work runs
        // inline on the producer, half on the assistant); tiny queues
        // leave the assistant starved, huge queues leave the *producer*
        // idle — a design insight the paper's depth-1 usage never hits.
        let cfg = CoreConfig::default();
        let rows = queue_capacity(&cfg, &[2, 4, 8, 16, 32]);
        let get = |cap: usize| {
            rows.iter()
                .find(|r| r.setting == format!("cap={cap}"))
                .unwrap()
                .speedup
        };
        assert!(get(4) > get(2), "4 {:.3} !> 2 {:.3}", get(4), get(2));
        assert!(get(8) > get(4), "8 {:.3} !> 4 {:.3}", get(8), get(4));
        assert!(get(8) > get(16), "8 {:.3} !> 16 {:.3}", get(8), get(16));
        // Saturated beyond the batch size: 16 and 32 identical.
        assert!((get(16) - get(32)).abs() < 1e-9);
    }

    #[test]
    fn fetch_policy_effect_is_modest() {
        let cfg = CoreConfig::default();
        let rows = fetch_policy(&cfg);
        for kernel in super::super::workloads::KERNEL_NAMES {
            let rr = rows
                .iter()
                .find(|r| r.setting.contains("RoundRobin") && r.kernel == kernel)
                .unwrap()
                .speedup;
            let ic = rows
                .iter()
                .find(|r| r.setting.contains("Icount") && r.kernel == kernel)
                .unwrap()
                .speedup;
            assert!(
                (rr - ic).abs() / rr < 0.25,
                "{kernel}: RR {rr} vs ICOUNT {ic} diverge wildly"
            );
        }
    }
}
