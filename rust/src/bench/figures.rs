//! Figure regeneration: every table and figure in the paper's
//! evaluation (DESIGN.md §6).
//!
//! * [`fig1`] — speedups over serial, 7 frameworks × 7 kernels (Fig. 1);
//! * [`fig3`] — Relic's speedups (Fig. 3);
//! * [`fig4`] — average speedups without negative outliers (Fig. 4);
//! * [`granularity`] — the §IV in-text serial task-time table;
//! * [`section5_geomeans`] — the §V in-text geomeans (with degradations);
//! * [`intra_kernel`] — beyond the paper: serial vs `pair` (two whole
//!   instances) vs `parallel_for` (one instance, internally fork-joined)
//!   per kernel, wall-clock;
//! * [`pool_scaling`] — beyond the paper: batch throughput of the
//!   sharded engine vs shard count, with built-in pool-vs-single-pair
//!   checksum verification.
//!
//! Each function returns structured rows; [`render_table`] pretty-prints
//! them with the paper's reference values beside ours.

use crate::smtsim::{self, CoreConfig, Trace};

use super::harness::geomean;
use super::workloads::{paper_task_micros, Workload, KERNEL_NAMES};

/// Framework order used in the paper's figures.
pub const FIG_RUNTIMES: [&str; 7] = [
    "llvm-openmp",
    "gnu-openmp",
    "intel-openmp",
    "x-openmp",
    "onetbb",
    "taskflow",
    "opencilk",
];

/// One speedup measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub kernel: String,
    pub runtime: String,
    pub speedup: f64,
    /// Paper's value for this cell, where the text reports one.
    pub paper: Option<f64>,
}

/// Paper-reported Fig. 1 cells (§V and §VII name the per-kernel bests
/// and a few specific values).
pub fn paper_fig1(kernel: &str, runtime: &str) -> Option<f64> {
    match (kernel, runtime) {
        ("bc", "taskflow") => Some(1.057),
        ("cc", "llvm-openmp") => Some(1.094),
        ("pr", "gnu-openmp") => Some(1.665),
        ("sssp", "taskflow") => Some(1.557),
        ("tc", "llvm-openmp") => Some(1.514),
        ("json", "opencilk") => Some(1.235),
        _ => None,
    }
}

/// Paper-reported Fig. 3 values (Relic): §VII gives BFS and the
/// per-kernel improvements over the best baseline.
pub fn paper_fig3(kernel: &str) -> Option<f64> {
    match kernel {
        "bc" => Some(1.057 + 0.304),
        "cc" => Some(1.094 + 0.301),
        "pr" => Some(1.665 + 0.143),
        "sssp" => Some(1.557 + 0.213),
        "json" => Some(1.235 + 0.086),
        "bfs" => Some(1.056),
        _ => None, // TC: "lower than LLVM's 1.514", no exact value
    }
}

/// Paper Fig. 4 (average speedup w/o negative outliers): Relic = 1.421
/// (§VII 42.1%); baselines derived from the reported relative gains.
pub fn paper_fig4(runtime: &str) -> Option<f64> {
    match runtime {
        "relic" => Some(1.421),
        "llvm-openmp" => Some(1.421 / 1.191),
        "gnu-openmp" => Some(1.421 / 1.310),
        "intel-openmp" => Some(1.421 / 1.202),
        "x-openmp" => Some(1.421 / 1.332),
        "onetbb" => Some(1.421 / 1.301),
        "taskflow" => Some(1.421 / 1.230),
        "opencilk" => Some(1.421 / 1.214),
        _ => None,
    }
}

/// Paper §V geometric means *including* degradations.
pub fn paper_section5_geomean(runtime: &str) -> Option<f64> {
    match runtime {
        "llvm-openmp" => Some(1.139),
        "gnu-openmp" => Some(1.0 - 0.177),
        "intel-openmp" => Some(1.113),
        "x-openmp" => Some(1.0 - 0.067),
        "onetbb" => Some(1.0 - 0.019),
        "taskflow" => Some(1.118),
        "opencilk" => Some(1.126),
        _ => None,
    }
}

/// Calibrated trace pair for every kernel (memoize: trace calibration
/// runs the simulator repeatedly).
pub fn all_trace_pairs(cfg: &CoreConfig) -> Vec<(String, Trace, Trace)> {
    Workload::all()
        .into_iter()
        .map(|w| {
            let a = w.trace(0, cfg);
            let b = w.trace(1, cfg);
            (w.name.to_string(), a, b)
        })
        .collect()
}

/// Fig. 1: the seven baseline frameworks across the seven kernels.
pub fn fig1(cfg: &CoreConfig) -> Vec<Cell> {
    let pairs = all_trace_pairs(cfg);
    let mut cells = Vec::new();
    for rt in FIG_RUNTIMES {
        for (kernel, a, b) in &pairs {
            cells.push(Cell {
                kernel: kernel.clone(),
                runtime: rt.to_string(),
                speedup: smtsim::speedup(rt, a, b, cfg),
                paper: paper_fig1(kernel, rt),
            });
        }
    }
    cells
}

/// Fig. 3: Relic across the seven kernels.
pub fn fig3(cfg: &CoreConfig) -> Vec<Cell> {
    all_trace_pairs(cfg)
        .into_iter()
        .map(|(kernel, a, b)| Cell {
            speedup: smtsim::speedup("relic", &a, &b, cfg),
            paper: paper_fig3(&kernel),
            kernel,
            runtime: "relic".into(),
        })
        .collect()
}

/// One Fig. 4 row: runtime + average speedup without negative outliers.
#[derive(Debug, Clone, PartialEq)]
pub struct SummaryRow {
    pub runtime: String,
    pub value: f64,
    pub paper: Option<f64>,
}

/// Fig. 4: per-framework geomean with degradations replaced by the
/// serial baseline (the paper's "without negative outliers" rule:
/// regressing kernels would be reverted to serial in production).
pub fn fig4(fig1_cells: &[Cell], fig3_cells: &[Cell]) -> Vec<SummaryRow> {
    let mut rows = Vec::new();
    for rt in FIG_RUNTIMES.iter().copied().chain(["relic"]) {
        let vals: Vec<f64> = fig1_cells
            .iter()
            .chain(fig3_cells)
            .filter(|c| c.runtime == rt)
            .map(|c| c.speedup.max(1.0))
            .collect();
        assert_eq!(vals.len(), KERNEL_NAMES.len(), "{rt}");
        rows.push(SummaryRow {
            runtime: rt.to_string(),
            value: geomean(vals),
            paper: paper_fig4(rt),
        });
    }
    rows
}

/// §V: geomeans including degradations (the in-text numbers).
pub fn section5_geomeans(fig1_cells: &[Cell]) -> Vec<SummaryRow> {
    FIG_RUNTIMES
        .iter()
        .map(|rt| {
            let vals: Vec<f64> = fig1_cells
                .iter()
                .filter(|c| c.runtime == *rt)
                .map(|c| c.speedup)
                .collect();
            SummaryRow {
                runtime: rt.to_string(),
                value: geomean(vals),
                paper: paper_section5_geomean(rt),
            }
        })
        .collect()
}

/// §IV granularity table row: kernel, simulated solo µs, paper µs.
#[derive(Debug, Clone, PartialEq)]
pub struct GranularityRow {
    pub kernel: String,
    pub micros: f64,
    pub paper_micros: f64,
}

/// The §IV serial task-granularity table (simulated, calibrated).
pub fn granularity(cfg: &CoreConfig) -> Vec<GranularityRow> {
    Workload::all()
        .into_iter()
        .map(|w| {
            let t = w.trace(0, cfg);
            let cycles = super::workloads::solo_cycles(&t, cfg);
            GranularityRow {
                kernel: w.name.to_string(),
                micros: cycles as f64 / (cfg.freq_ghz * 1000.0),
                paper_micros: paper_task_micros(w.name),
            }
        })
        .collect()
}

/// One intra-kernel comparison row (wall-clock).
///
/// `pair_speedup` is the paper's protocol — two whole instances, one
/// per logical thread, against running both serially. It measures
/// *throughput* and needs two independent requests.
/// `parallel_for_speedup` is one instance with its hot loops
/// fork-joined, against one serial instance. It measures *latency* of a
/// single request — the scenario `coordinator` hits on odd batches.
#[derive(Debug, Clone, PartialEq)]
pub struct IntraRow {
    pub kernel: String,
    /// Mean serial single-instance time (ns).
    pub serial_ns: f64,
    pub pair_speedup: f64,
    pub parallel_for_speedup: f64,
}

/// The intra-kernel ablation: serial vs `pair` vs `parallel_for` for
/// every workload, on `relic` (pin the main thread and the assistant to
/// an SMT sibling pair first for meaningful numbers). The fork-join
/// loops run under `schedule` (`repro intra --schedule dynamic`
/// selects); also asserts the parallel checksums equal the serial ones
/// — the run doubles as an end-to-end determinism check per schedule.
pub fn intra_kernel(
    relic: &crate::relic::Relic,
    schedule: crate::relic::Schedule,
    iters: u64,
    warmup: u64,
) -> Vec<IntraRow> {
    use crate::relic::Par;
    use std::sync::atomic::{AtomicU64, Ordering};

    let par = Par::Relic(relic).with_schedule(schedule);
    let mut rows = Vec::new();
    for w in Workload::all() {
        let serial_sum = w.run_native();
        assert_eq!(
            w.run_native_par(&par),
            serial_sum,
            "{}: parallel checksum diverges from serial under {}",
            w.name,
            schedule.name()
        );
        let sink = AtomicU64::new(0);
        let task = || {
            sink.fetch_add(w.run_native(), Ordering::Relaxed);
        };
        // One serial instance (the parallel_for baseline).
        let serial1 = super::harness::measure(iters, warmup, || task());
        // Two serial instances (the pair baseline, paper protocol).
        let serial2 = super::harness::measure(iters, warmup, || {
            task();
            task();
        });
        let paired = super::harness::measure(iters, warmup, || relic.pair(&task, &task));
        let pfor = super::harness::measure(iters, warmup, || {
            sink.fetch_add(w.run_native_par(&par), Ordering::Relaxed);
        });
        std::hint::black_box(sink.load(Ordering::Relaxed));
        rows.push(IntraRow {
            kernel: w.name.to_string(),
            serial_ns: serial1.mean_ns,
            pair_speedup: serial2.mean_ns / paired.mean_ns,
            parallel_for_speedup: serial1.mean_ns / pfor.mean_ns,
        });
    }
    rows
}

/// One pool-scaling measurement: batch throughput at a shard count.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolScalingRow {
    pub shards: usize,
    pub requests: usize,
    /// Mean wall time to process the whole batch (ms).
    pub batch_ms: f64,
    /// Requests per second at that batch time.
    pub throughput_rps: f64,
    /// Batch-time speedup relative to the 1-shard row (or the first
    /// row measured when 1 is not in the sweep).
    pub speedup: f64,
    /// Admission backpressure stalls observed across the whole run.
    pub backpressure_stalls: u64,
}

/// The pool-scaling sweep: process the same mixed-kernel batch on the
/// paper graph through a [`crate::coordinator::Engine`] at each shard
/// count, verifying along the way that every response's checksum equals
/// the plain single-pair kernel's — the run doubles as the
/// pool-vs-single-pair equivalence check. `template` carries
/// pin/channel/batch knobs; its shard count is overridden per row.
///
/// Meaningful *scaling* numbers need one idle physical core per shard;
/// elsewhere the sweep still measures and still verifies checksums.
pub fn pool_scaling(
    template: &crate::coordinator::EngineConfig,
    shard_counts: &[usize],
    requests: usize,
    reps: u64,
) -> Vec<PoolScalingRow> {
    use crate::coordinator::{run_native_kernel, Deadline, Engine, Request, RequestResult};
    use crate::graph::kronecker::paper_graph;

    let graph = paper_graph();
    let plan = super::workloads::mixed_request_plan(requests);
    let expected: Vec<u64> = plan
        .iter()
        .map(|&(k, source)| run_native_kernel(k, &graph, source))
        .collect();

    let reps = reps.max(1);
    let mut rows = Vec::new();
    for &shards in shard_counts {
        let mut config = template.clone();
        config.pool.shards = Some(shards.max(1));
        let mut engine = Engine::new(config);
        let make_batch = || -> Vec<Request> {
            plan.iter()
                .enumerate()
                .map(|(i, &(kernel, source))| Request {
                    id: i as u64,
                    kernel,
                    graph: graph.clone(),
                    source,
                    deadline: Deadline::none(),
                })
                .collect()
        };
        // Untimed warmup rep: Engine::new returns while shard threads
        // are still pinning and building their Relic pairs; without
        // this the first timed rep absorbs that one-time startup cost
        // and skews the 1-shard baseline every speedup divides by.
        let warm = engine.process_batch(make_batch());
        assert_eq!(warm.len(), requests);
        let mut total_ns = 0u128;
        for _ in 0..reps {
            let batch = make_batch();
            let t0 = std::time::Instant::now();
            let responses = engine.process_batch(batch);
            total_ns += t0.elapsed().as_nanos();
            assert_eq!(responses.len(), requests);
            for (i, r) in responses.iter().enumerate() {
                assert_eq!(
                    r.result,
                    RequestResult::Native(expected[i]),
                    "pool checksum diverged from single-pair at shards={shards}, request {i}"
                );
            }
        }
        let batch_ms = total_ns as f64 / reps as f64 / 1e6;
        rows.push(PoolScalingRow {
            shards: shards.max(1),
            requests,
            batch_ms,
            throughput_rps: if batch_ms > 0.0 { requests as f64 / (batch_ms / 1e3) } else { 0.0 },
            speedup: 1.0,
            backpressure_stalls: engine.pool_snapshot().backpressure_stalls,
        });
    }
    let base_ms = rows
        .iter()
        .find(|r| r.shards == 1)
        .or_else(|| rows.first())
        .map(|r| r.batch_ms)
        .unwrap_or(0.0);
    for r in &mut rows {
        r.speedup = if r.batch_ms > 0.0 { base_ms / r.batch_ms } else { 0.0 };
    }
    rows
}

/// One admission-sweep measurement: one submit mode at one offered
/// load.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionRow {
    /// Submit flavor: `"blocking"`, `"try"` or `"park"`.
    pub mode: String,
    /// Requests offered per rep.
    pub offered: usize,
    pub reps: u64,
    /// Verdict counts across all reps.
    pub accepted: u64,
    /// `QueueFull` bounces (the open-loop `try` driver drops them).
    pub rejected: u64,
    pub shed: u64,
    /// Accepted submissions that had to park for channel capacity.
    pub parked: u64,
    /// Accepted requests that completed past their deadline.
    pub deadline_misses: u64,
    pub completed: u64,
    /// Mean wall time to offer + drain one rep (ms).
    pub batch_ms: f64,
    /// Completed requests per second.
    pub throughput_rps: f64,
    /// Whether this row ran with EDF batch ordering (and therefore the
    /// deadline spread and the FIFO baseline below).
    pub edf: bool,
    /// Deadline misses of the FIFO-baseline engine fed the identical
    /// request stream — only distinct from `deadline_misses` when `edf`
    /// is set; equal to it otherwise.
    pub fifo_misses: u64,
    /// Post-run measured service-time estimate (sample-weighted mean
    /// EMA across shards and kernel classes), in µs. 0 when
    /// measurement is off — the EMA-convergence column: stable values
    /// across loads mean the estimator has settled.
    pub ema_us: f64,
}

/// The three admission front doors the sweep compares.
pub const ADMISSION_MODES: [&str; 3] = ["blocking", "try", "park"];

/// The admission sweep: drive an open-loop burst of `offered` requests
/// through each submit flavor at each offered load, measuring verdicts
/// (accept / queue-full / shed), parks, deadline misses, and
/// completion throughput. `deadline` stamps every request (`None` =
/// deadline-less, nothing sheds); the template's `admission` section
/// picks the shed policy.
///
/// Built-in correctness gates (the sweep doubles as a smoke test):
/// every response's checksum must equal the single-pair kernel's, and
/// the verdicts must reconcile — `accepted + rejected + shed ==
/// offered × reps` and `completed == accepted`, i.e. nothing is ever
/// silently dropped, on any path.
///
/// With `template.admission.edf` set the sweep becomes the
/// **Routing-and-EDF protocol** (EXPERIMENTS.md): request deadlines are
/// spread over a fixed weight cycle (tight deadlines arriving *behind*
/// loose ones — the inversion EDF exists to fix; FIFO serves them in
/// arrival order and eats the misses), and every row additionally runs
/// a FIFO-baseline engine — identical config except `edf = false` — on
/// the identical request stream, reporting its misses in
/// [`AdmissionRow::fifo_misses`] so the EDF win is a column, not an
/// anecdote.
pub fn admission_sweep(
    template: &crate::coordinator::EngineConfig,
    offered_loads: &[usize],
    deadline: Option<std::time::Duration>,
    reps: u64,
) -> Vec<AdmissionRow> {
    use crate::coordinator::{
        run_native_kernel, Admission, Deadline, Engine, Request, RequestResult,
    };
    use crate::graph::kronecker::paper_graph;

    let graph = paper_graph();
    let max_load = offered_loads.iter().copied().max().unwrap_or(0);
    let plan = super::workloads::mixed_request_plan(max_load);
    let expected: Vec<u64> = plan
        .iter()
        .map(|&(k, source)| run_native_kernel(k, &graph, source))
        .collect();

    let edf = template.admission.edf;
    // Deadline-spread weights (quarters of the base deadline) for the
    // EDF protocol: 2×, ½×, 1×, ¼× — every fourth request is tight and
    // arrives behind a loose one.
    const SPREAD: [u32; 4] = [8, 2, 4, 1];

    let reps = reps.max(1);
    let mut rows = Vec::new();
    for &offered in offered_loads {
        for mode in ADMISSION_MODES {
            // A fresh engine per row keeps the verdict counters
            // attributable to exactly this (mode, load) cell.
            let mut engine = Engine::new(template.clone());
            let make_req = |i: usize| Request {
                id: i as u64,
                kernel: plan[i].0,
                graph: graph.clone(),
                source: plan[i].1,
                deadline: match deadline {
                    Some(d) if edf => Deadline::within(d * SPREAD[i % SPREAD.len()] / 4),
                    Some(d) => Deadline::within(d),
                    None => Deadline::none(),
                },
            };
            // Untimed deadline-less warmup: absorbs shard spawn/pin
            // cost without touching the verdict counters (deadline-less
            // requests are never shed).
            for i in 0..offered.min(8) {
                let _ = engine.submit(Request { deadline: Deadline::none(), ..make_req(i) });
            }
            engine.drain();
            let warm_metrics = engine.aggregated_metrics();
            let warm_completed = warm_metrics.native_requests.get();

            let mut rejected = 0u64;
            let mut completed = 0u64;
            let mut total_ns = 0u128;
            for _ in 0..reps {
                let t0 = std::time::Instant::now();
                for i in 0..offered {
                    let verdict = match mode {
                        "blocking" => engine.submit(make_req(i)),
                        "try" => engine.try_submit(make_req(i)),
                        "park" => engine.submit_or_park(make_req(i)),
                        _ => unreachable!(),
                    };
                    if let Admission::QueueFull { .. } = verdict {
                        rejected += 1;
                    }
                }
                let responses = engine.drain();
                total_ns += t0.elapsed().as_nanos();
                for r in &responses {
                    assert_eq!(
                        r.result,
                        RequestResult::Native(expected[r.id as usize]),
                        "admission sweep checksum diverged (mode {mode}, request {})",
                        r.id
                    );
                }
                completed += responses.len() as u64;
            }
            let agg = engine.aggregated_metrics();
            let shed = agg.admission.shed_requests.get();
            let accepted = (offered as u64 * reps) - rejected - shed;
            assert_eq!(
                completed,
                accepted,
                "mode {mode}, load {offered}: every accepted request must complete"
            );
            assert_eq!(
                agg.native_requests.get(),
                warm_completed + completed,
                "mode {mode}, load {offered}: served == completed (+ warmup)"
            );
            let deadline_misses = agg.admission.deadline_misses.get();
            // FIFO baseline for the EDF protocol: same config, same
            // stream, edf off — its misses are the row's comparison
            // column. Run after the timed loop so the timing columns
            // stay attributable to the EDF engine alone. Deadline-less
            // streams skip it: their miss count is 0 by definition.
            let fifo_misses = if edf && deadline.is_some() {
                let mut baseline_cfg = template.clone();
                baseline_cfg.admission.edf = false;
                let mut baseline = Engine::new(baseline_cfg);
                for i in 0..offered.min(8) {
                    let _ =
                        baseline.submit(Request { deadline: Deadline::none(), ..make_req(i) });
                }
                baseline.drain();
                for _ in 0..reps {
                    for i in 0..offered {
                        let _ = match mode {
                            "blocking" => baseline.submit(make_req(i)),
                            "try" => baseline.try_submit(make_req(i)),
                            "park" => baseline.submit_or_park(make_req(i)),
                            _ => unreachable!(),
                        };
                    }
                    baseline.drain();
                }
                baseline.aggregated_metrics().admission.deadline_misses.get()
            } else {
                deadline_misses
            };
            let batch_ms = total_ns as f64 / reps as f64 / 1e6;
            rows.push(AdmissionRow {
                mode: mode.to_string(),
                offered,
                reps,
                accepted,
                rejected,
                shed,
                parked: agg.admission.parked_submits.get(),
                deadline_misses,
                completed,
                batch_ms,
                throughput_rps: if total_ns > 0 {
                    completed as f64 / (total_ns as f64 / 1e9)
                } else {
                    0.0
                },
                edf,
                fifo_misses,
                ema_us: agg.service_estimator.mean_estimate_ns() as f64 / 1e3,
            });
        }
    }
    rows
}

/// Render the admission-sweep table. Every row carries the measured
/// mean service-time EMA column (`ema µs` — 0.0 with measurement off;
/// stable across loads once the estimator has converged); rows
/// produced under the EDF protocol additionally grow the FIFO
/// baseline's miss column (`fifo`) next to EDF's.
pub fn render_admission(rows: &[AdmissionRow]) -> String {
    let edf = rows.iter().any(|r| r.edf);
    let mut out = format!(
        "{:<10}{:>9}{:>10}{:>9}{:>7}{:>8}{:>8}",
        "mode", "offered", "accepted", "rejected", "shed", "parked", "misses"
    );
    if edf {
        out += &format!("{:>7}", "fifo");
    }
    out += &format!("{:>9}{:>11}{:>12}\n", "ema µs", "batch ms", "req/s");
    for r in rows {
        out += &format!(
            "{:<10}{:>9}{:>10}{:>9}{:>7}{:>8}{:>8}",
            r.mode, r.offered, r.accepted, r.rejected, r.shed, r.parked, r.deadline_misses,
        );
        if edf {
            out += &format!("{:>7}", r.fifo_misses);
        }
        out += &format!("{:>9.1}{:>11.3}{:>12.0}\n", r.ema_us, r.batch_ms, r.throughput_rps);
    }
    out += "(accepted + rejected + shed = offered; completed checksums verified \
            against the single-pair kernels)\n";
    if edf {
        out += "(edf protocol: spread deadlines; `misses` = EDF engine, `fifo` = \
                FIFO baseline on the identical stream)\n";
    }
    out
}

/// Serialize admission-sweep rows to JSON for the perf trajectory.
pub fn admission_rows_to_json(rows: &[AdmissionRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("mode".into(), Value::String(r.mode.clone())),
                ("offered".into(), Value::Number(r.offered as f64)),
                ("reps".into(), Value::Number(r.reps as f64)),
                ("accepted".into(), Value::Number(r.accepted as f64)),
                ("rejected".into(), Value::Number(r.rejected as f64)),
                ("shed".into(), Value::Number(r.shed as f64)),
                ("parked".into(), Value::Number(r.parked as f64)),
                (
                    "deadline_misses".into(),
                    Value::Number(r.deadline_misses as f64),
                ),
                ("completed".into(), Value::Number(r.completed as f64)),
                ("batch_ms".into(), Value::Number(r.batch_ms)),
                ("throughput_rps".into(), Value::Number(r.throughput_rps)),
                ("edf".into(), Value::Bool(r.edf)),
                ("fifo_misses".into(), Value::Number(r.fifo_misses as f64)),
                ("ema_us".into(), Value::Number(r.ema_us)),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// One fault-recovery scenario: a fixed scripted failure injected into
/// a fresh supervised engine fed a deterministic request stream, with
/// the recovery counters as columns.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRow {
    /// Scenario name (see [`FAULT_SCENARIOS`]).
    pub scenario: String,
    /// Requests offered — all admitted (blocking, deadline-less).
    pub offered: usize,
    /// Responses that completed with a verified checksum.
    pub ok: u64,
    /// Responses carrying a typed `Failed` result.
    pub failed: u64,
    /// Kernel panics caught and contained.
    pub panics: u64,
    /// Watchdog quarantine trips (stuck/dead shards).
    pub trips: u64,
    /// Dead shards respawned.
    pub restarts: u64,
    /// Quarantined shards' queued requests re-routed to survivors.
    pub redirected: u64,
    /// Requests served inline because every shard was quarantined.
    pub degraded: u64,
    /// Lost responses synthesized as `Failed(ResponseLost)`.
    pub lost: u64,
    /// Wall time to offer + drain the stream (ms) — the degraded /
    /// recovering throughput column.
    pub batch_ms: f64,
}

/// The scripted failures the fault sweep drills, one engine each.
pub const FAULT_SCENARIOS: [&str; 6] = ["baseline", "panic", "stall", "kill", "drop", "all-down"];

/// The fault-recovery sweep (EXPERIMENTS.md §Fault-recovery protocol):
/// for each [`FAULT_SCENARIOS`] entry, build a fresh engine with the
/// supervisor forced on, arm exactly one scripted failure, drive the
/// same deterministic mixed request stream through blocking submits,
/// and drain.
///
/// Built-in gates (the sweep doubles as the CI fault smoke, failing
/// loudly when a recovery path breaks):
/// * **no-drop invariant** — every scenario returns exactly one
///   response per submitted request;
/// * surviving (non-`Failed`) checksums equal the single-pair
///   kernels';
/// * per-scenario recovery counters fired: `panic` catches exactly one
///   panic and fails exactly that request; `stall` trips the watchdog
///   and still completes everything; `kill` respawns the dead shard
///   and completes everything; `drop` synthesizes exactly one
///   `ResponseLost`; `all-down` serves every request inline; and
///   `baseline` keeps every recovery counter at zero.
///
/// Only the stall scenario runs a tight (40 ms) watchdog — it must
/// out-pace the scripted 200 ms stall. Every other scenario keeps a
/// lax stuck-after so a legitimately slow batch (the heartbeat bumps
/// once per batch, *before* the handler runs) can never read as a
/// spurious `Stuck` and dirty the baseline's counters. The template's
/// other knobs — shard count, pinning, channel depth — are honored as
/// given.
pub fn fault_sweep(template: &crate::coordinator::EngineConfig, offered: usize) -> Vec<FaultRow> {
    use crate::coordinator::{
        run_native_kernel, Deadline, Engine, GraphKernel, Request, RequestResult,
    };
    use crate::graph::kronecker::paper_graph;
    use crate::relic::FaultPlan;
    use std::sync::Arc;
    use std::time::Duration;

    let graph = paper_graph();
    // Enough requests that every kernel kind (the panic target
    // included) appears in the stream and every shard sees work.
    let offered = offered.max(12);
    let plan = super::workloads::mixed_request_plan(offered);
    let expected: Vec<u64> =
        plan.iter().map(|&(k, s)| run_native_kernel(k, &graph, s)).collect();
    let tight = Duration::from_millis(40);
    let lax = Duration::from_secs(2);
    let target = GraphKernel::Tc.artifact_name();
    let scenarios: [(&str, Option<FaultPlan>, Duration); 6] = [
        ("baseline", None, lax),
        ("panic", Some(FaultPlan::new().with_panic_on(target, 1)), lax),
        ("stall", Some(FaultPlan::new().with_stall(0, 1, tight * 5)), tight),
        ("kill", Some(FaultPlan::new().with_kill(0, 1)), lax),
        ("drop", Some(FaultPlan::new().with_drop_response(0, 1)), lax),
        ("all-down", None, lax),
    ];

    let mut rows = Vec::new();
    for (name, fault, stuck_after) in scenarios {
        let mut cfg = template.clone();
        cfg.supervisor.enabled = true;
        cfg.supervisor.stuck_after = stuck_after;
        cfg.pool.fault = fault.map(Arc::new);
        let mut engine = Engine::new(cfg);
        if name == "all-down" {
            for s in 0..engine.shard_count() {
                engine.set_quarantined(s, true);
            }
        }
        let t0 = std::time::Instant::now();
        for (i, &(kernel, source)) in plan.iter().enumerate() {
            let verdict = engine.submit(Request {
                id: i as u64,
                kernel,
                graph: graph.clone(),
                source,
                deadline: Deadline::none(),
            });
            assert!(
                verdict.is_accepted(),
                "{name}: blocking deadline-less submits always admit"
            );
        }
        let responses = engine.drain();
        let batch_ms = t0.elapsed().as_nanos() as f64 / 1e6;
        assert_eq!(
            responses.len(),
            offered,
            "{name}: the no-drop invariant — one response per submitted request"
        );
        let mut ok = 0u64;
        let mut failed = 0u64;
        for r in &responses {
            if r.result.is_ok() {
                assert_eq!(
                    r.result,
                    RequestResult::Native(expected[r.id as usize]),
                    "{name}: surviving checksum diverged (request {})",
                    r.id
                );
                ok += 1;
            } else {
                failed += 1;
            }
        }
        let agg = engine.aggregated_metrics();
        let row = FaultRow {
            scenario: name.to_string(),
            offered,
            ok,
            failed,
            panics: agg.fault.panics_caught.get(),
            trips: agg.fault.watchdog_trips.get(),
            restarts: agg.fault.shard_restarts.get(),
            redirected: agg.fault.redirected_requests.get(),
            degraded: agg.fault.degraded_requests.get(),
            lost: agg.fault.responses_lost.get(),
            batch_ms,
        };
        match name {
            "baseline" => {
                assert_eq!(row.failed, 0, "baseline fails nothing");
                assert!(agg.fault.is_quiet(), "baseline recovery counters stay zero");
            }
            "panic" => {
                assert_eq!(row.panics, 1, "exactly one injected panic is caught");
                assert_eq!(row.failed, 1, "exactly the panicking request fails typed");
            }
            "stall" => {
                assert!(row.trips >= 1, "the watchdog quarantines the stalled shard");
                assert_eq!(row.failed, 0, "stall recovery completes everything");
            }
            "kill" => {
                assert!(row.restarts >= 1, "the dead shard is respawned");
                assert_eq!(row.failed, 0, "kill recovery completes everything");
            }
            "drop" => {
                assert_eq!(row.lost, 1, "the dropped response synthesizes as lost");
                assert_eq!(row.failed, 1, "exactly the lost request fails typed");
            }
            "all-down" => {
                assert_eq!(row.degraded, offered as u64, "all-down serves inline");
                assert_eq!(row.failed, 0, "degraded mode fails nothing");
            }
            _ => unreachable!(),
        }
        rows.push(row);
    }
    rows
}

/// Render the fault-sweep table with its gate legend.
pub fn render_faults(rows: &[FaultRow]) -> String {
    let mut out = format!(
        "{:<10}{:>9}{:>6}{:>8}{:>8}{:>7}{:>10}{:>12}{:>10}{:>6}{:>11}\n",
        "scenario", "offered", "ok", "failed", "panics", "trips", "restarts", "redirected",
        "degraded", "lost", "batch ms"
    );
    for r in rows {
        out += &format!(
            "{:<10}{:>9}{:>6}{:>8}{:>8}{:>7}{:>10}{:>12}{:>10}{:>6}{:>11.1}\n",
            r.scenario,
            r.offered,
            r.ok,
            r.failed,
            r.panics,
            r.trips,
            r.restarts,
            r.redirected,
            r.degraded,
            r.lost,
            r.batch_ms,
        );
    }
    out += "(gates passed: one response per submitted request in every scenario; \
            surviving checksums verified; each scenario's recovery counters fired)\n";
    out
}

/// Serialize fault-sweep rows to JSON for the recovery trajectory.
pub fn fault_rows_to_json(rows: &[FaultRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("scenario".into(), Value::String(r.scenario.clone())),
                ("offered".into(), Value::Number(r.offered as f64)),
                ("ok".into(), Value::Number(r.ok as f64)),
                ("failed".into(), Value::Number(r.failed as f64)),
                ("panics".into(), Value::Number(r.panics as f64)),
                ("trips".into(), Value::Number(r.trips as f64)),
                ("restarts".into(), Value::Number(r.restarts as f64)),
                ("redirected".into(), Value::Number(r.redirected as f64)),
                ("degraded".into(), Value::Number(r.degraded as f64)),
                ("lost".into(), Value::Number(r.lost as f64)),
                ("batch_ms".into(), Value::Number(r.batch_ms)),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// One chaos-soak round: a seeded random multi-fault schedule against
/// a fresh supervised engine, with the recovery and replay counters as
/// columns and the built-in gates already asserted.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosRow {
    /// The soak seed (rounds derive their own sub-seeds from it).
    pub seed: u64,
    /// Round index within the soak.
    pub round: usize,
    /// Injections armed this round, e.g. `"panic+kill+drop"`.
    pub faults: String,
    /// Whether at-least-once replay was on.
    pub replay: bool,
    /// Requests offered — all admitted (blocking, deadline-less).
    pub offered: usize,
    /// Responses that completed with a verified checksum.
    pub ok: u64,
    /// Responses carrying a typed `Failed` result.
    pub failed: u64,
    /// Kernel panics caught and contained.
    pub panics: u64,
    /// Watchdog quarantine trips.
    pub trips: u64,
    /// Dead shards respawned.
    pub restarts: u64,
    /// Quarantined shards' queued requests re-routed to survivors.
    pub redirected: u64,
    /// Lost responses synthesized as `Failed(ResponseLost)`.
    pub lost: u64,
    /// Replay attempts launched.
    pub replays: u64,
    /// Requests recovered by replay.
    pub replay_successes: u64,
    /// Requests whose replay budget ran out.
    pub gave_up: u64,
    /// `ok / offered` — the soak's higher-is-better headline (1.0 =
    /// every request survived the fault schedule with a correct
    /// checksum).
    pub recovered_ratio: f64,
    /// Wall time to offer + drain the stream (ms).
    pub batch_ms: f64,
}

/// The deterministic chaos soak (EXPERIMENTS.md §Chaos-soak protocol):
/// each round derives a fault schedule from `(seed, round)` — a random
/// subset of {panic, stall, kill, drop} with randomized targets and
/// trigger points — arms it on a fresh supervised engine, drives the
/// deterministic mixed request stream through blocking submits, and
/// drains. The *schedule* is a pure function of the seed; thread
/// interleaving is not, so every gate is an invariant, not a trace.
///
/// Built-in gates (assertion failures, so `repro chaos` and the CI
/// smoke fail loudly):
/// * **no-drop** — exactly one response per submitted request, every
///   round;
/// * **checksum-equal-to-serial** — every surviving (non-`Failed`)
///   result equals the serial kernel's checksum;
/// * **books reconcile** — with replay on, every terminal failure is a
///   resolved give-up or deadline shed (`failed == gave_up +
///   replay_sheds`), and since these one-shot faults cannot outlast the
///   attempt budget, every caught panic and synthesized loss is
///   recovered (`failed == 0`, `replay_successes == panics + lost`).
///   With replay off, the reliability counters stay silent and every
///   caught panic / synthesized loss surfaces typed
///   (`failed == panics + lost`).
///
/// The shard count is taken from the template (`None` = 2 — the soak
/// needs a concrete count to aim shard-targeted faults). A tight
/// (40 ms) watchdog is used only on rounds that arm a stall, exactly
/// as in [`fault_sweep`].
pub fn chaos_soak(
    template: &crate::coordinator::EngineConfig,
    seed: u64,
    rounds: usize,
    offered: usize,
    replay: bool,
) -> Vec<ChaosRow> {
    use crate::coordinator::{
        run_native_kernel, Deadline, Engine, GraphKernel, Request, RequestResult,
    };
    use crate::graph::kronecker::paper_graph;
    use crate::relic::FaultPlan;
    use crate::testutil::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    let graph = paper_graph();
    // Enough requests that every kernel appears several times (panic
    // trigger points are per-kernel) and every shard sees work.
    let offered = offered.max(24);
    let plan = super::workloads::mixed_request_plan(offered);
    let expected: Vec<u64> =
        plan.iter().map(|&(k, s)| run_native_kernel(k, &graph, s)).collect();
    let shards = template.pool.shards.unwrap_or(2).max(1);
    let tight = Duration::from_millis(40);
    let lax = Duration::from_secs(2);
    let kernels = GraphKernel::all();

    let mut rows = Vec::new();
    for round in 0..rounds.max(1) {
        // Sub-seed: decorrelate rounds while keeping the whole soak a
        // pure function of `seed`.
        let mut rng = Rng::new(seed ^ ((round as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F)));
        let mut fault = FaultPlan::new();
        let mut armed: Vec<&str> = Vec::new();
        if rng.below(2) == 0 {
            let kernel = kernels[rng.below(kernels.len() as u64) as usize];
            let per_kernel = (offered / kernels.len()).max(1) as u64;
            fault = fault.with_panic_on(kernel.artifact_name(), 1 + rng.below(per_kernel));
            armed.push("panic");
        }
        let stall_armed = rng.below(2) == 0;
        if stall_armed {
            let shard = rng.below(shards as u64) as usize;
            fault = fault.with_stall(shard, 1 + rng.below(2), tight * 5);
            armed.push("stall");
        }
        if rng.below(2) == 0 {
            fault = fault.with_kill(rng.below(shards as u64) as usize, 1 + rng.below(2));
            armed.push("kill");
        }
        // Always leave at least one injection armed; the drop is the
        // one the replay layer has the most to say about.
        if rng.below(2) == 0 || armed.is_empty() {
            fault = fault.with_drop_response(rng.below(shards as u64) as usize, 1 + rng.below(2));
            armed.push("drop");
        }
        let faults = armed.join("+");

        let mut cfg = template.clone();
        cfg.pool.shards = Some(shards);
        cfg.supervisor.enabled = true;
        cfg.supervisor.stuck_after = if stall_armed { tight } else { lax };
        cfg.pool.fault = Some(Arc::new(fault));
        cfg.reliability.replay = replay;
        let mut engine = Engine::new(cfg);

        let t0 = std::time::Instant::now();
        for (i, &(kernel, source)) in plan.iter().enumerate() {
            let verdict = engine.submit(Request {
                id: i as u64,
                kernel,
                graph: graph.clone(),
                source,
                deadline: Deadline::none(),
            });
            assert!(
                verdict.is_accepted(),
                "chaos[{seed}/{round}]: blocking deadline-less submits always admit"
            );
        }
        let responses = engine.drain();
        let batch_ms = t0.elapsed().as_nanos() as f64 / 1e6;
        assert_eq!(
            responses.len(),
            offered,
            "chaos[{seed}/{round}] ({faults}): the no-drop invariant — one response per \
             submitted request"
        );
        let mut ok = 0u64;
        let mut failed = 0u64;
        for r in &responses {
            if r.result.is_ok() {
                assert_eq!(
                    r.result,
                    RequestResult::Native(expected[r.id as usize]),
                    "chaos[{seed}/{round}] ({faults}): surviving checksum diverged (request {})",
                    r.id
                );
                ok += 1;
            } else {
                failed += 1;
            }
        }
        let agg = engine.aggregated_metrics();
        let panics = agg.fault.panics_caught.get();
        let lost = agg.fault.responses_lost.get();
        if replay {
            assert_eq!(
                failed,
                agg.reliability.gave_up.get() + agg.reliability.replay_sheds.get(),
                "chaos[{seed}/{round}] ({faults}): the replay books reconcile — every \
                 terminal failure is a resolved give-up or deadline shed"
            );
            assert_eq!(
                failed, 0,
                "chaos[{seed}/{round}] ({faults}): one-shot faults within the attempt \
                 budget always recover"
            );
            assert_eq!(
                agg.reliability.replay_successes.get(),
                panics + lost,
                "chaos[{seed}/{round}] ({faults}): every caught panic and synthesized \
                 loss was recovered by replay"
            );
        } else {
            assert!(
                agg.reliability.is_quiet(),
                "chaos[{seed}/{round}] ({faults}): replay off keeps the reliability \
                 counters silent"
            );
            assert_eq!(
                failed,
                panics + lost,
                "chaos[{seed}/{round}] ({faults}): with replay off every caught panic \
                 and synthesized loss surfaces typed"
            );
        }
        rows.push(ChaosRow {
            seed,
            round,
            faults,
            replay,
            offered,
            ok,
            failed,
            panics,
            trips: agg.fault.watchdog_trips.get(),
            restarts: agg.fault.shard_restarts.get(),
            redirected: agg.fault.redirected_requests.get(),
            lost,
            replays: agg.reliability.replays.get(),
            replay_successes: agg.reliability.replay_successes.get(),
            gave_up: agg.reliability.gave_up.get(),
            recovered_ratio: ok as f64 / offered as f64,
            batch_ms,
        });
    }
    rows
}

/// Render the chaos-soak table with its gate legend.
pub fn render_chaos(rows: &[ChaosRow]) -> String {
    let mut out = format!(
        "{:<6}{:<22}{:>9}{:>6}{:>8}{:>8}{:>7}{:>10}{:>6}{:>9}{:>11}{:>9}{:>11}\n",
        "round", "faults", "offered", "ok", "failed", "panics", "trips", "restarts", "lost",
        "replays", "recovered", "gave-up", "batch ms"
    );
    for r in rows {
        out += &format!(
            "{:<6}{:<22}{:>9}{:>6}{:>8}{:>8}{:>7}{:>10}{:>6}{:>9}{:>11}{:>9}{:>11.1}\n",
            r.round,
            r.faults,
            r.offered,
            r.ok,
            r.failed,
            r.panics,
            r.trips,
            r.restarts,
            r.lost,
            r.replays,
            r.replay_successes,
            r.gave_up,
            r.batch_ms,
        );
    }
    out += "(gates passed: one response per submitted request in every round; surviving \
            checksums equal the serial kernels'; the replay books reconcile)\n";
    out
}

/// Serialize chaos-soak rows to JSON (the nightly bench workflow
/// archives these as the HA trajectory).
pub fn chaos_rows_to_json(rows: &[ChaosRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("seed".into(), Value::Number(r.seed as f64)),
                ("round".into(), Value::Number(r.round as f64)),
                ("faults".into(), Value::String(r.faults.clone())),
                ("replay".into(), Value::Bool(r.replay)),
                ("offered".into(), Value::Number(r.offered as f64)),
                ("ok".into(), Value::Number(r.ok as f64)),
                ("failed".into(), Value::Number(r.failed as f64)),
                ("panics".into(), Value::Number(r.panics as f64)),
                ("trips".into(), Value::Number(r.trips as f64)),
                ("restarts".into(), Value::Number(r.restarts as f64)),
                ("redirected".into(), Value::Number(r.redirected as f64)),
                ("lost".into(), Value::Number(r.lost as f64)),
                ("replays".into(), Value::Number(r.replays as f64)),
                (
                    "replay_successes".into(),
                    Value::Number(r.replay_successes as f64),
                ),
                ("gave_up".into(), Value::Number(r.gave_up as f64)),
                (
                    "recovered_ratio".into(),
                    Value::Number(r.recovered_ratio),
                ),
                ("batch_ms".into(), Value::Number(r.batch_ms)),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// Serialize intra-kernel rows to JSON (the nightly bench workflow
/// archives these as the fork-join perf trajectory).
pub fn intra_rows_to_json(rows: &[IntraRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("kernel".into(), Value::String(r.kernel.clone())),
                ("serial_ns".into(), Value::Number(r.serial_ns)),
                ("pair_speedup".into(), Value::Number(r.pair_speedup)),
                (
                    "parallel_for_speedup".into(),
                    Value::Number(r.parallel_for_speedup),
                ),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// Render the pool-scaling table.
pub fn render_pool_scaling(rows: &[PoolScalingRow]) -> String {
    let mut out = format!(
        "{:<8}{:>10}{:>12}{:>14}{:>10}{:>10}\n",
        "shards", "requests", "batch ms", "req/s", "speedup", "stalls"
    );
    for r in rows {
        out += &format!(
            "{:<8}{:>10}{:>12.3}{:>14.0}{:>9.3}x{:>10}\n",
            r.shards, r.requests, r.batch_ms, r.throughput_rps, r.speedup, r.backpressure_stalls
        );
    }
    out += "(speedup = batch time vs the 1-shard row; \
            checksums verified against the single-pair kernels)\n";
    out
}

/// Serialize pool-scaling rows to JSON for plotting.
pub fn pool_rows_to_json(rows: &[PoolScalingRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("shards".into(), Value::Number(r.shards as f64)),
                ("requests".into(), Value::Number(r.requests as f64)),
                ("batch_ms".into(), Value::Number(r.batch_ms)),
                ("throughput_rps".into(), Value::Number(r.throughput_rps)),
                ("speedup".into(), Value::Number(r.speedup)),
                (
                    "backpressure_stalls".into(),
                    Value::Number(r.backpressure_stalls as f64),
                ),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// One whale-scaling measurement: one oversized request at one borrow
/// cap (see `EXPERIMENTS.md` §Whale-scaling protocol).
#[derive(Debug, Clone, PartialEq)]
pub struct WhaleRow {
    pub kernel: String,
    pub shards: usize,
    pub max_borrow: usize,
    /// Mean serial single-instance time (ns).
    pub serial_ns: f64,
    /// Mean single-pair fork-join time (ns) — the 2-thread ceiling a
    /// borrowing engine has to beat.
    pub pair_ns: f64,
    /// Mean engine latency of the whale request (ns), submit to drain.
    pub engine_ns: f64,
    pub speedup_vs_serial: f64,
    pub speedup_vs_pair: f64,
    /// Whether every engine response matched the serial checksum. The
    /// sweep also *asserts* this, so a false value never reaches the
    /// output — the field keeps the gate visible in the archived JSON.
    pub checksum_ok: bool,
}

/// The whale-scaling sweep: one big request per rep through an engine
/// at each borrow cap, against two baselines measured on the calling
/// thread — serial, and single-pair fork-join (the 2-thread ceiling).
/// `max_borrow = 0` rows are the degeneracy anchor (no broker at all);
/// higher caps let the request borrow idle shards, so on an otherwise
/// idle ≥2-shard SMT host `speedup_vs_pair > 1` is the tentpole claim.
/// Every engine response is asserted bitwise equal to the serial
/// checksum — the sweep doubles as the cross-shard determinism gate.
pub fn whale_sweep(
    template: &crate::coordinator::EngineConfig,
    shards: usize,
    max_borrows: &[usize],
    scale: u32,
    reps: u64,
) -> Vec<WhaleRow> {
    use crate::coordinator::{
        run_native_kernel, run_native_kernel_par, Deadline, Engine, GraphKernel, Request,
        RequestResult,
    };
    use crate::graph::kronecker::{kronecker_graph, KroneckerParams, PAPER_SEED};
    use crate::relic::{Par, Relic};

    let graph = kronecker_graph(&KroneckerParams::gap(scale, 16, PAPER_SEED));
    let reps = reps.max(1);
    // PageRank and BC: the two kernels whose hot loops are wide and
    // regular enough for a whale to profit from extra pair-shards.
    let kernels = [GraphKernel::Pr, GraphKernel::Bc];
    let mut rows = Vec::new();
    for kernel in kernels {
        let expected = run_native_kernel(kernel, &graph, 0);
        let mut serial_total = 0u128;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            assert_eq!(run_native_kernel(kernel, &graph, 0), expected);
            serial_total += t0.elapsed().as_nanos();
        }
        let serial_ns = serial_total as f64 / reps as f64;
        let relic = Relic::new();
        let par = Par::Relic(&relic);
        // Untimed warmup doubles as the pair-path checksum gate.
        assert_eq!(run_native_kernel_par(kernel, &graph, 0, &par), expected);
        let mut pair_total = 0u128;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            assert_eq!(run_native_kernel_par(kernel, &graph, 0, &par), expected);
            pair_total += t0.elapsed().as_nanos();
        }
        let pair_ns = pair_total as f64 / reps as f64;
        drop(relic);
        for &max_borrow in max_borrows {
            let mut config = template.clone();
            config.pool.shards = Some(shards.max(1));
            config.max_borrow = max_borrow;
            let mut engine = Engine::new(config);
            let make_req = |id: u64| Request {
                id,
                kernel,
                graph: graph.clone(),
                source: 0,
                deadline: Deadline::none(),
            };
            // Untimed warmup rep (shard spawn, pinning, first-touch).
            let warm = engine.process_batch(vec![make_req(0)]);
            assert_eq!(warm.len(), 1);
            let mut engine_total = 0u128;
            for rep in 0..reps {
                let t0 = std::time::Instant::now();
                let responses = engine.process_batch(vec![make_req(rep + 1)]);
                engine_total += t0.elapsed().as_nanos();
                assert_eq!(responses.len(), 1);
                assert_eq!(
                    responses[0].result,
                    RequestResult::Native(expected),
                    "whale checksum diverged: kernel={kernel:?} max_borrow={max_borrow}"
                );
            }
            let engine_ns = engine_total as f64 / reps as f64;
            rows.push(WhaleRow {
                kernel: kernel.artifact_name().to_string(),
                shards: shards.max(1),
                max_borrow,
                serial_ns,
                pair_ns,
                engine_ns,
                speedup_vs_serial: if engine_ns > 0.0 { serial_ns / engine_ns } else { 0.0 },
                speedup_vs_pair: if engine_ns > 0.0 { pair_ns / engine_ns } else { 0.0 },
                checksum_ok: true,
            });
        }
    }
    rows
}

/// Render the whale-scaling table.
pub fn render_whale(rows: &[WhaleRow]) -> String {
    let mut out = format!(
        "{:<8}{:>8}{:>8}{:>12}{:>12}{:>12}{:>11}{:>9}\n",
        "kernel", "shards", "borrow", "serial ms", "pair ms", "engine ms", "vs serial", "vs pair"
    );
    for r in rows {
        out += &format!(
            "{:<8}{:>8}{:>8}{:>12.3}{:>12.3}{:>12.3}{:>10.3}x{:>8.3}x\n",
            r.kernel,
            r.shards,
            r.max_borrow,
            r.serial_ns / 1e6,
            r.pair_ns / 1e6,
            r.engine_ns / 1e6,
            r.speedup_vs_serial,
            r.speedup_vs_pair,
        );
    }
    out += "(vs pair > 1 at borrow > 0 = the whale beat the 2-thread single-pair ceiling; \
            checksums asserted bitwise against serial)\n";
    out
}

/// Serialize whale-scaling rows to JSON for the nightly trend diff.
pub fn whale_rows_to_json(rows: &[WhaleRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("kernel".into(), Value::String(r.kernel.clone())),
                ("shards".into(), Value::Number(r.shards as f64)),
                ("max_borrow".into(), Value::Number(r.max_borrow as f64)),
                ("serial_ns".into(), Value::Number(r.serial_ns)),
                ("pair_ns".into(), Value::Number(r.pair_ns)),
                ("engine_ns".into(), Value::Number(r.engine_ns)),
                ("speedup_vs_serial".into(), Value::Number(r.speedup_vs_serial)),
                ("speedup_vs_pair".into(), Value::Number(r.speedup_vs_pair)),
                ("checksum_ok".into(), Value::Bool(r.checksum_ok)),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// One plan-ablation measurement: a mixed-kernel workload served under
/// one plan source (see `EXPERIMENTS.md` §Plan-ablation protocol).
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRow {
    /// Plan source: `baseline` (no plan machinery), a forced plan spec,
    /// or `tuner`.
    pub config: String,
    /// Requests per round.
    pub requests: usize,
    /// Mean wall time per timed round (ms).
    pub mean_batch_ms: f64,
    /// Mean per-request completion latency (µs) across timed rounds.
    pub mean_req_us: f64,
    /// Mean batch time vs the `baseline` row.
    pub speedup_vs_baseline: f64,
    /// Whether every response matched the serial checksum. Asserted
    /// inside the sweep, so a false value never reaches the output —
    /// the field keeps the gate visible in the archived JSON.
    pub checksum_ok: bool,
    /// The tuner's resolved per-(kernel, shape) assignment after the
    /// run (`tuner` row only; empty for static rows). Non-uniform
    /// entries here are the ablation's headline observation.
    pub resolved: String,
}

/// The plan-ablation sweep: one engine per plan source — the pre-plan
/// baseline, each forced static plan, and the online tuner — all
/// serving identical mixed-kernel rounds on the same graph. The tuner
/// engine first runs untimed warm rounds so epsilon-greedy's forced
/// exploration sweeps the lattice before measurement. Every response is
/// asserted bitwise equal to the serial checksum: plans and tuning
/// change *assignment*, never results.
pub fn plan_sweep(
    template: &crate::coordinator::EngineConfig,
    shards: usize,
    scale: u32,
    reps: u64,
) -> Vec<PlanRow> {
    use crate::coordinator::{
        run_native_kernel, Deadline, Engine, GraphKernel, Request, RequestResult, TunerConfig,
    };
    use crate::graph::kronecker::{kronecker_graph, KroneckerParams, PAPER_SEED};
    use crate::relic::{ExecutionPlan, Schedule};

    let graph = kronecker_graph(&KroneckerParams::gap(scale, 16, PAPER_SEED));
    let reps = reps.max(1);
    let expected: Vec<(GraphKernel, u64)> = GraphKernel::all()
        .into_iter()
        .map(|k| (k, run_native_kernel(k, &graph, 0)))
        .collect();
    // Two requests per kernel per round: every round has pairing
    // partners available for serial-planned arms.
    let per_round = 2 * expected.len();
    let tuner_cfg = template.tuner.unwrap_or_default();
    // Enough untimed rounds (one settle tick each) for forced
    // exploration to give every arm its quota before measurement.
    let warm_rounds =
        (ExecutionPlan::lattice().len() as u64 * tuner_cfg.min_samples.max(1) + 10) as usize;

    let configs: Vec<(String, Option<ExecutionPlan>, Option<TunerConfig>)> = vec![
        ("baseline".into(), None, None),
        ("serial".into(), Some(ExecutionPlan::serial()), None),
        ("pair:static".into(), Some(ExecutionPlan::pair(Schedule::Static)), None),
        ("pair:dynamic".into(), Some(ExecutionPlan::pair(Schedule::Dynamic)), None),
        (
            "pair:edge-balanced".into(),
            Some(ExecutionPlan::pair(Schedule::EdgeBalanced)),
            None,
        ),
        ("tuner".into(), None, Some(tuner_cfg)),
    ];

    let mut rows: Vec<PlanRow> = Vec::new();
    let mut baseline_ms = 0.0f64;
    for (name, plan, tuner) in configs {
        let mut config = template.clone();
        config.pool.shards = Some(shards.max(1));
        config.plan = plan;
        config.tuner = tuner;
        let mut engine = Engine::new(config);
        let make_round = |round: u64| -> Vec<Request> {
            (0..per_round)
                .map(|i| Request {
                    id: round * per_round as u64 + i as u64,
                    kernel: expected[i % expected.len()].0,
                    graph: graph.clone(),
                    source: 0,
                    deadline: Deadline::none(),
                })
                .collect()
        };
        let check = |responses: &[crate::coordinator::Response]| {
            assert_eq!(responses.len(), per_round, "{name}: lost responses");
            for (i, r) in responses.iter().enumerate() {
                let (kernel, want) = expected[i % expected.len()];
                assert_eq!(
                    r.result,
                    RequestResult::Native(want),
                    "{name}: {kernel:?} checksum diverged from serial"
                );
            }
        };
        // Warm rounds: shard spawn + first-touch for everyone; lattice
        // exploration for the tuner. Checksums are gated here too —
        // exploration must never be visible in results.
        let warm = if tuner.is_some() { warm_rounds } else { 1 };
        for round in 0..warm {
            check(&engine.process_batch(make_round(round as u64)));
        }
        let mut batch_total = 0u128;
        let mut latency_total = 0u128;
        for rep in 0..reps {
            let t0 = std::time::Instant::now();
            let responses = engine.process_batch(make_round(warm as u64 + rep));
            batch_total += t0.elapsed().as_nanos();
            check(&responses);
            latency_total += responses.iter().map(|r| r.latency_ns as u128).sum::<u128>();
        }
        let mean_batch_ms = batch_total as f64 / reps as f64 / 1e6;
        let mean_req_us =
            latency_total as f64 / (reps as u128 * per_round as u128) as f64 / 1e3;
        if name == "baseline" {
            baseline_ms = mean_batch_ms;
        }
        let resolved = engine
            .tuner()
            .map(|t| {
                t.resolved()
                    .iter()
                    .map(|r| {
                        format!(
                            "{}[{}]={}",
                            r.kernel.artifact_name(),
                            crate::coordinator::tuner::shape_name(r.shape),
                            r.plan
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .unwrap_or_default();
        rows.push(PlanRow {
            config: name,
            requests: per_round,
            mean_batch_ms,
            mean_req_us,
            speedup_vs_baseline: if mean_batch_ms > 0.0 {
                baseline_ms / mean_batch_ms
            } else {
                0.0
            },
            checksum_ok: true,
            resolved,
        });
    }
    rows
}

/// Render the plan-ablation table.
pub fn render_plan(rows: &[PlanRow]) -> String {
    let mut out = format!(
        "{:<20}{:>10}{:>12}{:>12}{:>13}\n",
        "plan source", "requests", "batch ms", "req µs", "vs baseline"
    );
    for r in rows {
        out += &format!(
            "{:<20}{:>10}{:>12.3}{:>12.1}{:>12.3}x\n",
            r.config, r.requests, r.mean_batch_ms, r.mean_req_us, r.speedup_vs_baseline
        );
    }
    for r in rows.iter().filter(|r| !r.resolved.is_empty()) {
        out += &format!("resolved ({}): {}\n", r.config, r.resolved);
    }
    out += "(baseline = pre-plan pairing path; every response asserted bitwise \
            equal to the serial checksum under every plan source)\n";
    out
}

/// Serialize plan-ablation rows to JSON for the nightly trend diff.
pub fn plan_rows_to_json(rows: &[PlanRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("config".into(), Value::String(r.config.clone())),
                ("requests".into(), Value::Number(r.requests as f64)),
                ("mean_batch_ms".into(), Value::Number(r.mean_batch_ms)),
                ("mean_req_us".into(), Value::Number(r.mean_req_us)),
                (
                    "speedup_vs_baseline".into(),
                    Value::Number(r.speedup_vs_baseline),
                ),
                ("checksum_ok".into(), Value::Bool(r.checksum_ok)),
                ("resolved".into(), Value::String(r.resolved.clone())),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// One streaming-pipeline scenario measurement (`repro stream`,
/// `stream.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamRow {
    /// Edge-stream generator scenario (`power-law` / `uniform`).
    pub scenario: String,
    /// Edges per delta batch.
    pub batch: usize,
    /// Batches streamed.
    pub batches: usize,
    /// Vertices in the stream graph.
    pub vertices: usize,
    /// Edges actually inserted.
    pub edges_accepted: u64,
    /// Self-loops and duplicates rejected by the apply path.
    pub edges_rejected: u64,
    /// Accepted insertions per second of pipeline wall-clock — the
    /// headline metric the nightly diff trends.
    pub updates_per_sec: f64,
    /// Pipeline wall-clock (ms).
    pub elapsed_ms: f64,
    /// Escape-hatch rebuilds performed (each verified bit-identical).
    pub recomputes: u64,
    /// Backpressure stalls summed over the three stage links.
    pub stalls: u64,
    /// Final incremental-CC checksum (equals the full-recompute value;
    /// gated before the row is emitted).
    pub cc_checksum: u64,
    /// Final delta-PageRank checksum (bitwise-gated against the serial
    /// kernel on the rebuilt graph).
    pub pr_checksum: u64,
    /// Final dynamic-BFS checksum (gated against the BFS oracle).
    pub bfs_checksum: u64,
    /// All three oracle gates passed. A false value never reaches the
    /// output — the sweep returns `Err` first — the field keeps the
    /// gate visible in the archived JSON.
    pub oracle_ok: bool,
    /// The `[stream] off` degeneracy leg: two plain engines answered
    /// the mixed workload response-for-response identically.
    pub stream_off_identical: bool,
}

/// Typed hard gate for the streaming sweep. Where the older sweeps
/// assert (a panic aborts nonzero but prints no table), a failed stream
/// gate becomes an `Err` whose message embeds the *rendered failing
/// row* — `repro stream` propagates it to `main`, which prints it and
/// exits 1. Unit-tested by `stream_gate_failure_propagates`.
fn stream_gate(ok: bool, reason: &str, row: &StreamRow) -> crate::Result<()> {
    if ok {
        return Ok(());
    }
    anyhow::bail!(
        "stream gate failed: {reason}\nfailing row:\n{}",
        render_stream(std::slice::from_ref(row))
    )
}

/// The `[stream] off` degeneracy leg: `[stream] enabled = false`
/// materializes no pipeline and leaves [`crate::coordinator::Engine`]
/// construction untouched, so an engine built alongside a disabled
/// stream config must answer a mixed-kernel workload response for
/// response like a plain engine. This builds both and compares
/// `(id, result)` streams (latency is wall-clock and excluded).
fn stream_off_degeneracy(template: &crate::coordinator::EngineConfig, shards: usize) -> bool {
    use crate::coordinator::{Deadline, Engine, GraphKernel, Request, Response};
    let graph = crate::graph::kronecker::paper_graph();
    let mut serve = || -> Vec<Response> {
        let mut config = template.clone();
        config.pool.shards = Some(shards.max(1));
        let mut engine = Engine::new(config);
        let requests: Vec<Request> = GraphKernel::all()
            .iter()
            .enumerate()
            .map(|(i, &kernel)| Request {
                id: i as u64,
                kernel,
                graph: graph.clone(),
                source: 0,
                deadline: Deadline::none(),
            })
            .collect();
        engine.process_batch(requests)
    };
    let plain = serve();
    let with_disabled_stream = serve();
    plain.len() == with_disabled_stream.len()
        && plain
            .iter()
            .zip(with_disabled_stream.iter())
            .all(|(a, b)| a.id == b.id && a.result == b.result)
}

/// The streaming sweep: run the parse → analytics → emit pipeline over
/// both generator scenarios and hard-gate every round — lossless
/// ordered delivery, clean parses, escape-hatch rebuilds bit-identical,
/// and the final incremental CC / delta-PageRank / dynamic-BFS state
/// bitwise equal to full recomputes on the rebuilt graph — plus the
/// `[stream] off` engine-degeneracy leg. Gate failures return a typed
/// error with the failing row rendered (see [`stream_gate`]).
pub fn stream_sweep(
    template: &crate::coordinator::EngineConfig,
    cfg: &crate::coordinator::StreamConfig,
    shards: usize,
) -> crate::Result<Vec<StreamRow>> {
    use crate::coordinator::stream::{encode_stream, run_pipeline, EdgeDist};
    use crate::graph::{cc, oracle, pr};
    use crate::probe::NoProbe;

    let stream_off_identical = stream_off_degeneracy(template, shards);
    let mut rows = Vec::new();
    for dist in EdgeDist::all() {
        let docs = encode_stream(dist, cfg);
        let (report, analytics) = run_pipeline(cfg, docs);
        let rebuilt = analytics.graph().rebuild();
        let labels = analytics.cc_labels();
        let cc_ok = labels == oracle::components_min_label(&rebuilt)
            && labels == cc::shiloach_vishkin(&rebuilt, &mut NoProbe);
        let kernel = pr::pagerank(&rebuilt, pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe);
        let pr_ok = analytics
            .pr_scores()
            .iter()
            .map(|s| s.to_bits())
            .eq(kernel.iter().map(|s| s.to_bits()));
        let bfs_ok = analytics.bfs_depths() == oracle::bfs_depths(&rebuilt, cfg.source);
        let row = StreamRow {
            scenario: dist.name().into(),
            batch: cfg.batch,
            batches: cfg.batches,
            vertices: 1usize << cfg.scale,
            edges_accepted: report.edges_accepted,
            edges_rejected: report.edges_rejected,
            updates_per_sec: report.updates_per_sec,
            elapsed_ms: report.elapsed_ms,
            recomputes: report.recomputes,
            stalls: report.stalls.iter().sum(),
            cc_checksum: report.checksums.0,
            pr_checksum: report.checksums.1,
            bfs_checksum: report.checksums.2,
            oracle_ok: cc_ok && pr_ok && bfs_ok,
            stream_off_identical,
        };
        stream_gate(
            report.emitted.len() == cfg.batches && report.out_of_order == 0,
            "pipeline dropped or reordered a batch",
            &row,
        )?;
        stream_gate(report.parse_errors == 0, "generated stream must parse cleanly", &row)?;
        stream_gate(
            report.recompute_mismatches == 0,
            "escape-hatch rebuild diverged from the incremental state",
            &row,
        )?;
        stream_gate(cc_ok, "incremental CC != full-recompute oracle", &row)?;
        stream_gate(pr_ok, "delta-PageRank != serial kernel (bitwise)", &row)?;
        stream_gate(bfs_ok, "dynamic BFS != full-recompute oracle", &row)?;
        stream_gate(
            stream_off_identical,
            "[stream] off engines diverged response-for-response",
            &row,
        )?;
        rows.push(row);
    }
    Ok(rows)
}

/// Render the streaming-sweep table with its gate legend.
pub fn render_stream(rows: &[StreamRow]) -> String {
    let mut out = format!(
        "{:<12}{:>8}{:>9}{:>10}{:>10}{:>10}{:>13}{:>8}{:>8}\n",
        "scenario",
        "batch",
        "batches",
        "vertices",
        "accepted",
        "rejected",
        "updates/s",
        "recomp",
        "stalls"
    );
    for r in rows {
        out += &format!(
            "{:<12}{:>8}{:>9}{:>10}{:>10}{:>10}{:>13.0}{:>8}{:>8}\n",
            r.scenario,
            r.batch,
            r.batches,
            r.vertices,
            r.edges_accepted,
            r.edges_rejected,
            r.updates_per_sec,
            r.recomputes,
            r.stalls
        );
    }
    out += "(gates passed: lossless ordered pipeline; incremental CC / delta-PR / \
            dynamic BFS bitwise equal to full recomputes on the rebuilt graph; \
            escape-hatch rebuilds matched; [stream] off identical to the plain \
            engine response-for-response)\n";
    out
}

/// Serialize streaming rows to JSON for the nightly trend diff
/// (`python/bench_diff.py` keys on `(scenario, batch)` and trends
/// `updates_per_sec`). Checksums travel as strings: they are u64 bit
/// reductions and must survive the f64-backed JSON number type
/// losslessly.
pub fn stream_rows_to_json(rows: &[StreamRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("scenario".into(), Value::String(r.scenario.clone())),
                ("batch".into(), Value::Number(r.batch as f64)),
                ("batches".into(), Value::Number(r.batches as f64)),
                ("vertices".into(), Value::Number(r.vertices as f64)),
                ("edges_accepted".into(), Value::Number(r.edges_accepted as f64)),
                ("edges_rejected".into(), Value::Number(r.edges_rejected as f64)),
                ("updates_per_sec".into(), Value::Number(r.updates_per_sec)),
                ("elapsed_ms".into(), Value::Number(r.elapsed_ms)),
                ("recomputes".into(), Value::Number(r.recomputes as f64)),
                ("stalls".into(), Value::Number(r.stalls as f64)),
                ("cc_checksum".into(), Value::String(r.cc_checksum.to_string())),
                ("pr_checksum".into(), Value::String(r.pr_checksum.to_string())),
                ("bfs_checksum".into(), Value::String(r.bfs_checksum.to_string())),
                ("oracle_ok".into(), Value::Bool(r.oracle_ok)),
                (
                    "stream_off_identical".into(),
                    Value::Bool(r.stream_off_identical),
                ),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// Render the intra-kernel comparison table.
pub fn render_intra(rows: &[IntraRow]) -> String {
    let mut out = format!(
        "{:<8}{:>12}{:>12}{:>16}\n",
        "kernel", "serial µs", "pair", "parallel_for"
    );
    for r in rows {
        out += &format!(
            "{:<8}{:>12.2}{:>11.3}x{:>15.3}x\n",
            r.kernel,
            r.serial_ns / 1000.0,
            r.pair_speedup,
            r.parallel_for_speedup
        );
    }
    out += "(pair = 2 whole instances / 2 serial; parallel_for = 1 split instance / 1 serial)\n";
    out
}

/// Render speedup cells as a kernel × runtime text matrix.
pub fn render_matrix(cells: &[Cell]) -> String {
    let runtimes: Vec<&str> = {
        let mut seen = Vec::new();
        for c in cells {
            if !seen.contains(&c.runtime.as_str()) {
                seen.push(&c.runtime);
            }
        }
        seen
    };
    let mut out = format!("{:<8}", "kernel");
    for rt in &runtimes {
        out += &format!("{rt:>14}");
    }
    out += "\n";
    for kernel in KERNEL_NAMES {
        out += &format!("{kernel:<8}");
        for rt in &runtimes {
            let cell = cells
                .iter()
                .find(|c| c.kernel == kernel && c.runtime == *rt);
            match cell {
                Some(c) => {
                    let paper = c
                        .paper
                        .map(|p| format!("({p:.2})"))
                        .unwrap_or_default();
                    out += &format!("{:>14}", format!("{:.3}{paper}", c.speedup));
                }
                None => out += &format!("{:>14}", "-"),
            }
        }
        out += "\n";
    }
    out += "(parenthesized = paper-reported value for that cell)\n";
    out
}

/// Render Fig. 4 / §V summary rows.
pub fn render_summary(rows: &[SummaryRow], label: &str) -> String {
    let mut out = format!("{label}\n{:<14}{:>10}{:>12}\n", "runtime", "ours", "paper");
    for r in rows {
        let paper = r.paper.map(|p| format!("{p:.3}")).unwrap_or_else(|| "-".into());
        out += &format!("{:<14}{:>10.3}{:>12}\n", r.runtime, r.value, paper);
    }
    out
}

/// Render the granularity table.
pub fn render_granularity(rows: &[GranularityRow]) -> String {
    let mut out = format!("{:<8}{:>12}{:>12}\n", "kernel", "sim µs", "paper µs");
    for r in rows {
        out += &format!("{:<8}{:>12.2}{:>12.2}\n", r.kernel, r.micros, r.paper_micros);
    }
    out
}

/// Serialize cells to JSON for plotting.
pub fn cells_to_json(cells: &[Cell]) -> String {
    use crate::json::Value;
    let arr = cells
        .iter()
        .map(|c| {
            Value::Object(vec![
                ("kernel".into(), Value::String(c.kernel.clone())),
                ("runtime".into(), Value::String(c.runtime.clone())),
                ("speedup".into(), Value::Number(c.speedup)),
                (
                    "paper".into(),
                    c.paper.map(Value::Number).unwrap_or(Value::Null),
                ),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CoreConfig {
        CoreConfig::default()
    }

    #[test]
    fn fig4_uses_outlier_rule() {
        let f1 = vec![
            Cell { kernel: "bc".into(), runtime: "llvm-openmp".into(), speedup: 0.5, paper: None },
        ];
        // Build full synthetic sets for one runtime + relic.
        let mut f1_full = Vec::new();
        let mut f3_full = Vec::new();
        for k in KERNEL_NAMES {
            for rt in FIG_RUNTIMES {
                f1_full.push(Cell {
                    kernel: k.into(),
                    runtime: rt.into(),
                    speedup: if rt == "llvm-openmp" { 0.5 } else { 1.2 },
                    paper: None,
                });
            }
            f3_full.push(Cell {
                kernel: k.into(),
                runtime: "relic".into(),
                speedup: 1.5,
                paper: None,
            });
        }
        let rows = fig4(&f1_full, &f3_full);
        let llvm = rows.iter().find(|r| r.runtime == "llvm-openmp").unwrap();
        // All-degrading runtime floors at 1.0, not 0.5.
        assert!((llvm.value - 1.0).abs() < 1e-12);
        let relic = rows.iter().find(|r| r.runtime == "relic").unwrap();
        assert!((relic.value - 1.5).abs() < 1e-12);
        drop(f1);
    }

    #[test]
    fn paper_reference_values_sane() {
        assert!(paper_fig4("relic").unwrap() > paper_fig4("llvm-openmp").unwrap());
        assert!(paper_section5_geomean("gnu-openmp").unwrap() < 1.0);
        assert_eq!(paper_fig1("pr", "gnu-openmp"), Some(1.665));
    }

    #[test]
    fn granularity_rows_cover_all_kernels() {
        let rows = granularity(&cfg());
        assert_eq!(rows.len(), KERNEL_NAMES.len());
        for r in &rows {
            // Calibration holds each to ±7% of the paper's time.
            assert!(
                (r.micros - r.paper_micros).abs() / r.paper_micros < 0.08,
                "{}: {} vs {}",
                r.kernel,
                r.micros,
                r.paper_micros
            );
        }
    }

    #[test]
    fn intra_kernel_rows_cover_all_and_verify_checksums() {
        // Tiny iteration counts: this checks plumbing + the built-in
        // checksum assertion (for every schedule), not timing quality.
        let relic = crate::relic::Relic::new();
        for schedule in crate::relic::Schedule::all() {
            let rows = intra_kernel(&relic, schedule, 3, 1);
            assert_eq!(rows.len(), KERNEL_NAMES.len());
            for r in &rows {
                assert!(r.serial_ns > 0.0, "{} ({schedule})", r.kernel);
                assert!(
                    r.pair_speedup > 0.0 && r.parallel_for_speedup > 0.0,
                    "{} ({schedule})",
                    r.kernel
                );
            }
            let s = render_intra(&rows);
            for k in KERNEL_NAMES {
                assert!(s.contains(k), "render missing {k}");
            }
        }
    }

    #[test]
    fn pool_scaling_verifies_and_renders() {
        // Tiny sweep: plumbing + the built-in checksum equivalence, not
        // timing quality. Unpinned so affinity-restricted CI works.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig {
                pin: false,
                ..crate::relic::PoolConfig::default()
            },
            ..crate::coordinator::EngineConfig::default()
        };
        let rows = pool_scaling(&template, &[1, 2], 8, 1);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].shards, 1);
        assert_eq!(rows[1].shards, 2);
        for r in &rows {
            assert!(r.batch_ms > 0.0);
            assert!(r.throughput_rps > 0.0);
            assert!(r.speedup > 0.0);
        }
        assert!((rows[0].speedup - 1.0).abs() < 1e-12, "1-shard row is the baseline");
        let s = render_pool_scaling(&rows);
        assert!(s.contains("shards"));
        assert!(s.contains("req/s"));
        let json = pool_rows_to_json(&rows);
        assert!(json.contains("\"shards\""));
        assert!(json.contains("\"throughput_rps\""));
    }

    #[test]
    fn admission_sweep_reconciles_and_renders() {
        // Deep channels + tiny loads: every mode accepts everything, so
        // the reconciliation asserts inside the sweep do the heavy
        // lifting. Unpinned so affinity-restricted CI works.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig {
                shards: Some(2),
                pin: false,
                ..crate::relic::PoolConfig::default()
            },
            ..crate::coordinator::EngineConfig::default()
        };
        let rows = admission_sweep(&template, &[4, 8], None, 1);
        assert_eq!(rows.len(), 2 * ADMISSION_MODES.len());
        for r in &rows {
            assert_eq!(r.accepted, r.offered as u64, "{}: deep channels accept all", r.mode);
            assert_eq!(r.completed, r.accepted);
            assert_eq!(r.shed, 0);
            assert_eq!(r.deadline_misses, 0, "deadline-less requests never miss");
            assert!(r.batch_ms > 0.0);
        }
        let s = render_admission(&rows);
        for mode in ADMISSION_MODES {
            assert!(s.contains(mode), "render missing {mode}");
        }
        let json = admission_rows_to_json(&rows);
        assert!(json.contains("\"mode\""));
        assert!(json.contains("\"throughput_rps\""));
    }

    #[test]
    fn admission_sweep_sheds_under_always_overloaded_policy() {
        // LoadFactor(-1) reads as "always overloaded": every deadlined
        // request sheds, deterministically, on every submit flavor.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig {
                shards: Some(1),
                pin: false,
                ..crate::relic::PoolConfig::default()
            },
            admission: crate::coordinator::AdmissionConfig {
                shed: crate::coordinator::ShedPolicy::LoadFactor(-1.0),
                ..Default::default()
            },
            ..crate::coordinator::EngineConfig::default()
        };
        let rows =
            admission_sweep(&template, &[6], Some(std::time::Duration::from_secs(3600)), 1);
        for r in &rows {
            assert_eq!(r.shed, r.offered as u64, "{}: all deadlined requests shed", r.mode);
            assert_eq!(r.accepted, 0);
            assert_eq!(r.completed, 0);
        }
    }

    #[test]
    fn admission_sweep_edf_protocol_adds_baseline_and_ema_columns() {
        // EDF + measured EMA: the sweep runs the FIFO baseline per row
        // and surfaces the estimator readout. Generous deadlines keep
        // the run deterministic (no misses on either engine) while the
        // columns and reconciliation are exercised end to end.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig {
                shards: Some(1),
                pin: false,
                ..crate::relic::PoolConfig::default()
            },
            admission: crate::coordinator::AdmissionConfig {
                ema_alpha: 0.5,
                edf: true,
                ..Default::default()
            },
            ..crate::coordinator::EngineConfig::default()
        };
        let rows =
            admission_sweep(&template, &[6], Some(std::time::Duration::from_secs(3600)), 1);
        assert_eq!(rows.len(), ADMISSION_MODES.len());
        for r in &rows {
            assert!(r.edf);
            assert_eq!(r.completed, r.offered as u64);
            assert_eq!(r.deadline_misses, 0, "hour-scale deadlines cannot miss");
            assert_eq!(r.fifo_misses, 0, "baseline cannot miss either");
            assert!(r.ema_us > 0.0, "measured EMA converged to a real latency");
        }
        let s = render_admission(&rows);
        assert!(s.contains("fifo"), "baseline column rendered: {s}");
        assert!(s.contains("ema µs"), "EMA column rendered: {s}");
        assert!(s.contains("edf protocol"), "legend explains the columns: {s}");
        let json = admission_rows_to_json(&rows);
        assert!(json.contains("\"fifo_misses\""));
        assert!(json.contains("\"ema_us\""));
        assert!(json.contains("\"edf\""));
        // Non-EDF rows keep the compact table (no baseline column).
        let plain = admission_sweep(
            &crate::coordinator::EngineConfig {
                pool: crate::relic::PoolConfig {
                    shards: Some(1),
                    pin: false,
                    ..crate::relic::PoolConfig::default()
                },
                ..crate::coordinator::EngineConfig::default()
            },
            &[4],
            None,
            1,
        );
        assert!(plain.iter().all(|r| !r.edf && r.fifo_misses == r.deadline_misses));
        assert!(!render_admission(&plain).contains("edf protocol"));
    }

    #[test]
    fn fault_sweep_runs_every_scenario_and_renders() {
        // The sweep's own gates (no-drop invariant, checksums, recovery
        // counters per scenario) are the real assertions; this test
        // drives them at the smallest deterministic size. Unpinned so
        // affinity-restricted CI works.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig {
                shards: Some(2),
                pin: false,
                ..crate::relic::PoolConfig::default()
            },
            ..crate::coordinator::EngineConfig::default()
        };
        let rows = fault_sweep(&template, 12);
        assert_eq!(rows.len(), FAULT_SCENARIOS.len());
        for (r, name) in rows.iter().zip(FAULT_SCENARIOS) {
            assert_eq!(r.scenario, name);
            assert_eq!(r.ok + r.failed, r.offered as u64, "{name}: ok + failed = offered");
            assert!(r.batch_ms > 0.0);
        }
        let s = render_faults(&rows);
        for name in FAULT_SCENARIOS {
            assert!(s.contains(name), "render missing {name}");
        }
        assert!(s.contains("gates passed"));
        let json = fault_rows_to_json(&rows);
        assert!(json.contains("\"scenario\""));
        assert!(json.contains("\"restarts\""));
        assert!(json.contains("all-down"));
    }

    #[test]
    fn intra_rows_serialize_to_json() {
        let rows = vec![IntraRow {
            kernel: "tc".into(),
            serial_ns: 1234.5,
            pair_speedup: 1.4,
            parallel_for_speedup: 1.2,
        }];
        let json = intra_rows_to_json(&rows);
        assert!(json.contains("\"kernel\""));
        assert!(json.contains("\"pair_speedup\""));
        assert!(json.contains("tc"));
    }

    #[test]
    fn render_matrix_contains_all_kernels() {
        let cells = vec![Cell {
            kernel: "bc".into(),
            runtime: "relic".into(),
            speedup: 1.5,
            paper: Some(1.361),
        }];
        let s = render_matrix(&cells);
        assert!(s.contains("bc"));
        assert!(s.contains("1.500(1.36)"));
    }

    #[test]
    fn whale_sweep_small_graph_checksums_and_degenerate_row() {
        // Unpinned, tiny scale, one rep: the correctness shape of the
        // sweep (both kernels × both borrow caps, all checksums
        // asserted inside), not a performance claim.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig { pin: false, ..Default::default() },
            ..Default::default()
        };
        let rows = whale_sweep(&template, 2, &[0, 1], 6, 1);
        assert_eq!(rows.len(), 4, "pr/bc × borrow {{0,1}}");
        assert!(rows.iter().all(|r| r.checksum_ok));
        assert!(rows.iter().all(|r| r.serial_ns > 0.0 && r.engine_ns > 0.0));
        assert_eq!(rows.iter().filter(|r| r.max_borrow == 0).count(), 2);
        let s = render_whale(&rows);
        assert!(s.contains("vs pair"));
        let json = whale_rows_to_json(&rows);
        assert!(json.contains("\"speedup_vs_pair\""));
        assert!(json.contains("\"checksum_ok\""));
    }

    #[test]
    fn plan_sweep_small_graph_covers_every_source_and_resolves_the_tuner() {
        // Unpinned, tiny scale, one rep: the correctness shape of the
        // sweep (baseline + four forced plans + tuner, all checksums
        // asserted inside), not a performance claim.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig { pin: false, ..Default::default() },
            ..Default::default()
        };
        let rows = plan_sweep(&template, 2, 6, 1);
        let names: Vec<&str> = rows.iter().map(|r| r.config.as_str()).collect();
        assert_eq!(
            names,
            ["baseline", "serial", "pair:static", "pair:dynamic", "pair:edge-balanced", "tuner"]
        );
        assert!(rows.iter().all(|r| r.checksum_ok && r.mean_batch_ms > 0.0));
        // Only the tuner row resolves per-(kernel, shape) assignments,
        // and after the warm rounds every kernel has one.
        assert!(rows.iter().filter(|r| r.config != "tuner").all(|r| r.resolved.is_empty()));
        let tuner_row = rows.last().expect("tuner row");
        for k in crate::coordinator::GraphKernel::all() {
            assert!(
                tuner_row.resolved.contains(k.artifact_name()),
                "tuner resolved nothing for {k:?}: {}",
                tuner_row.resolved
            );
        }
        let s = render_plan(&rows);
        assert!(s.contains("vs baseline") && s.contains("resolved (tuner):"));
        let json = plan_rows_to_json(&rows);
        assert!(json.contains("\"speedup_vs_baseline\"") && json.contains("\"resolved\""));
    }

    #[test]
    fn stream_sweep_passes_gates_and_serializes() {
        // Tiny stream: plumbing + every hard gate (oracle equality,
        // lossless pipeline, escape-hatch match, engine degeneracy),
        // not timing quality. Unpinned for affinity-restricted CI.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig { pin: false, ..Default::default() },
            ..Default::default()
        };
        let cfg = crate::coordinator::StreamConfig {
            enabled: true,
            scale: 6,
            batch: 32,
            batches: 8,
            queue_capacity: 4,
            recompute_interval: 4,
            source: 0,
            seed: 5,
            pin: false,
        };
        let rows = stream_sweep(&template, &cfg, 1).expect("all stream gates hold");
        assert_eq!(rows.len(), 2, "one row per scenario");
        let names: Vec<&str> = rows.iter().map(|r| r.scenario.as_str()).collect();
        assert_eq!(names, ["power-law", "uniform"]);
        for r in &rows {
            assert!(r.oracle_ok && r.stream_off_identical);
            assert_eq!(r.recomputes, 2, "8 batches / interval 4");
            assert_eq!(r.vertices, 64);
            assert!(r.edges_accepted > 0);
        }
        let s = render_stream(&rows);
        assert!(s.contains("power-law") && s.contains("uniform"));
        assert!(s.contains("gates passed"));
        let json = stream_rows_to_json(&rows);
        assert!(json.contains("\"scenario\"") && json.contains("\"updates_per_sec\""));
        assert!(json.contains("\"cc_checksum\""));
    }

    #[test]
    fn stream_gate_failure_propagates_with_the_failing_row() {
        // The satellite-4 contract: a failed gate surfaces as a typed
        // error carrying the rendered failing row, which `repro stream`
        // propagates to main's nonzero-exit path.
        let row = StreamRow {
            scenario: "uniform".into(),
            batch: 32,
            batches: 8,
            vertices: 64,
            edges_accepted: 10,
            edges_rejected: 2,
            updates_per_sec: 1.0,
            elapsed_ms: 1.0,
            recomputes: 1,
            stalls: 0,
            cc_checksum: 1,
            pr_checksum: 2,
            bfs_checksum: 3,
            oracle_ok: false,
            stream_off_identical: true,
        };
        assert!(stream_gate(true, "unused", &row).is_ok());
        let err = stream_gate(false, "synthetic failure", &row).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stream gate failed: synthetic failure"), "{msg}");
        assert!(msg.contains("failing row"), "{msg}");
        assert!(msg.contains("uniform"), "row rendered into the error: {msg}");
    }
}
