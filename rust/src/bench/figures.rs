//! Figure regeneration: every table and figure in the paper's
//! evaluation (DESIGN.md §6).
//!
//! * [`fig1`] — speedups over serial, 7 frameworks × 7 kernels (Fig. 1);
//! * [`fig3`] — Relic's speedups (Fig. 3);
//! * [`fig4`] — average speedups without negative outliers (Fig. 4);
//! * [`granularity`] — the §IV in-text serial task-time table;
//! * [`section5_geomeans`] — the §V in-text geomeans (with degradations);
//! * [`intra_kernel`] — beyond the paper: serial vs `pair` (two whole
//!   instances) vs `parallel_for` (one instance, internally fork-joined)
//!   per kernel, wall-clock;
//! * [`pool_scaling`] — beyond the paper: batch throughput of the
//!   sharded engine vs shard count, with built-in pool-vs-single-pair
//!   checksum verification.
//!
//! Each function returns structured rows; [`render_table`] pretty-prints
//! them with the paper's reference values beside ours.

use crate::smtsim::{self, CoreConfig, Trace};

use super::harness::geomean;
use super::workloads::{paper_task_micros, Workload, KERNEL_NAMES};

/// Framework order used in the paper's figures.
pub const FIG_RUNTIMES: [&str; 7] = [
    "llvm-openmp",
    "gnu-openmp",
    "intel-openmp",
    "x-openmp",
    "onetbb",
    "taskflow",
    "opencilk",
];

/// One speedup measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub kernel: String,
    pub runtime: String,
    pub speedup: f64,
    /// Paper's value for this cell, where the text reports one.
    pub paper: Option<f64>,
}

/// Paper-reported Fig. 1 cells (§V and §VII name the per-kernel bests
/// and a few specific values).
pub fn paper_fig1(kernel: &str, runtime: &str) -> Option<f64> {
    match (kernel, runtime) {
        ("bc", "taskflow") => Some(1.057),
        ("cc", "llvm-openmp") => Some(1.094),
        ("pr", "gnu-openmp") => Some(1.665),
        ("sssp", "taskflow") => Some(1.557),
        ("tc", "llvm-openmp") => Some(1.514),
        ("json", "opencilk") => Some(1.235),
        _ => None,
    }
}

/// Paper-reported Fig. 3 values (Relic): §VII gives BFS and the
/// per-kernel improvements over the best baseline.
pub fn paper_fig3(kernel: &str) -> Option<f64> {
    match kernel {
        "bc" => Some(1.057 + 0.304),
        "cc" => Some(1.094 + 0.301),
        "pr" => Some(1.665 + 0.143),
        "sssp" => Some(1.557 + 0.213),
        "json" => Some(1.235 + 0.086),
        "bfs" => Some(1.056),
        _ => None, // TC: "lower than LLVM's 1.514", no exact value
    }
}

/// Paper Fig. 4 (average speedup w/o negative outliers): Relic = 1.421
/// (§VII 42.1%); baselines derived from the reported relative gains.
pub fn paper_fig4(runtime: &str) -> Option<f64> {
    match runtime {
        "relic" => Some(1.421),
        "llvm-openmp" => Some(1.421 / 1.191),
        "gnu-openmp" => Some(1.421 / 1.310),
        "intel-openmp" => Some(1.421 / 1.202),
        "x-openmp" => Some(1.421 / 1.332),
        "onetbb" => Some(1.421 / 1.301),
        "taskflow" => Some(1.421 / 1.230),
        "opencilk" => Some(1.421 / 1.214),
        _ => None,
    }
}

/// Paper §V geometric means *including* degradations.
pub fn paper_section5_geomean(runtime: &str) -> Option<f64> {
    match runtime {
        "llvm-openmp" => Some(1.139),
        "gnu-openmp" => Some(1.0 - 0.177),
        "intel-openmp" => Some(1.113),
        "x-openmp" => Some(1.0 - 0.067),
        "onetbb" => Some(1.0 - 0.019),
        "taskflow" => Some(1.118),
        "opencilk" => Some(1.126),
        _ => None,
    }
}

/// Calibrated trace pair for every kernel (memoize: trace calibration
/// runs the simulator repeatedly).
pub fn all_trace_pairs(cfg: &CoreConfig) -> Vec<(String, Trace, Trace)> {
    Workload::all()
        .into_iter()
        .map(|w| {
            let a = w.trace(0, cfg);
            let b = w.trace(1, cfg);
            (w.name.to_string(), a, b)
        })
        .collect()
}

/// Fig. 1: the seven baseline frameworks across the seven kernels.
pub fn fig1(cfg: &CoreConfig) -> Vec<Cell> {
    let pairs = all_trace_pairs(cfg);
    let mut cells = Vec::new();
    for rt in FIG_RUNTIMES {
        for (kernel, a, b) in &pairs {
            cells.push(Cell {
                kernel: kernel.clone(),
                runtime: rt.to_string(),
                speedup: smtsim::speedup(rt, a, b, cfg),
                paper: paper_fig1(kernel, rt),
            });
        }
    }
    cells
}

/// Fig. 3: Relic across the seven kernels.
pub fn fig3(cfg: &CoreConfig) -> Vec<Cell> {
    all_trace_pairs(cfg)
        .into_iter()
        .map(|(kernel, a, b)| Cell {
            speedup: smtsim::speedup("relic", &a, &b, cfg),
            paper: paper_fig3(&kernel),
            kernel,
            runtime: "relic".into(),
        })
        .collect()
}

/// One Fig. 4 row: runtime + average speedup without negative outliers.
#[derive(Debug, Clone, PartialEq)]
pub struct SummaryRow {
    pub runtime: String,
    pub value: f64,
    pub paper: Option<f64>,
}

/// Fig. 4: per-framework geomean with degradations replaced by the
/// serial baseline (the paper's "without negative outliers" rule:
/// regressing kernels would be reverted to serial in production).
pub fn fig4(fig1_cells: &[Cell], fig3_cells: &[Cell]) -> Vec<SummaryRow> {
    let mut rows = Vec::new();
    for rt in FIG_RUNTIMES.iter().copied().chain(["relic"]) {
        let vals: Vec<f64> = fig1_cells
            .iter()
            .chain(fig3_cells)
            .filter(|c| c.runtime == rt)
            .map(|c| c.speedup.max(1.0))
            .collect();
        assert_eq!(vals.len(), KERNEL_NAMES.len(), "{rt}");
        rows.push(SummaryRow {
            runtime: rt.to_string(),
            value: geomean(vals),
            paper: paper_fig4(rt),
        });
    }
    rows
}

/// §V: geomeans including degradations (the in-text numbers).
pub fn section5_geomeans(fig1_cells: &[Cell]) -> Vec<SummaryRow> {
    FIG_RUNTIMES
        .iter()
        .map(|rt| {
            let vals: Vec<f64> = fig1_cells
                .iter()
                .filter(|c| c.runtime == *rt)
                .map(|c| c.speedup)
                .collect();
            SummaryRow {
                runtime: rt.to_string(),
                value: geomean(vals),
                paper: paper_section5_geomean(rt),
            }
        })
        .collect()
}

/// §IV granularity table row: kernel, simulated solo µs, paper µs.
#[derive(Debug, Clone, PartialEq)]
pub struct GranularityRow {
    pub kernel: String,
    pub micros: f64,
    pub paper_micros: f64,
}

/// The §IV serial task-granularity table (simulated, calibrated).
pub fn granularity(cfg: &CoreConfig) -> Vec<GranularityRow> {
    Workload::all()
        .into_iter()
        .map(|w| {
            let t = w.trace(0, cfg);
            let cycles = super::workloads::solo_cycles(&t, cfg);
            GranularityRow {
                kernel: w.name.to_string(),
                micros: cycles as f64 / (cfg.freq_ghz * 1000.0),
                paper_micros: paper_task_micros(w.name),
            }
        })
        .collect()
}

/// One intra-kernel comparison row (wall-clock).
///
/// `pair_speedup` is the paper's protocol — two whole instances, one
/// per logical thread, against running both serially. It measures
/// *throughput* and needs two independent requests.
/// `parallel_for_speedup` is one instance with its hot loops
/// fork-joined, against one serial instance. It measures *latency* of a
/// single request — the scenario `coordinator` hits on odd batches.
#[derive(Debug, Clone, PartialEq)]
pub struct IntraRow {
    pub kernel: String,
    /// Mean serial single-instance time (ns).
    pub serial_ns: f64,
    pub pair_speedup: f64,
    pub parallel_for_speedup: f64,
}

/// The intra-kernel ablation: serial vs `pair` vs `parallel_for` for
/// every workload, on `relic` (pin the main thread and the assistant to
/// an SMT sibling pair first for meaningful numbers). The fork-join
/// loops run under `schedule` (`repro intra --schedule dynamic`
/// selects); also asserts the parallel checksums equal the serial ones
/// — the run doubles as an end-to-end determinism check per schedule.
pub fn intra_kernel(
    relic: &crate::relic::Relic,
    schedule: crate::relic::Schedule,
    iters: u64,
    warmup: u64,
) -> Vec<IntraRow> {
    use crate::relic::Par;
    use std::sync::atomic::{AtomicU64, Ordering};

    let par = Par::Relic(relic).with_schedule(schedule);
    let mut rows = Vec::new();
    for w in Workload::all() {
        let serial_sum = w.run_native();
        assert_eq!(
            w.run_native_par(&par),
            serial_sum,
            "{}: parallel checksum diverges from serial under {}",
            w.name,
            schedule.name()
        );
        let sink = AtomicU64::new(0);
        let task = || {
            sink.fetch_add(w.run_native(), Ordering::Relaxed);
        };
        // One serial instance (the parallel_for baseline).
        let serial1 = super::harness::measure(iters, warmup, || task());
        // Two serial instances (the pair baseline, paper protocol).
        let serial2 = super::harness::measure(iters, warmup, || {
            task();
            task();
        });
        let paired = super::harness::measure(iters, warmup, || relic.pair(&task, &task));
        let pfor = super::harness::measure(iters, warmup, || {
            sink.fetch_add(w.run_native_par(&par), Ordering::Relaxed);
        });
        std::hint::black_box(sink.load(Ordering::Relaxed));
        rows.push(IntraRow {
            kernel: w.name.to_string(),
            serial_ns: serial1.mean_ns,
            pair_speedup: serial2.mean_ns / paired.mean_ns,
            parallel_for_speedup: serial1.mean_ns / pfor.mean_ns,
        });
    }
    rows
}

/// One pool-scaling measurement: batch throughput at a shard count.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolScalingRow {
    pub shards: usize,
    pub requests: usize,
    /// Mean wall time to process the whole batch (ms).
    pub batch_ms: f64,
    /// Requests per second at that batch time.
    pub throughput_rps: f64,
    /// Batch-time speedup relative to the 1-shard row (or the first
    /// row measured when 1 is not in the sweep).
    pub speedup: f64,
    /// Admission backpressure stalls observed across the whole run.
    pub backpressure_stalls: u64,
}

/// The pool-scaling sweep: process the same mixed-kernel batch on the
/// paper graph through a [`crate::coordinator::Engine`] at each shard
/// count, verifying along the way that every response's checksum equals
/// the plain single-pair kernel's — the run doubles as the
/// pool-vs-single-pair equivalence check. `template` carries
/// pin/channel/batch knobs; its shard count is overridden per row.
///
/// Meaningful *scaling* numbers need one idle physical core per shard;
/// elsewhere the sweep still measures and still verifies checksums.
pub fn pool_scaling(
    template: &crate::coordinator::EngineConfig,
    shard_counts: &[usize],
    requests: usize,
    reps: u64,
) -> Vec<PoolScalingRow> {
    use crate::coordinator::{
        run_native_kernel, Engine, GraphKernel, Request, RequestResult,
    };
    use crate::graph::kronecker::paper_graph;

    let graph = paper_graph();
    let kernels = GraphKernel::all();
    let plan: Vec<(GraphKernel, u32)> = (0..requests)
        .map(|i| (kernels[i % kernels.len()], (i % 32) as u32))
        .collect();
    let expected: Vec<u64> = plan
        .iter()
        .map(|&(k, source)| run_native_kernel(k, &graph, source))
        .collect();

    let reps = reps.max(1);
    let mut rows = Vec::new();
    for &shards in shard_counts {
        let mut config = template.clone();
        config.pool.shards = Some(shards.max(1));
        let mut engine = Engine::new(config);
        let make_batch = || -> Vec<Request> {
            plan.iter()
                .enumerate()
                .map(|(i, &(kernel, source))| Request {
                    id: i as u64,
                    kernel,
                    graph: graph.clone(),
                    source,
                })
                .collect()
        };
        // Untimed warmup rep: Engine::new returns while shard threads
        // are still pinning and building their Relic pairs; without
        // this the first timed rep absorbs that one-time startup cost
        // and skews the 1-shard baseline every speedup divides by.
        let warm = engine.process_batch(make_batch());
        assert_eq!(warm.len(), requests);
        let mut total_ns = 0u128;
        for _ in 0..reps {
            let batch = make_batch();
            let t0 = std::time::Instant::now();
            let responses = engine.process_batch(batch);
            total_ns += t0.elapsed().as_nanos();
            assert_eq!(responses.len(), requests);
            for (i, r) in responses.iter().enumerate() {
                assert_eq!(
                    r.result,
                    RequestResult::Native(expected[i]),
                    "pool checksum diverged from single-pair at shards={shards}, request {i}"
                );
            }
        }
        let batch_ms = total_ns as f64 / reps as f64 / 1e6;
        rows.push(PoolScalingRow {
            shards: shards.max(1),
            requests,
            batch_ms,
            throughput_rps: if batch_ms > 0.0 { requests as f64 / (batch_ms / 1e3) } else { 0.0 },
            speedup: 1.0,
            backpressure_stalls: engine.pool_snapshot().backpressure_stalls,
        });
    }
    let base_ms = rows
        .iter()
        .find(|r| r.shards == 1)
        .or_else(|| rows.first())
        .map(|r| r.batch_ms)
        .unwrap_or(0.0);
    for r in &mut rows {
        r.speedup = if r.batch_ms > 0.0 { base_ms / r.batch_ms } else { 0.0 };
    }
    rows
}

/// Render the pool-scaling table.
pub fn render_pool_scaling(rows: &[PoolScalingRow]) -> String {
    let mut out = format!(
        "{:<8}{:>10}{:>12}{:>14}{:>10}{:>10}\n",
        "shards", "requests", "batch ms", "req/s", "speedup", "stalls"
    );
    for r in rows {
        out += &format!(
            "{:<8}{:>10}{:>12.3}{:>14.0}{:>9.3}x{:>10}\n",
            r.shards, r.requests, r.batch_ms, r.throughput_rps, r.speedup, r.backpressure_stalls
        );
    }
    out += "(speedup = batch time vs the 1-shard row; \
            checksums verified against the single-pair kernels)\n";
    out
}

/// Serialize pool-scaling rows to JSON for plotting.
pub fn pool_rows_to_json(rows: &[PoolScalingRow]) -> String {
    use crate::json::Value;
    let arr = rows
        .iter()
        .map(|r| {
            Value::Object(vec![
                ("shards".into(), Value::Number(r.shards as f64)),
                ("requests".into(), Value::Number(r.requests as f64)),
                ("batch_ms".into(), Value::Number(r.batch_ms)),
                ("throughput_rps".into(), Value::Number(r.throughput_rps)),
                ("speedup".into(), Value::Number(r.speedup)),
                (
                    "backpressure_stalls".into(),
                    Value::Number(r.backpressure_stalls as f64),
                ),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

/// Render the intra-kernel comparison table.
pub fn render_intra(rows: &[IntraRow]) -> String {
    let mut out = format!(
        "{:<8}{:>12}{:>12}{:>16}\n",
        "kernel", "serial µs", "pair", "parallel_for"
    );
    for r in rows {
        out += &format!(
            "{:<8}{:>12.2}{:>11.3}x{:>15.3}x\n",
            r.kernel,
            r.serial_ns / 1000.0,
            r.pair_speedup,
            r.parallel_for_speedup
        );
    }
    out += "(pair = 2 whole instances / 2 serial; parallel_for = 1 split instance / 1 serial)\n";
    out
}

/// Render speedup cells as a kernel × runtime text matrix.
pub fn render_matrix(cells: &[Cell]) -> String {
    let runtimes: Vec<&str> = {
        let mut seen = Vec::new();
        for c in cells {
            if !seen.contains(&c.runtime.as_str()) {
                seen.push(&c.runtime);
            }
        }
        seen
    };
    let mut out = format!("{:<8}", "kernel");
    for rt in &runtimes {
        out += &format!("{rt:>14}");
    }
    out += "\n";
    for kernel in KERNEL_NAMES {
        out += &format!("{kernel:<8}");
        for rt in &runtimes {
            let cell = cells
                .iter()
                .find(|c| c.kernel == kernel && c.runtime == *rt);
            match cell {
                Some(c) => {
                    let paper = c
                        .paper
                        .map(|p| format!("({p:.2})"))
                        .unwrap_or_default();
                    out += &format!("{:>14}", format!("{:.3}{paper}", c.speedup));
                }
                None => out += &format!("{:>14}", "-"),
            }
        }
        out += "\n";
    }
    out += "(parenthesized = paper-reported value for that cell)\n";
    out
}

/// Render Fig. 4 / §V summary rows.
pub fn render_summary(rows: &[SummaryRow], label: &str) -> String {
    let mut out = format!("{label}\n{:<14}{:>10}{:>12}\n", "runtime", "ours", "paper");
    for r in rows {
        let paper = r.paper.map(|p| format!("{p:.3}")).unwrap_or_else(|| "-".into());
        out += &format!("{:<14}{:>10.3}{:>12}\n", r.runtime, r.value, paper);
    }
    out
}

/// Render the granularity table.
pub fn render_granularity(rows: &[GranularityRow]) -> String {
    let mut out = format!("{:<8}{:>12}{:>12}\n", "kernel", "sim µs", "paper µs");
    for r in rows {
        out += &format!("{:<8}{:>12.2}{:>12.2}\n", r.kernel, r.micros, r.paper_micros);
    }
    out
}

/// Serialize cells to JSON for plotting.
pub fn cells_to_json(cells: &[Cell]) -> String {
    use crate::json::Value;
    let arr = cells
        .iter()
        .map(|c| {
            Value::Object(vec![
                ("kernel".into(), Value::String(c.kernel.clone())),
                ("runtime".into(), Value::String(c.runtime.clone())),
                ("speedup".into(), Value::Number(c.speedup)),
                (
                    "paper".into(),
                    c.paper.map(Value::Number).unwrap_or(Value::Null),
                ),
            ])
        })
        .collect();
    crate::json::to_string(&Value::Array(arr))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CoreConfig {
        CoreConfig::default()
    }

    #[test]
    fn fig4_uses_outlier_rule() {
        let f1 = vec![
            Cell { kernel: "bc".into(), runtime: "llvm-openmp".into(), speedup: 0.5, paper: None },
        ];
        // Build full synthetic sets for one runtime + relic.
        let mut f1_full = Vec::new();
        let mut f3_full = Vec::new();
        for k in KERNEL_NAMES {
            for rt in FIG_RUNTIMES {
                f1_full.push(Cell {
                    kernel: k.into(),
                    runtime: rt.into(),
                    speedup: if rt == "llvm-openmp" { 0.5 } else { 1.2 },
                    paper: None,
                });
            }
            f3_full.push(Cell {
                kernel: k.into(),
                runtime: "relic".into(),
                speedup: 1.5,
                paper: None,
            });
        }
        let rows = fig4(&f1_full, &f3_full);
        let llvm = rows.iter().find(|r| r.runtime == "llvm-openmp").unwrap();
        // All-degrading runtime floors at 1.0, not 0.5.
        assert!((llvm.value - 1.0).abs() < 1e-12);
        let relic = rows.iter().find(|r| r.runtime == "relic").unwrap();
        assert!((relic.value - 1.5).abs() < 1e-12);
        drop(f1);
    }

    #[test]
    fn paper_reference_values_sane() {
        assert!(paper_fig4("relic").unwrap() > paper_fig4("llvm-openmp").unwrap());
        assert!(paper_section5_geomean("gnu-openmp").unwrap() < 1.0);
        assert_eq!(paper_fig1("pr", "gnu-openmp"), Some(1.665));
    }

    #[test]
    fn granularity_rows_cover_all_kernels() {
        let rows = granularity(&cfg());
        assert_eq!(rows.len(), KERNEL_NAMES.len());
        for r in &rows {
            // Calibration holds each to ±7% of the paper's time.
            assert!(
                (r.micros - r.paper_micros).abs() / r.paper_micros < 0.08,
                "{}: {} vs {}",
                r.kernel,
                r.micros,
                r.paper_micros
            );
        }
    }

    #[test]
    fn intra_kernel_rows_cover_all_and_verify_checksums() {
        // Tiny iteration counts: this checks plumbing + the built-in
        // checksum assertion (for every schedule), not timing quality.
        let relic = crate::relic::Relic::new();
        for schedule in crate::relic::Schedule::all() {
            let rows = intra_kernel(&relic, schedule, 3, 1);
            assert_eq!(rows.len(), KERNEL_NAMES.len());
            for r in &rows {
                assert!(r.serial_ns > 0.0, "{} ({schedule})", r.kernel);
                assert!(
                    r.pair_speedup > 0.0 && r.parallel_for_speedup > 0.0,
                    "{} ({schedule})",
                    r.kernel
                );
            }
            let s = render_intra(&rows);
            for k in KERNEL_NAMES {
                assert!(s.contains(k), "render missing {k}");
            }
        }
    }

    #[test]
    fn pool_scaling_verifies_and_renders() {
        // Tiny sweep: plumbing + the built-in checksum equivalence, not
        // timing quality. Unpinned so affinity-restricted CI works.
        let template = crate::coordinator::EngineConfig {
            pool: crate::relic::PoolConfig {
                pin: false,
                ..crate::relic::PoolConfig::default()
            },
            ..crate::coordinator::EngineConfig::default()
        };
        let rows = pool_scaling(&template, &[1, 2], 8, 1);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].shards, 1);
        assert_eq!(rows[1].shards, 2);
        for r in &rows {
            assert!(r.batch_ms > 0.0);
            assert!(r.throughput_rps > 0.0);
            assert!(r.speedup > 0.0);
        }
        assert!((rows[0].speedup - 1.0).abs() < 1e-12, "1-shard row is the baseline");
        let s = render_pool_scaling(&rows);
        assert!(s.contains("shards"));
        assert!(s.contains("req/s"));
        let json = pool_rows_to_json(&rows);
        assert!(json.contains("\"shards\""));
        assert!(json.contains("\"throughput_rps\""));
    }

    #[test]
    fn render_matrix_contains_all_kernels() {
        let cells = vec![Cell {
            kernel: "bc".into(),
            runtime: "relic".into(),
            speedup: 1.5,
            paper: Some(1.361),
        }];
        let s = render_matrix(&cells);
        assert!(s.contains("bc"));
        assert!(s.contains("1.500(1.36)"));
    }
}
