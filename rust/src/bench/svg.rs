//! SVG bar-chart rendering for the paper's figures — `repro fig1 --out
//! results` emits `fig1.svg`/`fig3.svg`/`fig4.svg` in the visual style
//! of the paper (grouped bars of speedup-over-serial, unit line marked).

use super::figures::{Cell, SummaryRow};

/// Chart geometry.
const BAR_W: f64 = 14.0;
const GROUP_GAP: f64 = 18.0;
const PLOT_H: f64 = 260.0;
const MARGIN_L: f64 = 56.0;
const MARGIN_TOP: f64 = 30.0;
const MARGIN_BOT: f64 = 70.0;

/// Color palette (one per runtime, stable order).
const COLORS: [&str; 8] = [
    "#4e79a7", "#f28e2b", "#e15759", "#76b7b2", "#59a14f", "#edc948", "#b07aa1",
    "#1b9e77",
];

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// Render grouped bars: kernels on the x-axis, one bar per runtime.
pub fn grouped_bars(title: &str, cells: &[Cell]) -> String {
    let kernels: Vec<&str> = {
        let mut seen = Vec::new();
        for c in cells {
            if !seen.contains(&c.kernel.as_str()) {
                seen.push(&c.kernel);
            }
        }
        seen
    };
    let runtimes: Vec<&str> = {
        let mut seen = Vec::new();
        for c in cells {
            if !seen.contains(&c.runtime.as_str()) {
                seen.push(&c.runtime);
            }
        }
        seen
    };
    let max_v = cells.iter().map(|c| c.speedup).fold(2.0_f64, f64::max) * 1.05;
    let group_w = runtimes.len() as f64 * BAR_W + GROUP_GAP;
    let width = MARGIN_L + kernels.len() as f64 * group_w + 160.0; // legend space
    let height = MARGIN_TOP + PLOT_H + MARGIN_BOT;
    let y_of = |v: f64| MARGIN_TOP + PLOT_H * (1.0 - v / max_v);

    let mut svg = format!(
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{width:.0}" height="{height:.0}" "#
    );
    svg += r#"font-family="sans-serif" font-size="11">"#;
    svg += &format!(
        r#"<text x="{:.0}" y="16" font-size="13" font-weight="bold">{}</text>"#,
        MARGIN_L,
        esc(title)
    );
    // Y axis + gridlines at 0.5 steps.
    let mut v = 0.0;
    while v <= max_v {
        let y = y_of(v);
        let stroke = if (v - 1.0).abs() < 1e-9 { "#888" } else { "#ddd" };
        svg += &format!(
            r#"<line x1="{MARGIN_L:.0}" y1="{y:.1}" x2="{:.0}" y2="{y:.1}" stroke="{stroke}"/>"#,
            MARGIN_L + kernels.len() as f64 * group_w
        );
        svg += &format!(
            r#"<text x="{:.0}" y="{:.1}" text-anchor="end">{v:.1}</text>"#,
            MARGIN_L - 6.0,
            y + 4.0
        );
        v += 0.5;
    }
    // Bars.
    for (ki, kernel) in kernels.iter().enumerate() {
        let gx = MARGIN_L + ki as f64 * group_w;
        for (ri, rt) in runtimes.iter().enumerate() {
            if let Some(c) = cells.iter().find(|c| c.kernel == *kernel && c.runtime == *rt)
            {
                let x = gx + ri as f64 * BAR_W;
                let y = y_of(c.speedup);
                let h = MARGIN_TOP + PLOT_H - y;
                let color = COLORS[ri % COLORS.len()];
                svg += &format!(
                    r#"<rect x="{x:.1}" y="{y:.1}" width="{:.1}" height="{h:.1}" fill="{color}">"#,
                    BAR_W - 2.0
                );
                svg += &format!(
                    r#"<title>{}/{}: {:.3}</title></rect>"#,
                    esc(kernel),
                    esc(rt),
                    c.speedup
                );
                // Paper-reported marker: a black tick at the paper value.
                if let Some(p) = c.paper {
                    let py = y_of(p);
                    svg += &format!(
                        r##"<line x1="{x:.1}" y1="{py:.1}" x2="{:.1}" y2="{py:.1}" "##,
                        x + BAR_W - 2.0
                    );
                    svg += r##"stroke="#000" stroke-width="2"/>"##;
                }
            }
        }
        svg += &format!(
            r#"<text x="{:.1}" y="{:.0}" text-anchor="middle">{}</text>"#,
            gx + (runtimes.len() as f64 * BAR_W) / 2.0,
            MARGIN_TOP + PLOT_H + 16.0,
            esc(kernel)
        );
    }
    // Legend.
    let lx = MARGIN_L + kernels.len() as f64 * group_w + 12.0;
    for (ri, rt) in runtimes.iter().enumerate() {
        let y = MARGIN_TOP + ri as f64 * 16.0;
        svg += &format!(
            r#"<rect x="{lx:.0}" y="{y:.0}" width="12" height="12" fill="{}"/>"#,
            COLORS[ri % COLORS.len()]
        );
        svg += &format!(
            r#"<text x="{:.0}" y="{:.0}">{}</text>"#,
            lx + 16.0,
            y + 10.0,
            esc(rt)
        );
    }
    svg += &format!(
        r##"<text x="{lx:.0}" y="{:.0}" fill="#444">black tick = paper value</text>"##,
        MARGIN_TOP + runtimes.len() as f64 * 16.0 + 16.0
    );
    svg += "</svg>\n";
    svg
}

/// Render Fig. 4-style summary bars (one bar per runtime).
pub fn summary_bars(title: &str, rows: &[SummaryRow]) -> String {
    let cells: Vec<Cell> = rows
        .iter()
        .map(|r| Cell {
            kernel: "average".into(),
            runtime: r.runtime.clone(),
            speedup: r.value,
            paper: r.paper,
        })
        .collect();
    grouped_bars(title, &cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(k: &str, r: &str, s: f64, p: Option<f64>) -> Cell {
        Cell { kernel: k.into(), runtime: r.into(), speedup: s, paper: p }
    }

    #[test]
    fn renders_valid_svg_with_bars_and_ticks() {
        let cells = vec![
            cell("bfs", "relic", 1.3, Some(1.06)),
            cell("bfs", "llvm-openmp", 1.2, None),
            cell("pr", "relic", 1.9, Some(1.81)),
            cell("pr", "llvm-openmp", 1.9, None),
        ];
        let svg = grouped_bars("Figure 3", &cells);
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert_eq!(svg.matches("<rect").count(), 4 + 2, "4 bars + 2 legend swatches");
        assert_eq!(svg.matches("stroke-width=\"2\"").count(), 2, "2 paper ticks");
        assert!(svg.contains("Figure 3"));
        assert!(svg.contains("relic"));
    }

    #[test]
    fn escapes_markup_in_labels() {
        let cells = vec![cell("a<b", "x&y", 1.0, None)];
        let svg = grouped_bars("t", &cells);
        assert!(!svg.contains("a<b"));
        assert!(svg.contains("a&lt;b"));
        assert!(svg.contains("x&amp;y"));
    }

    #[test]
    fn summary_bars_from_rows() {
        let rows = vec![
            SummaryRow { runtime: "relic".into(), value: 1.5, paper: Some(1.42) },
            SummaryRow { runtime: "gnu-openmp".into(), value: 1.1, paper: Some(1.09) },
        ];
        let svg = summary_bars("Figure 4", &rows);
        assert!(svg.contains("Figure 4"));
        assert!(svg.matches("<rect").count() >= 2);
    }
}
