//! PJRT execution of the AOT-compiled JAX/Pallas graph kernels.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Executables are compiled once and
//! cached; the hot path is literal packing + `execute` only — Python is
//! never involved at request time.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::{Entry, Manifest};

/// A PJRT client with a cache of compiled graph-kernel executables.
pub struct GraphExecutor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
    /// Executions performed (for metrics/tests).
    pub executions: u64,
}

impl GraphExecutor {
    /// Create a CPU-PJRT executor over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir).with_context(|| {
            format!("loading manifest from {artifacts_dir:?} (run `make artifacts`)")
        })?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(GraphExecutor { client, manifest, cache: HashMap::new(), executions: 0 })
    }

    /// Executor over the default artifacts directory.
    pub fn from_default_dir() -> Result<Self> {
        Self::new(&Manifest::default_dir())
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Kernels available in the manifest.
    pub fn available(&self) -> Vec<(String, usize)> {
        self.manifest.entries.iter().map(|e| (e.kernel.clone(), e.n)).collect()
    }

    fn entry(&self, kernel: &str, n: usize) -> Result<Entry> {
        self.manifest
            .find(kernel, n)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no artifact for kernel {kernel} at n={n}"))
    }

    /// Compile (or fetch from cache) the executable for `kernel`/`n`.
    pub fn prepare(&mut self, kernel: &str, n: usize) -> Result<()> {
        if self.cache.contains_key(&(kernel.to_string(), n)) {
            return Ok(());
        }
        let entry = self.entry(kernel, n)?;
        let path = self.manifest.path_of(&entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        self.cache.insert((kernel.to_string(), n), exe);
        Ok(())
    }

    /// Execute a graph kernel. `inputs` are row-major f32 buffers whose
    /// shapes must match the manifest entry. Returns the first (only)
    /// output as a flat f32 vector.
    pub fn execute(&mut self, kernel: &str, n: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let entry = self.entry(kernel, n)?;
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "kernel {kernel} expects {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
        self.prepare(kernel, n)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&entry.inputs) {
            let expect: usize = shape.iter().product();
            anyhow::ensure!(
                buf.len() == expect,
                "kernel {kernel} input shape {shape:?} needs {expect} elems, got {}",
                buf.len()
            );
            let lit = xla::Literal::vec1(buf);
            let lit = if shape.len() == 1 {
                lit
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).context("literal reshape")?
            };
            literals.push(lit);
        }
        let exe = self.cache.get(&(kernel.to_string(), n)).expect("prepared above");
        let result = exe.execute::<xla::Literal>(&literals).context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("device-to-host")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrap output tuple")?;
        let values = out.to_vec::<f32>().context("output to f32 vec")?;
        self.executions += 1;
        Ok(values)
    }
}

#[cfg(test)]
mod tests {
    //! Full round-trip tests live in `rust/tests/pjrt_roundtrip.rs`
    //! (they need `make artifacts`); here we cover the error paths that
    //! don't require artifacts.
    use super::*;

    #[test]
    fn missing_artifacts_dir_is_a_clean_error() {
        let err = GraphExecutor::new(Path::new("/nonexistent/artifacts"))
            .err()
            .expect("must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }
}
