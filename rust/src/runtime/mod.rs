//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + manifest) and executes them on
//! the CPU PJRT client from the Rust hot path.
//!
//! Layering (see the repository README): Python/JAX/Pallas runs once at
//! build time (`make artifacts`); this module is the only component
//! that touches the XLA runtime, and the coordinator calls it through
//! [`GraphExecutor`].

mod exec;
pub mod manifest;

pub use exec::GraphExecutor;
pub use manifest::{Entry, Manifest};
