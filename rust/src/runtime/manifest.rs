//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. `artifacts/manifest.json` lists every lowered HLO
//! module with its kernel name, graph size, and input shapes.

use std::path::{Path, PathBuf};

use crate::json::{self, Value};

/// One AOT-compiled kernel artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    /// Kernel name (`pagerank`, `bfs`, `sssp`, `cc`, `tc`, `bc`).
    pub kernel: String,
    /// Graph size the module was lowered for.
    pub n: usize,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
    /// Input tensor shapes (row-major).
    pub inputs: Vec<Vec<usize>>,
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read(dir.join("manifest.json"))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        Self::from_value(dir, &v)
    }

    fn from_value(dir: &Path, v: &Value) -> anyhow::Result<Self> {
        anyhow::ensure!(
            v["format"].as_str() == Some("hlo-text"),
            "unsupported artifact format {:?}; expected hlo-text",
            v["format"]
        );
        let entries = v["entries"]
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| {
                Ok(Entry {
                    kernel: e["kernel"]
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("entry missing kernel"))?
                        .to_string(),
                    n: e["n"].as_u64().ok_or_else(|| anyhow::anyhow!("entry missing n"))?
                        as usize,
                    file: e["file"]
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("entry missing file"))?
                        .to_string(),
                    inputs: e["inputs"]
                        .as_array()
                        .ok_or_else(|| anyhow::anyhow!("entry missing inputs"))?
                        .iter()
                        .map(|shape| {
                            shape
                                .as_array()
                                .unwrap_or(&[])
                                .iter()
                                .map(|d| d.as_u64().unwrap_or(0) as usize)
                                .collect()
                        })
                        .collect(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Find the entry for a kernel at size `n`.
    pub fn find(&self, kernel: &str, n: usize) -> Option<&Entry> {
        self.entries.iter().find(|e| e.kernel == kernel && e.n == n)
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Default artifacts directory: `$RELIC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("RELIC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &[u8] = br#"{
        "format": "hlo-text",
        "return_tuple": true,
        "entries": [
            {"kernel": "pagerank", "n": 32, "file": "pagerank_n32.hlo.txt",
             "inputs": [[32, 32], [32]], "outputs": 1},
            {"kernel": "tc", "n": 32, "file": "tc_n32.hlo.txt",
             "inputs": [[32, 32]], "outputs": 1}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(Path::new("/tmp/a"), &v).unwrap();
        assert_eq!(m.entries.len(), 2);
        let pr = m.find("pagerank", 32).unwrap();
        assert_eq!(pr.inputs, vec![vec![32, 32], vec![32]]);
        assert_eq!(m.path_of(pr), PathBuf::from("/tmp/a/pagerank_n32.hlo.txt"));
        assert!(m.find("pagerank", 64).is_none());
        assert!(m.find("bogus", 32).is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let v = json::parse(br#"{"format": "proto", "entries": []}"#).unwrap();
        assert!(Manifest::from_value(Path::new("."), &v).is_err());
    }
}
