//! Single-source shortest paths — delta-stepping (GAP `sssp`).
//!
//! GAP's serial delta-stepping with integer weights in `[1, 255]`.
//! On the paper's input this is the coarsest task (6.4 µs) and the
//! benchmark every framework manages to accelerate (Fig. 1).

use std::sync::atomic::{AtomicU32, Ordering};

use crate::probe::Probe;
use crate::relic::{ExecutionPlan, Grain, Par, Schedule};

use super::csr::balanced_boundary;
use super::CsrGraph;

const DIST_BASE: u64 = 0x5500_0000;
const BUCKET_BASE: u64 = 0x5600_0000;

/// Minimum frontier entries per fork-join chunk in [`delta_stepping_par`].
const PAR_GRAIN: usize = 8;

/// GAP's default delta for Kronecker inputs with weights in [1, 255].
pub const DEFAULT_DELTA: u32 = 64;

/// Delta-stepping SSSP; returns per-vertex distance, `u32::MAX` if
/// unreachable. Panics if the graph is unweighted.
pub fn delta_stepping<P: Probe>(
    g: &CsrGraph,
    source: u32,
    delta: u32,
    probe: &mut P,
) -> Vec<u32> {
    assert!(g.is_weighted(), "SSSP requires a weighted graph");
    assert!(delta > 0);
    let n = g.num_vertices();
    let mut dist = vec![u32::MAX; n];
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new()];
    dist[source as usize] = 0;
    buckets[0].push(source);
    probe.store(DIST_BASE + source as u64 * 4);
    probe.store(BUCKET_BASE);

    let mut i = 0usize;
    while i < buckets.len() {
        // Process bucket i to fixpoint (light-edge re-insertions land back
        // in bucket i; this serial variant processes every settled vertex
        // once per appearance and relies on the distance check to skip
        // stale entries — GAP does the same).
        let mut frontier = std::mem::take(&mut buckets[i]);
        let mut cursor = 0;
        while cursor < frontier.len() {
            let u = frontier[cursor];
            cursor += 1;
            probe.load(BUCKET_BASE + cursor as u64 * 4);
            probe.load(DIST_BASE + u as u64 * 4);
            probe.branch(false);
            let du = dist[u as usize];
            // Stale entry: vertex already settled into an earlier bucket.
            if du == u32::MAX || (du / delta) as usize != i {
                continue;
            }
            g.probe_scan_weighted(u, probe);
            for (v, w) in g.neighbors_weighted(u) {
                let nd = du.saturating_add(w);
                probe.load(DIST_BASE + v as u64 * 4);
                probe.compute(3);
                probe.branch(false);
                if nd < dist[v as usize] {
                    dist[v as usize] = nd;
                    probe.store(DIST_BASE + v as u64 * 4);
                    let b = (nd / delta) as usize;
                    while buckets.len() <= b {
                        buckets.push(Vec::new());
                    }
                    if b == i {
                        frontier.push(v);
                        probe.store(BUCKET_BASE + frontier.len() as u64 * 4);
                    } else {
                        buckets[b].push(v);
                        let slot = (b as u64) * 0x1000 + buckets[b].len() as u64 * 4;
                        probe.store(BUCKET_BASE + slot);
                    }
                }
            }
        }
        i += 1;
    }
    dist
}

/// [`delta_stepping`] with edge relaxation split across the SMT pair.
///
/// Each bucket drains in *waves*: a wave's entries are chunked across
/// the pair, relaxations use an atomic `fetch_min` on the distance, and
/// successful same-bucket improvements form the next wave. Distances
/// only decrease and every bucket still drains to fixpoint before the
/// next one starts, so the result is the exact shortest-distance vector
/// — identical to the serial kernel (which the Dijkstra oracle pins
/// down) for any scheduling. Under [`Schedule::EdgeBalanced`] wave
/// chunks are balanced by their entries' degrees (a per-wave prefix
/// over one reused buffer).
pub fn delta_stepping_par(g: &CsrGraph, source: u32, delta: u32, par: &Par) -> Vec<u32> {
    delta_stepping_grain(g, source, delta, par, PAR_GRAIN)
}

/// [`delta_stepping_par`] under an [`ExecutionPlan`]: the plan picks
/// serial vs pair, the schedule, and the grain (0 defers to this
/// kernel's default). Distances stay identical for every plan.
pub fn delta_stepping_plan(
    g: &CsrGraph,
    source: u32,
    delta: u32,
    par: &Par,
    plan: &ExecutionPlan,
) -> Vec<u32> {
    delta_stepping_grain(g, source, delta, &plan.apply(par), plan.grain_or(PAR_GRAIN))
}

fn delta_stepping_grain(g: &CsrGraph, source: u32, delta: u32, par: &Par, grain: usize) -> Vec<u32> {
    assert!(g.is_weighted(), "SSSP requires a weighted graph");
    assert!(delta > 0);
    let n = g.num_vertices();
    let dist: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    dist[source as usize].store(0, Ordering::Relaxed);
    let edge_balanced = par.schedule() == Schedule::EdgeBalanced;
    let mut wave_work: Vec<u64> = Vec::new();
    let mut buckets: Vec<Vec<u32>> = vec![vec![source]];

    let mut i = 0usize;
    while i < buckets.len() {
        let mut wave = std::mem::take(&mut buckets[i]);
        while !wave.is_empty() {
            let w = &wave;
            // Waves that fit one grain take the serial fast path and
            // never read the prefix — skip building it for them.
            if edge_balanced && w.len() > grain {
                g.degree_prefix_into(w, &mut wave_work);
            }
            let wave_work = &wave_work;
            let bound = |ci: usize, k: usize| balanced_boundary(wave_work, 0, w.len(), ci, k);
            // Relax every edge of the wave's live entries; collect the
            // (bucket, vertex) of each successful improvement per chunk.
            let parts: Vec<Vec<(usize, u32)>> = par.chunk_map(
                0..w.len(),
                Grain::Bounded(grain, &bound),
                |sub| {
                    let mut local: Vec<(usize, u32)> = Vec::new();
                    for idx in sub {
                        let u = w[idx];
                        let du = dist[u as usize].load(Ordering::Relaxed);
                        // Stale entry: settled into an earlier bucket.
                        if du == u32::MAX || (du / delta) as usize != i {
                            continue;
                        }
                        for (v, wt) in g.neighbors_weighted(u) {
                            let nd = du.saturating_add(wt);
                            if nd < dist[v as usize].fetch_min(nd, Ordering::Relaxed) {
                                local.push(((nd / delta) as usize, v));
                            }
                        }
                    }
                    local
                },
            );
            // Sort improvements into buckets on the main thread;
            // same-bucket ones become the next wave (dist >= i*delta
            // along any relaxed path, so b >= i always).
            let mut next_wave = Vec::new();
            for (b, v) in parts.into_iter().flatten() {
                if b == i {
                    next_wave.push(v);
                } else {
                    while buckets.len() <= b {
                        buckets.push(Vec::new());
                    }
                    buckets[b].push(v);
                }
            }
            wave = next_wave;
        }
        i += 1;
    }
    dist.into_iter().map(AtomicU32::into_inner).collect()
}

/// Benchmark checksum: sum of finite distances.
pub fn checksum(dist: &[u32]) -> u64 {
    dist.iter().filter(|&&d| d != u32::MAX).map(|&d| d as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{kronecker, oracle, CsrGraph};
    use crate::probe::NoProbe;

    fn wg(n: usize, edges: &[(u32, u32, u32)]) -> CsrGraph {
        CsrGraph::from_undirected_weighted(n, edges, true)
    }

    #[test]
    fn chooses_lighter_two_hop_path() {
        // 0-2 direct weight 10; 0-1-2 total 3.
        let g = wg(3, &[(0, 2, 10), (0, 1, 1), (1, 2, 2)]);
        assert_eq!(delta_stepping(&g, 0, 4, &mut NoProbe), vec![0, 1, 3]);
    }

    #[test]
    fn unreachable_is_max() {
        let g = wg(3, &[(0, 1, 5)]);
        assert_eq!(delta_stepping(&g, 0, 64, &mut NoProbe), vec![0, 5, u32::MAX]);
    }

    #[test]
    fn matches_dijkstra_oracle_across_deltas() {
        crate::testutil::check(60, |rng| {
            let n = rng.range(1, 48);
            let m = rng.range(0, 3 * n);
            let edges: Vec<(u32, u32, u32)> = (0..m)
                .map(|_| {
                    (
                        rng.below(n as u64) as u32,
                        rng.below(n as u64) as u32,
                        1 + rng.below(255) as u32,
                    )
                })
                .collect();
            let g = wg(n, &edges);
            let src = rng.below(n as u64) as u32;
            let delta = [1u32, 8, 64, 1024][rng.below(4) as usize];
            let got = delta_stepping(&g, src, delta, &mut NoProbe);
            let want = oracle::dijkstra(&g, src);
            if got != want {
                return Err(format!(
                    "sssp mismatch (delta {delta}, src {src}): {got:?} vs {want:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_matches_serial_distances() {
        use crate::relic::Relic;
        let relic = Relic::new();
        crate::testutil::check(30, |rng| {
            let n = rng.range(1, 64);
            let m = rng.range(0, 3 * n);
            let edges: Vec<(u32, u32, u32)> = (0..m)
                .map(|_| {
                    (
                        rng.below(n as u64) as u32,
                        rng.below(n as u64) as u32,
                        1 + rng.below(255) as u32,
                    )
                })
                .collect();
            let g = wg(n, &edges);
            let src = rng.below(n as u64) as u32;
            let delta = [1u32, 8, 64][rng.below(3) as usize];
            let serial = delta_stepping(&g, src, delta, &mut NoProbe);
            for par in [
                Par::Serial,
                Par::Relic(&relic),
                Par::Relic(&relic).with_schedule(Schedule::Dynamic),
                Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced),
            ] {
                if delta_stepping_par(&g, src, delta, &par) != serial {
                    return Err(format!(
                        "sssp {}/serial diverge (delta {delta}, src {src})",
                        par.schedule().name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn paper_graph_sssp_runs() {
        let g = kronecker::paper_graph();
        let d = delta_stepping(&g, 0, DEFAULT_DELTA, &mut NoProbe);
        assert_eq!(d[0], 0);
        assert!(d.iter().filter(|&&x| x != u32::MAX).count() > 16);
    }
}
