//! Incremental graph kernels over a mutable edge-stream view (ISSUE 10).
//!
//! The streaming engine ([`crate::coordinator::stream`]) applies batches
//! of edge insertions to a live graph and re-derives analytics after
//! every batch. Rebuilding the CSR and re-running the kernels from
//! scratch per batch would make each microsecond-scale update pay a
//! full-recompute cost; this module maintains the kernel state
//! *incrementally* instead:
//!
//! * [`DeltaCsr`] — an adjacency overlay over an immutable
//!   [`CsrGraph`]: inserted edges live in per-vertex sorted side lists,
//!   and neighbor iteration merges base + overlay in sorted order, so a
//!   traversal sees **exactly** the neighbor sequence a rebuilt CSR
//!   would produce. That ordering contract is what makes every
//!   incremental kernel bitwise-comparable to a from-scratch run.
//! * [`IncrementalCc`] — connected components by union-find
//!   maintenance. The union rule (larger root attaches under smaller)
//!   keeps each tree's root the minimum vertex id of its component, so
//!   [`IncrementalCc::labels`] is canonical: identical to
//!   [`super::oracle::components_min_label`] and to
//!   [`super::cc::shiloach_vishkin`] regardless of insertion order.
//! * [`DeltaPageRank`] — the serial [`super::pr::pagerank`] power
//!   iteration with a memoized per-iteration trajectory and
//!   residual-driven recomputation: only vertices whose inputs changed
//!   (adjacency deltas, or a neighbor whose score diverged bitwise from
//!   the previous run) re-pull; everything else reuses the memoized
//!   value. The result is **bitwise identical** to running the serial
//!   kernel from scratch on the rebuilt graph — see the module test
//!   `delta_pagerank_bitwise_equals_kernel_on_rebuilt_graph`.
//! * [`DynamicBfs`] — dynamic frontier BFS. Edge insertions only ever
//!   lower depths, so a worklist relaxation from the new edge's
//!   endpoints converges to the unique BFS fixpoint
//!   ([`super::oracle::bfs_depths`]).
//!
//! [`IncrementalAnalytics`] bundles the three kernels behind one
//! `apply_batch` entry point (with [`Par`]-parallel delta
//! classification) and implements the `recompute_interval` escape
//! hatch: every Nth batch the overlay is collapsed into a fresh base
//! CSR and all three kernels are recomputed from scratch — the
//! recomputed state must be bit-identical to the incremental state
//! (checked, counted, and gated by `repro stream` and
//! `tests/stream_correctness.rs`).

use std::collections::VecDeque;

use crate::relic::Par;

use super::pr::{DAMPING, MAX_ITERS, TOLERANCE};
use super::CsrGraph;

/// Minimum batch entries per parallel classification chunk: a
/// classification is two binary searches (~tens of ns), so chunks need
/// enough of them to amortize Relic's submit cost.
const CLASSIFY_GRAIN: usize = 64;

/// A mutable edge-stream view over an immutable [`CsrGraph`]: the base
/// adjacency plus per-vertex sorted overlays of inserted edges.
///
/// **Ordering contract.** [`DeltaCsr::neighbors`] yields the merge of
/// the base's sorted neighbor slice and the sorted overlay — i.e. the
/// ascending neighbor list a [`CsrGraph`] rebuilt from the same edge
/// set would store. Every kernel in this module iterates neighbors
/// exclusively through that merge, so floating-point summation orders
/// (and therefore checksums) match the rebuilt graph bit for bit.
///
/// Weights are deliberately not modeled: the incremental kernels (CC,
/// PR, BFS) are weight-free, and carrying weights through the overlay
/// would complicate the rebuild-equality contract for nothing.
#[derive(Debug, Clone)]
pub struct DeltaCsr {
    base: CsrGraph,
    /// Per-vertex sorted extra neighbors, disjoint from the base lists
    /// (duplicates are rejected at [`DeltaCsr::insert`]).
    extra: Vec<Vec<u32>>,
    /// Undirected edges living in the overlay.
    extra_edges: usize,
}

impl DeltaCsr {
    /// Wrap an unweighted base graph. Panics on a weighted base — the
    /// overlay cannot represent weights, so a rebuild would silently
    /// drop them.
    pub fn new(base: CsrGraph) -> Self {
        assert!(
            !base.is_weighted(),
            "DeltaCsr views the unweighted skeleton; strip weights first"
        );
        let n = base.num_vertices();
        DeltaCsr { base, extra: vec![Vec::new(); n], extra_edges: 0 }
    }

    /// An empty graph on `n` vertices — the usual stream starting point.
    pub fn empty(n: usize) -> Self {
        Self::new(CsrGraph::from_undirected_edges(n, &[]))
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.base.num_vertices()
    }

    /// Number of undirected edges (base + overlay).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.base.num_edges() + self.extra_edges
    }

    /// Undirected edges currently in the overlay (the rebuild pressure
    /// the `recompute_interval` escape hatch relieves).
    #[inline]
    pub fn overlay_edges(&self) -> usize {
        self.extra_edges
    }

    /// Degree of `v` in the merged view.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        self.base.degree(v) + self.extra[v as usize].len()
    }

    /// Merged sorted neighbors of `v` — the rebuilt-CSR iteration order.
    #[inline]
    pub fn neighbors(&self, v: u32) -> MergedNeighbors<'_> {
        MergedNeighbors {
            base: self.base.neighbors(v),
            extra: &self.extra[v as usize],
            i: 0,
            j: 0,
        }
    }

    /// Whether the undirected edge `{u, v}` is present.
    #[inline]
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.base.neighbors(u).binary_search(&v).is_ok()
            || self.extra[u as usize].binary_search(&v).is_ok()
    }

    /// Insert the undirected edge `{u, v}`. Returns `false` (and leaves
    /// the view untouched) for self-loops and duplicates — mirroring
    /// what [`CsrGraph::from_undirected_edges`] drops at build time.
    ///
    /// # Panics
    /// If an endpoint is out of range (malformed wire input must be
    /// rejected by [`DeltaCsr::classify`] / the decode layer first).
    pub fn insert(&mut self, u: u32, v: u32) -> bool {
        let n = self.num_vertices();
        assert!((u as usize) < n && (v as usize) < n, "edge ({u}, {v}) out of range");
        if u == v || self.has_edge(u, v) {
            return false;
        }
        for (a, b) in [(u, v), (v, u)] {
            let list = &mut self.extra[a as usize];
            let pos = list.binary_search(&b).unwrap_err();
            list.insert(pos, b);
        }
        self.extra_edges += 1;
        true
    }

    /// Classify a delta batch in parallel: `true` where the edge is a
    /// well-formed *new* edge against the current (pre-batch) view —
    /// in-range, not a self-loop, not already present. Intra-batch
    /// duplicates still pass here (the read-only snapshot cannot see
    /// them); the serial [`DeltaCsr::insert`] stays authoritative.
    ///
    /// Deterministic under every [`crate::relic::Schedule`]: each slot
    /// is a pure function of `(self, edges[i])` and the writes are
    /// disjoint.
    pub fn classify(&self, edges: &[(u32, u32)], par: &Par) -> Vec<bool> {
        let n = self.num_vertices();
        let mut keep = vec![false; edges.len()];
        par.map_into(&mut keep, CLASSIFY_GRAIN, |i| {
            let (u, v) = edges[i];
            (u as usize) < n && (v as usize) < n && u != v && !self.has_edge(u, v)
        });
        keep
    }

    /// Every undirected edge once, `(u, v)` with `u < v`, ascending.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.num_edges());
        for u in 0..self.num_vertices() as u32 {
            for v in self.neighbors(u) {
                if v > u {
                    out.push((u, v));
                }
            }
        }
        out
    }

    /// Collapse the view into a standalone CSR. The rebuilt graph's
    /// neighbor lists equal this view's merged iteration order exactly
    /// (both are the sorted dedup'd union), which is what the
    /// bitwise-equality contract of every kernel here rests on.
    pub fn rebuild(&self) -> CsrGraph {
        CsrGraph::from_undirected_edges(self.num_vertices(), &self.edges())
    }
}

/// Sorted merge of a base neighbor slice and an overlay slice (the two
/// are disjoint, so no tie-break is ever taken).
pub struct MergedNeighbors<'a> {
    base: &'a [u32],
    extra: &'a [u32],
    i: usize,
    j: usize,
}

impl Iterator for MergedNeighbors<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        match (self.base.get(self.i), self.extra.get(self.j)) {
            (Some(&b), Some(&e)) => {
                if b < e {
                    self.i += 1;
                    Some(b)
                } else {
                    self.j += 1;
                    Some(e)
                }
            }
            (Some(&b), None) => {
                self.i += 1;
                Some(b)
            }
            (None, Some(&e)) => {
                self.j += 1;
                Some(e)
            }
            (None, None) => None,
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = (self.base.len() - self.i) + (self.extra.len() - self.j);
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for MergedNeighbors<'_> {}

/// Incremental connected components: a union-find forest maintained
/// under edge insertions.
///
/// The union rule attaches the *larger* root under the *smaller*, so
/// by induction every tree's root is the minimum vertex id of its
/// component — [`IncrementalCc::labels`] is therefore canonical (a
/// pure function of the edge *set*, not the insertion order) and equal
/// to [`super::oracle::components_min_label`] /
/// [`super::cc::shiloach_vishkin`] on the same graph.
#[derive(Debug, Clone)]
pub struct IncrementalCc {
    parent: Vec<u32>,
}

impl IncrementalCc {
    /// Build from the current edges of a view.
    pub fn new(g: &DeltaCsr) -> Self {
        let mut cc = IncrementalCc { parent: (0..g.num_vertices() as u32).collect() };
        for u in 0..g.num_vertices() as u32 {
            for v in g.neighbors(u) {
                if v > u {
                    cc.union(u, v);
                }
            }
        }
        cc
    }

    /// Root of `v`'s tree, with path halving.
    fn find(&mut self, mut v: u32) -> u32 {
        while self.parent[v as usize] != v {
            let grand = self.parent[self.parent[v as usize] as usize];
            self.parent[v as usize] = grand;
            v = grand;
        }
        v
    }

    /// Record the edge `{u, v}`: merge the two components, min-id root
    /// winning. Idempotent for edges already in one component.
    pub fn union(&mut self, u: u32, v: u32) {
        let (ru, rv) = (self.find(u), self.find(v));
        if ru == rv {
            return;
        }
        let (lo, hi) = if ru < rv { (ru, rv) } else { (rv, ru) };
        self.parent[hi as usize] = lo;
    }

    /// Canonical labels: `labels[v]` = minimum vertex id of `v`'s
    /// component. Read-only (no path compression), so interior forest
    /// shape never leaks into the observable state.
    pub fn labels(&self) -> Vec<u32> {
        self.parent
            .iter()
            .enumerate()
            .map(|(v, _)| {
                let mut r = v as u32;
                while self.parent[r as usize] != r {
                    r = self.parent[r as usize];
                }
                r
            })
            .collect()
    }
}

/// Delta-PageRank with a memoized trajectory and residual-driven
/// recomputation, bitwise-equal to the serial kernel by construction.
///
/// The serial [`super::pr::pagerank`] is a pure Jacobi iteration: each
/// pass scatters `scores[v] / deg(v)` into an `outgoing` buffer, then
/// pulls per-vertex sums *only from that buffer* (the in-place score
/// write never feeds the same iteration), and accumulates the L1 error
/// serially in vertex order. That structure makes the computation
/// *replayable*: vertex `u`'s value at iteration `t` depends only on
/// `u`'s adjacency and its neighbors' scores at `t` — so if none of
/// those inputs changed bitwise since the previous run, the previous
/// run's value **is** the new value, bit for bit.
///
/// [`DeltaPageRank::refresh`] exploits exactly that: it memoizes every
/// iteration's score vector (`MAX_ITERS` × n doubles), and on the next
/// refresh recomputes a vertex's pull sum only when its own adjacency
/// changed or a neighbor is *dirty* (bitwise-diverged from the
/// memoized trajectory) or adjacency-changed — the residual-driven
/// re-push rule, with "residual ≠ 0" decided by exact bit comparison
/// instead of a threshold so no error is ever introduced. The
/// per-iteration L1 error is recomputed serially in full (each term is
/// bitwise equal to the from-scratch term), so the convergence break
/// fires on exactly the same iteration.
#[derive(Debug, Clone)]
pub struct DeltaPageRank {
    max_iters: u32,
    tolerance: f64,
    /// Scores at the end of each completed iteration of the last
    /// refresh (`traj.last()` = the published scores).
    traj: Vec<Vec<f64>>,
    /// Published scores (initial uniform vector until first refresh).
    scores: Vec<f64>,
    /// Vertices whose adjacency changed since the last refresh.
    changed: Vec<bool>,
    changed_list: Vec<u32>,
}

impl DeltaPageRank {
    /// Build and run the initial full computation (GAP defaults:
    /// [`DAMPING`], [`TOLERANCE`], [`MAX_ITERS`]).
    pub fn new(g: &DeltaCsr) -> Self {
        Self::with_limits(g, MAX_ITERS, TOLERANCE)
    }

    /// [`DeltaPageRank::new`] with explicit iteration cap / tolerance
    /// (tests drive small caps to cross the early-exit boundary).
    pub fn with_limits(g: &DeltaCsr, max_iters: u32, tolerance: f64) -> Self {
        let n = g.num_vertices();
        let mut pr = DeltaPageRank {
            max_iters,
            tolerance,
            traj: Vec::new(),
            scores: if n == 0 { Vec::new() } else { vec![1.0 / n as f64; n] },
            changed: vec![false; n],
            changed_list: Vec::new(),
        };
        pr.refresh(g);
        pr
    }

    /// Mark both endpoints of an applied edge as adjacency-changed.
    /// Call once per accepted insertion, before the next `refresh`.
    pub fn note_insert(&mut self, u: u32, v: u32) {
        for x in [u, v] {
            if !self.changed[x as usize] {
                self.changed[x as usize] = true;
                self.changed_list.push(x);
            }
        }
    }

    /// Re-derive the scores for the view's current edge set. Bitwise
    /// identical to running the serial kernel from scratch on
    /// `g.rebuild()`; the memoized trajectory only skips pull sums
    /// whose inputs are provably (bitwise) unchanged.
    pub fn refresh(&mut self, g: &DeltaCsr) {
        let n = g.num_vertices();
        if n == 0 {
            return;
        }
        let base = (1.0 - DAMPING) / n as f64;
        let old = std::mem::take(&mut self.traj);
        let mut scores = vec![1.0 / n as f64; n];
        let mut outgoing = vec![0.0f64; n];
        // Vertices whose pull inputs this iteration may differ from the
        // memoized run: recomputed fresh each iteration below.
        let mut recompute = vec![false; n];
        // `dirty`: scores[v] differs bitwise from the memoized run at
        // the same point. Both runs start from the uniform vector.
        let mut dirty_list: Vec<u32> = Vec::new();

        for t in 0..self.max_iters as usize {
            // Scatter. Every value is bitwise the from-scratch value
            // because `scores` is (inductively) and degrees are current.
            for (v, out) in outgoing.iter_mut().enumerate() {
                let deg = g.degree(v as u32);
                *out = if deg > 0 { scores[v] / deg as f64 } else { 0.0 };
            }
            let memo = old.get(t);
            // Residual-driven marking: a vertex re-pulls when its own
            // adjacency changed, or a neighbor's outgoing contribution
            // differs from the memoized run (score dirty or degree
            // changed). With no memoized iteration, everything re-pulls.
            recompute.fill(memo.is_none());
            if memo.is_some() {
                for &v in &self.changed_list {
                    recompute[v as usize] = true;
                    for w in g.neighbors(v) {
                        recompute[w as usize] = true;
                    }
                }
                for &v in &dirty_list {
                    for w in g.neighbors(v) {
                        recompute[w as usize] = true;
                    }
                }
            }
            // Pull + serial error accumulation, exactly the kernel's
            // in-place single pass (reads only `outgoing`).
            dirty_list.clear();
            let mut error = 0.0;
            for u in 0..n {
                let new = if recompute[u] {
                    let mut incoming = 0.0;
                    for v in g.neighbors(u as u32) {
                        incoming += outgoing[v as usize];
                    }
                    base + DAMPING * incoming
                } else {
                    memo.expect("reuse implies a memoized iteration")[u]
                };
                error += (new - scores[u]).abs();
                if recompute[u] {
                    if let Some(m) = memo {
                        if new.to_bits() != m[u].to_bits() {
                            dirty_list.push(u as u32);
                        }
                    }
                }
                scores[u] = new;
            }
            self.traj.push(scores.clone());
            if error < self.tolerance {
                break;
            }
        }
        self.scores = scores;
        for &v in &self.changed_list {
            self.changed[v as usize] = false;
        }
        self.changed_list.clear();
    }

    /// The current scores (after the last `refresh`).
    pub fn scores(&self) -> &[f64] {
        &self.scores
    }

    /// The serial kernel run from scratch over a view — the oracle the
    /// incremental path is bitwise-gated against. Identical to
    /// [`super::pr::pagerank`] on `g.rebuild()` (same iteration
    /// structure over the same sorted neighbor order).
    pub fn from_scratch(g: &DeltaCsr, max_iters: u32, tolerance: f64) -> Vec<f64> {
        let n = g.num_vertices();
        if n == 0 {
            return Vec::new();
        }
        let base = (1.0 - DAMPING) / n as f64;
        let mut scores = vec![1.0 / n as f64; n];
        let mut outgoing = vec![0.0f64; n];
        for _ in 0..max_iters {
            for (v, out) in outgoing.iter_mut().enumerate() {
                let deg = g.degree(v as u32);
                *out = if deg > 0 { scores[v] / deg as f64 } else { 0.0 };
            }
            let mut error = 0.0;
            for u in 0..n {
                let mut incoming = 0.0;
                for v in g.neighbors(u as u32) {
                    incoming += outgoing[v as usize];
                }
                let new = base + DAMPING * incoming;
                error += (new - scores[u]).abs();
                scores[u] = new;
            }
            if error < tolerance {
                break;
            }
        }
        scores
    }
}

/// Dynamic frontier BFS: depths from a fixed source maintained under
/// edge insertions.
///
/// Insertions only ever *lower* depths, so relaxing outward from each
/// new edge's endpoints converges to the unique fixpoint — the true
/// BFS depth vector ([`super::oracle::bfs_depths`], `u32::MAX` =
/// unreachable). Depths are integers, so bitwise equality is exact
/// equality.
#[derive(Debug, Clone)]
pub struct DynamicBfs {
    source: u32,
    depth: Vec<u32>,
}

impl DynamicBfs {
    /// Full BFS over the view's current edges.
    pub fn new(g: &DeltaCsr, source: u32) -> Self {
        DynamicBfs { source, depth: Self::from_scratch(g, source) }
    }

    /// The BFS source.
    pub fn source(&self) -> u32 {
        self.source
    }

    /// Account for the (already applied) insertion of `{u, v}`:
    /// worklist relaxation from whichever endpoint the new edge
    /// improves, then outward until no depth can drop further.
    pub fn insert(&mut self, g: &DeltaCsr, u: u32, v: u32) {
        let mut work: VecDeque<u32> = VecDeque::new();
        let (du, dv) = (self.depth[u as usize], self.depth[v as usize]);
        if du != u32::MAX && du + 1 < dv {
            self.depth[v as usize] = du + 1;
            work.push_back(v);
        } else if dv != u32::MAX && dv + 1 < du {
            self.depth[u as usize] = dv + 1;
            work.push_back(u);
        }
        while let Some(w) = work.pop_front() {
            let dw = self.depth[w as usize];
            for x in g.neighbors(w) {
                if dw + 1 < self.depth[x as usize] {
                    self.depth[x as usize] = dw + 1;
                    work.push_back(x);
                }
            }
        }
    }

    /// Current depths (`u32::MAX` = unreachable).
    pub fn depths(&self) -> &[u32] {
        &self.depth
    }

    /// Full BFS oracle over a view (matches
    /// [`super::oracle::bfs_depths`] on the rebuilt graph).
    pub fn from_scratch(g: &DeltaCsr, source: u32) -> Vec<u32> {
        let n = g.num_vertices();
        let mut depth = vec![u32::MAX; n];
        if n == 0 {
            return depth;
        }
        depth[source as usize] = 0;
        let mut queue: VecDeque<u32> = VecDeque::new();
        queue.push_back(source);
        while let Some(u) = queue.pop_front() {
            let du = depth[u as usize];
            for v in g.neighbors(u) {
                if depth[v as usize] == u32::MAX {
                    depth[v as usize] = du + 1;
                    queue.push_back(v);
                }
            }
        }
        depth
    }
}

/// Outcome of one applied delta batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchOutcome {
    /// Edges actually inserted.
    pub accepted: usize,
    /// Self-loops, duplicates (inter- or intra-batch), out-of-range.
    pub rejected: usize,
    /// Whether this batch tripped the `recompute_interval` escape hatch.
    pub recomputed: bool,
    /// When `recomputed`: did the from-scratch state match the
    /// incremental state bit for bit? (`true` when not recomputed.)
    pub recompute_matched: bool,
}

/// The three incremental kernels behind one batch-apply entry point,
/// plus the `recompute_interval` escape hatch.
#[derive(Debug)]
pub struct IncrementalAnalytics {
    graph: DeltaCsr,
    cc: IncrementalCc,
    pr: DeltaPageRank,
    bfs: DynamicBfs,
    /// Rebuild-and-recompute from scratch every N batches (0 = never).
    /// The recomputed state must equal the incremental state bitwise —
    /// the escape hatch doubles as a continuous self-check.
    recompute_interval: usize,
    batches_applied: usize,
    recomputes: u64,
    recompute_mismatches: u64,
}

impl IncrementalAnalytics {
    /// Start from an existing (unweighted) base graph.
    pub fn new(base: CsrGraph, source: u32, recompute_interval: usize) -> Self {
        let graph = DeltaCsr::new(base);
        let cc = IncrementalCc::new(&graph);
        let pr = DeltaPageRank::new(&graph);
        let bfs = DynamicBfs::new(&graph, source);
        IncrementalAnalytics {
            graph,
            cc,
            pr,
            bfs,
            recompute_interval,
            batches_applied: 0,
            recomputes: 0,
            recompute_mismatches: 0,
        }
    }

    /// Start from an empty graph on `n` vertices.
    pub fn empty(n: usize, source: u32, recompute_interval: usize) -> Self {
        Self::new(CsrGraph::from_undirected_edges(n, &[]), source, recompute_interval)
    }

    /// Apply one delta batch: classify in parallel (`par`), insert the
    /// survivors serially in batch order (the authoritative dedup),
    /// update CC/BFS per edge, refresh PageRank once, then — every
    /// `recompute_interval` batches — rebuild from scratch and swap the
    /// recomputed state in after checking it matches bitwise.
    pub fn apply_batch(&mut self, edges: &[(u32, u32)], par: &Par) -> BatchOutcome {
        let keep = self.graph.classify(edges, par);
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        for (i, &(u, v)) in edges.iter().enumerate() {
            if keep[i] && self.graph.insert(u, v) {
                self.cc.union(u, v);
                self.bfs.insert(&self.graph, u, v);
                self.pr.note_insert(u, v);
                accepted += 1;
            } else {
                rejected += 1;
            }
        }
        self.pr.refresh(&self.graph);
        self.batches_applied += 1;
        let due = self.recompute_interval > 0
            && self.batches_applied % self.recompute_interval == 0;
        let matched = if due { self.recompute_from_scratch() } else { true };
        BatchOutcome { accepted, rejected, recomputed: due, recompute_matched: matched }
    }

    /// The escape hatch: collapse the overlay into a fresh base CSR,
    /// recompute all three kernels from scratch on it, verify the
    /// states match the incremental ones bit for bit, and swap the
    /// fresh state in (resetting overlay growth and trajectory noise).
    /// Returns whether the states matched; a mismatch is counted and
    /// the *recomputed* (ground-truth) state still wins.
    fn recompute_from_scratch(&mut self) -> bool {
        self.recomputes += 1;
        let fresh_graph = DeltaCsr::new(self.graph.rebuild());
        let fresh_cc = IncrementalCc::new(&fresh_graph);
        let fresh_pr = DeltaPageRank::new(&fresh_graph);
        let fresh_bfs = DynamicBfs::new(&fresh_graph, self.bfs.source());
        let bits = |s: &[f64]| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let matched = fresh_cc.labels() == self.cc.labels()
            && bits(fresh_pr.scores()) == bits(self.pr.scores())
            && fresh_bfs.depths() == self.bfs.depths();
        if !matched {
            self.recompute_mismatches += 1;
        }
        self.graph = fresh_graph;
        self.cc = fresh_cc;
        self.pr = fresh_pr;
        self.bfs = fresh_bfs;
        matched
    }

    /// The live graph view.
    pub fn graph(&self) -> &DeltaCsr {
        &self.graph
    }

    /// Canonical component labels (min vertex id per component).
    pub fn cc_labels(&self) -> Vec<u32> {
        self.cc.labels()
    }

    /// Current PageRank scores.
    pub fn pr_scores(&self) -> &[f64] {
        self.pr.scores()
    }

    /// Current BFS depths from the configured source.
    pub fn bfs_depths(&self) -> &[u32] {
        self.bfs.depths()
    }

    /// `(cc, pr, bfs)` checksums in the kernels' own reductions —
    /// comparable against [`super::cc::checksum`] /
    /// [`super::pr::checksum`] / [`super::bfs::checksum`] of a
    /// from-scratch run on the rebuilt graph.
    pub fn checksums(&self) -> (u64, u64, u64) {
        (
            super::cc::checksum(&self.cc.labels()),
            super::pr::checksum(self.pr.scores()),
            super::bfs::checksum(self.bfs.depths()),
        )
    }

    /// Batches applied so far.
    pub fn batches_applied(&self) -> usize {
        self.batches_applied
    }

    /// Escape-hatch rebuilds performed so far.
    pub fn recomputes(&self) -> u64 {
        self.recomputes
    }

    /// Escape-hatch rebuilds whose state did NOT match the incremental
    /// state (always 0 unless the bitwise contract is broken — gated by
    /// `repro stream` and the stream correctness tests).
    pub fn recompute_mismatches(&self) -> u64 {
        self.recompute_mismatches
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{bfs, cc, oracle, pr};
    use crate::probe::NoProbe;
    use crate::relic::{Par, Relic, Schedule};
    use crate::testutil::Rng;

    /// Seeded random edge stream (mix of fresh edges, duplicates, and
    /// self-loops) over `n` vertices.
    fn random_edges(rng: &mut Rng, n: usize, count: usize) -> Vec<(u32, u32)> {
        (0..count)
            .map(|_| {
                let u = rng.below(n as u64) as u32;
                // ~1/8 self-loops to exercise rejection.
                let v = if rng.below(8) == 0 { u } else { rng.below(n as u64) as u32 };
                (u, v)
            })
            .collect()
    }

    #[test]
    fn merged_neighbors_match_rebuilt_csr() {
        crate::testutil::check(20, |rng| {
            let n = 2 + rng.below(60) as usize;
            let mut g = DeltaCsr::empty(n);
            for (u, v) in random_edges(rng, n, 4 * n) {
                g.insert(u, v);
            }
            let rebuilt = g.rebuild();
            for v in 0..n as u32 {
                let merged: Vec<u32> = g.neighbors(v).collect();
                if merged != rebuilt.neighbors(v) {
                    return Err(format!("vertex {v}: merged {merged:?}"));
                }
                if g.degree(v) != rebuilt.degree(v) {
                    return Err(format!("vertex {v}: degree mismatch"));
                }
            }
            if g.num_edges() != rebuilt.num_edges() {
                return Err("edge count mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn insert_rejects_self_loops_and_duplicates() {
        let mut g = DeltaCsr::empty(4);
        assert!(!g.insert(2, 2), "self-loop");
        assert!(g.insert(0, 1));
        assert!(!g.insert(0, 1), "duplicate");
        assert!(!g.insert(1, 0), "reversed duplicate");
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn insert_panics_out_of_range() {
        DeltaCsr::empty(3).insert(0, 7);
    }

    #[test]
    fn overlay_over_nonempty_base() {
        let base = CsrGraph::from_undirected_edges(5, &[(0, 1), (1, 2)]);
        let mut g = DeltaCsr::new(base);
        assert!(g.has_edge(0, 1));
        assert!(!g.insert(1, 2), "base edges count as duplicates");
        assert!(g.insert(2, 3));
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(2).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn classify_agrees_with_serial_under_every_schedule() {
        let relic = Relic::new();
        crate::testutil::check(8, |rng| {
            let n = 2 + rng.below(40) as usize;
            let mut g = DeltaCsr::empty(n);
            for (u, v) in random_edges(rng, n, 2 * n) {
                g.insert(u, v);
            }
            let batch = random_edges(rng, n, 3 * n);
            let want = g.classify(&batch, &Par::Serial);
            for sched in Schedule::all() {
                let got = g.classify(&batch, &Par::Relic(&relic).with_schedule(sched));
                if got != want {
                    return Err(format!("schedule {} diverged", sched.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn incremental_cc_matches_oracle_and_kernel() {
        crate::testutil::check(15, |rng| {
            let n = 2 + rng.below(50) as usize;
            let mut g = DeltaCsr::empty(n);
            let mut cc = IncrementalCc::new(&g);
            for (u, v) in random_edges(rng, n, 5 * n) {
                if g.insert(u, v) {
                    cc.union(u, v);
                }
            }
            let rebuilt = g.rebuild();
            let labels = cc.labels();
            if labels != oracle::components_min_label(&rebuilt) {
                return Err("labels != oracle".into());
            }
            if labels != cc::shiloach_vishkin(&rebuilt, &mut NoProbe) {
                return Err("labels != shiloach_vishkin".into());
            }
            Ok(())
        });
    }

    #[test]
    fn delta_pagerank_bitwise_equals_kernel_on_rebuilt_graph() {
        crate::testutil::check(10, |rng| {
            let n = 2 + rng.below(40) as usize;
            let mut g = DeltaCsr::empty(n);
            let mut dpr = DeltaPageRank::new(&g);
            // Several checkpoints so the trajectory is actually reused.
            for _ in 0..4 {
                for (u, v) in random_edges(rng, n, n) {
                    if g.insert(u, v) {
                        dpr.note_insert(u, v);
                    }
                }
                dpr.refresh(&g);
                let kernel =
                    pr::pagerank(&g.rebuild(), pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe);
                let got: Vec<u64> = dpr.scores().iter().map(|s| s.to_bits()).collect();
                let want: Vec<u64> = kernel.iter().map(|s| s.to_bits()).collect();
                if got != want {
                    return Err(format!("scores diverged at {} edges", g.num_edges()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn delta_pagerank_handles_iteration_count_shifts() {
        // A tiny iteration cap + loose tolerance makes the early-exit
        // boundary move between refreshes; bitwise equality must hold
        // whether the new run is shorter or longer than the memo.
        crate::testutil::check(10, |rng| {
            let n = 2 + rng.below(30) as usize;
            let mut g = DeltaCsr::empty(n);
            let mut dpr = DeltaPageRank::with_limits(&g, 5, 1e-2);
            for _ in 0..5 {
                for (u, v) in random_edges(rng, n, n / 2 + 1) {
                    if g.insert(u, v) {
                        dpr.note_insert(u, v);
                    }
                }
                dpr.refresh(&g);
                let want = DeltaPageRank::from_scratch(&g, 5, 1e-2);
                let got: Vec<u64> = dpr.scores().iter().map(|s| s.to_bits()).collect();
                let want: Vec<u64> = want.iter().map(|s| s.to_bits()).collect();
                if got != want {
                    return Err(format!("diverged at {} edges", g.num_edges()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn from_scratch_matches_kernel_on_view() {
        crate::testutil::check(10, |rng| {
            let n = 2 + rng.below(40) as usize;
            let mut g = DeltaCsr::empty(n);
            for (u, v) in random_edges(rng, n, 3 * n) {
                g.insert(u, v);
            }
            let view = DeltaPageRank::from_scratch(&g, pr::MAX_ITERS, pr::TOLERANCE);
            let kernel = pr::pagerank(&g.rebuild(), pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe);
            let view: Vec<u64> = view.iter().map(|s| s.to_bits()).collect();
            let kernel: Vec<u64> = kernel.iter().map(|s| s.to_bits()).collect();
            if view != kernel {
                return Err("view run != kernel on rebuilt graph".into());
            }
            Ok(())
        });
    }

    #[test]
    fn dynamic_bfs_matches_oracle_at_every_insertion() {
        crate::testutil::check(10, |rng| {
            let n = 2 + rng.below(40) as usize;
            let source = rng.below(n as u64) as u32;
            let mut g = DeltaCsr::empty(n);
            let mut dbfs = DynamicBfs::new(&g, source);
            for (u, v) in random_edges(rng, n, 4 * n) {
                if g.insert(u, v) {
                    dbfs.insert(&g, u, v);
                    if dbfs.depths() != oracle::bfs_depths(&g.rebuild(), source) {
                        return Err(format!("depths diverged after ({u}, {v})"));
                    }
                }
            }
            if dbfs.depths() != bfs::bfs(&g.rebuild(), source, &mut NoProbe) {
                return Err("final depths != bfs kernel".into());
            }
            Ok(())
        });
    }

    #[test]
    fn analytics_escape_hatch_matches_and_resets_overlay() {
        let relic = Relic::new();
        let par = Par::Relic(&relic);
        let mut rng = Rng::new(42);
        let mut an = IncrementalAnalytics::empty(64, 0, 2);
        for round in 0..6 {
            let batch = random_edges(&mut rng, 64, 48);
            let out = an.apply_batch(&batch, &par);
            assert!(out.recompute_matched, "round {round}: escape hatch diverged");
            assert_eq!(out.recomputed, (round + 1) % 2 == 0);
            if out.recomputed {
                assert_eq!(an.graph().overlay_edges(), 0, "rebuild collapses the overlay");
            }
        }
        assert_eq!(an.recomputes(), 3);
        assert_eq!(an.recompute_mismatches(), 0);
        assert_eq!(an.batches_applied(), 6);
    }

    #[test]
    fn analytics_checksums_match_kernels_on_rebuilt_graph() {
        let mut rng = Rng::new(7);
        let mut an = IncrementalAnalytics::empty(50, 3, 0);
        for _ in 0..4 {
            let batch = random_edges(&mut rng, 50, 40);
            an.apply_batch(&batch, &Par::Serial);
        }
        let g = an.graph().rebuild();
        let (ccs, prs, bfss) = an.checksums();
        assert_eq!(ccs, cc::checksum(&cc::shiloach_vishkin(&g, &mut NoProbe)));
        assert_eq!(
            prs,
            pr::checksum(&pr::pagerank(&g, pr::MAX_ITERS, pr::TOLERANCE, &mut NoProbe))
        );
        assert_eq!(bfss, bfs::checksum(&bfs::bfs(&g, 3, &mut NoProbe)));
    }

    #[test]
    fn analytics_counts_accepted_and_rejected() {
        let mut an = IncrementalAnalytics::empty(8, 0, 0);
        // 2 good edges, 1 self-loop, 1 intra-batch duplicate.
        let out = an.apply_batch(&[(0, 1), (2, 3), (4, 4), (0, 1)], &Par::Serial);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 2);
        assert!(!out.recomputed);
        assert!(out.recompute_matched);
        assert_eq!(an.graph().num_edges(), 2);
    }
}
