//! Compressed sparse row graph — the GAP benchmark data structure.

use crate::probe::Probe;

/// Logical probe-address bases for the CSR arrays (see `probe` docs).
pub const OFFSETS_BASE: u64 = 0x4000_0000;
pub const TARGETS_BASE: u64 = 0x4100_0000;
pub const WEIGHTS_BASE: u64 = 0x4200_0000;

/// An undirected graph in CSR form with optional integer edge weights.
///
/// Neighbor lists are sorted (GAP does the same), which triangle
/// counting relies on for merge-based intersection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsrGraph {
    /// `offsets[v]..offsets[v+1]` indexes `targets` for vertex `v`.
    offsets: Vec<u32>,
    /// Concatenated sorted neighbor lists.
    targets: Vec<u32>,
    /// Per-directed-edge weights, parallel to `targets` (empty if unweighted).
    weights: Vec<u32>,
}

impl CsrGraph {
    /// Build from an undirected edge list; self-loops and duplicate edges
    /// are removed, each remaining edge appears in both endpoint lists.
    pub fn from_undirected_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let weighted: Vec<(u32, u32, u32)> = edges.iter().map(|&(u, v)| (u, v, 1)).collect();
        Self::from_undirected_weighted(n, &weighted, false)
    }

    /// Weighted variant; `keep_weights=false` drops the weight array.
    pub fn from_undirected_weighted(
        n: usize,
        edges: &[(u32, u32, u32)],
        keep_weights: bool,
    ) -> Self {
        assert!(n <= u32::MAX as usize);
        // Symmetrize, drop self loops, dedup (keeping first weight).
        let mut dir: Vec<(u32, u32, u32)> = Vec::with_capacity(edges.len() * 2);
        for &(u, v, w) in edges {
            assert!((u as usize) < n && (v as usize) < n, "edge out of range");
            if u != v {
                dir.push((u, v, w));
                dir.push((v, u, w));
            }
        }
        // Sort including the weight so dedup deterministically keeps the
        // *minimum* weight per directed pair — both directions of an
        // undirected edge then agree (duplicate R-MAT samples can carry
        // different weights; keeping an arbitrary one per direction would
        // make the graph silently asymmetric).
        dir.sort_unstable();
        dir.dedup_by_key(|e| (e.0, e.1));

        let mut offsets = vec![0u32; n + 1];
        for &(u, _, _) in &dir {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let targets: Vec<u32> = dir.iter().map(|&(_, v, _)| v).collect();
        let weights = if keep_weights {
            dir.iter().map(|&(_, _, w)| w).collect()
        } else {
            Vec::new()
        };
        CsrGraph { offsets, targets, weights }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len() / 2
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Sorted neighbors of `v`.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let (s, e) = (self.offsets[v as usize] as usize, self.offsets[v as usize + 1] as usize);
        &self.targets[s..e]
    }

    /// Neighbors with weights; panics if the graph is unweighted.
    #[inline]
    pub fn neighbors_weighted(&self, v: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let (s, e) = (self.offsets[v as usize] as usize, self.offsets[v as usize + 1] as usize);
        self.targets[s..e].iter().copied().zip(self.weights[s..e].iter().copied())
    }

    /// Whether a weight array is present (edge-free graphs built with
    /// `keep_weights` count as weighted).
    #[inline]
    pub fn is_weighted(&self) -> bool {
        self.weights.len() == self.targets.len()
    }

    /// Probe helper: record the loads for scanning `v`'s neighbor list
    /// (offset lookup + one load per target cache line).
    #[inline]
    pub fn probe_scan<P: Probe>(&self, v: u32, probe: &mut P) {
        probe.load(OFFSETS_BASE + v as u64 * 4);
        let (s, e) = (self.offsets[v as usize] as u64, self.offsets[v as usize + 1] as u64);
        let mut line = u64::MAX;
        for i in s..e {
            let l = TARGETS_BASE + (i * 4 & !63);
            if l != line {
                line = l;
                probe.load(l);
            }
        }
    }

    /// Probe helper: loads for the weighted scan (targets + weights lines).
    #[inline]
    pub fn probe_scan_weighted<P: Probe>(&self, v: u32, probe: &mut P) {
        self.probe_scan(v, probe);
        let (s, e) = (self.offsets[v as usize] as u64, self.offsets[v as usize + 1] as u64);
        let mut line = u64::MAX;
        for i in s..e {
            let l = WEIGHTS_BASE + (i * 4 & !63);
            if l != line {
                line = l;
                probe.load(l);
            }
        }
    }

    /// Total directed-edge count (2x undirected).
    #[inline]
    pub fn num_directed_edges(&self) -> usize {
        self.targets.len()
    }

    /// Start vertex of chunk `i` of `k` over the vertex range `lo..hi`,
    /// bisecting the offsets array so every chunk carries ~equal work —
    /// a vertex's work being its degree plus one (the `+1` keeps
    /// edge-free stretches splittable instead of collapsing into one
    /// chunk). Monotone in `i`, with `i == 0 → lo` and `i >= k → hi`,
    /// and a pure function of the graph and its arguments — the
    /// edge-balanced schedules stay deterministic by construction.
    ///
    /// This is the degree-aware boundary function the
    /// [`Schedule::EdgeBalanced`](crate::relic::Schedule) kernel loops
    /// wrap in a [`Grain::Bounded`](crate::relic::Grain) and feed to
    /// [`Par::map_into`](crate::relic::Par::map_into) and friends: on
    /// skewed (power-law) graphs a uniform vertex split
    /// strands the hub vertices' edges in one chunk, while this one
    /// narrows chunks around the hubs.
    pub fn edge_balanced_boundary(&self, lo: usize, hi: usize, i: usize, k: usize) -> usize {
        debug_assert!(lo <= hi && hi < self.offsets.len());
        if i == 0 || lo >= hi || k == 0 {
            return lo.min(hi);
        }
        if i >= k {
            return hi;
        }
        // Cumulative work of the vertices in `lo..v`: strictly
        // increasing in v, so the bisection is well-defined.
        let base = self.offsets[lo] as u64;
        bisect_share(|v| (self.offsets[v] as u64 - base) + (v - lo) as u64, lo, hi, i, k)
    }

    /// Fill `buf` with the cumulative degree prefix of a worklist
    /// (`buf[j]` = Σ of `degree + 1` over `items[..j]`), the weight
    /// array the frontier loops (bfs/sssp waves, bc levels) feed to
    /// [`balanced_boundary`]. The `+1` per item keeps zero-degree
    /// stretches splittable. Reuses `buf`'s capacity across calls.
    pub fn degree_prefix_into(&self, items: &[u32], buf: &mut Vec<u64>) {
        buf.clear();
        buf.reserve(items.len() + 1);
        buf.push(0);
        let mut total = 0u64;
        for &v in items {
            total += self.degree(v) as u64 + 1;
            buf.push(total);
        }
    }

    /// Cumulative triangle-counting work, for feeding
    /// [`balanced_boundary`]: entry `u + 1` accumulates, over vertices
    /// `<= u`, one unit per vertex plus the merge-intersection length
    /// `deg(u) + deg(v)` of every rank-ordered neighbor `v > u` (the
    /// wedge scan `tc` actually performs). Unlike plain degrees, this
    /// captures that a hub's intersections also walk its *neighbors'*
    /// lists.
    pub fn cumulative_wedge_work(&self) -> Vec<u64> {
        let n = self.num_vertices();
        let mut cum = Vec::with_capacity(n + 1);
        cum.push(0u64);
        let mut total = 0u64;
        for u in 0..n as u32 {
            let du = self.degree(u) as u64;
            let mut work = 1u64;
            for &v in self.neighbors(u) {
                if v > u {
                    work += du + self.degree(v) as u64;
                }
            }
            total += work;
            cum.push(total);
        }
        cum
    }
}

/// Start index of chunk `i` of `k` over `lo..hi` for an explicit
/// cumulative-work prefix (`cum[j]` = total work of items `< j`, so
/// `cum.len()` must exceed `hi`): the first index whose cumulative work
/// reaches the `i/k`-th share. Monotone in `i`, `i == 0 → lo`,
/// `i >= k → hi`. The frontier loops (bfs/sssp levels, bc's per-level
/// sigma pull, tc's wedge-balanced reduce) build their prefix over the
/// current worklist and pass this as the boundary function.
pub fn balanced_boundary(cum: &[u64], lo: usize, hi: usize, i: usize, k: usize) -> usize {
    if i == 0 || lo >= hi || k == 0 {
        return lo.min(hi);
    }
    if i >= k {
        return hi;
    }
    debug_assert!(hi < cum.len());
    bisect_share(|v| cum[v] - cum[lo], lo, hi, i, k)
}

/// Shared core of the boundary functions: the first index in `lo..=hi`
/// whose cumulative `work` (monotone, `work(lo) == 0`) reaches the
/// `i/k`-th share of `work(hi)`. Callers handle the `i == 0` /
/// `i >= k` / empty-range early-outs.
fn bisect_share(work: impl Fn(usize) -> u64, lo: usize, hi: usize, i: usize, k: usize) -> usize {
    let total = work(hi);
    let target = ((total as u128 * i as u128) / k as u128) as u64;
    let (mut a, mut b) = (lo, hi);
    while a < b {
        let mid = (a + b) / 2;
        if work(mid) < target {
            a = mid + 1;
        } else {
            b = mid;
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> CsrGraph {
        // 0-1, 0-2, 1-2, 1-3, 2-3
        CsrGraph::from_undirected_edges(4, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn build_symmetric_sorted() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2, 3]);
        assert_eq!(g.neighbors(3), &[1, 2]);
        assert_eq!(g.degree(1), 3);
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
    }

    #[test]
    fn duplicate_edges_keep_min_weight_symmetrically() {
        let g = CsrGraph::from_undirected_weighted(
            3,
            &[(1, 2, 50), (2, 1, 10), (1, 2, 30)],
            true,
        );
        let w12: Vec<_> = g.neighbors_weighted(1).collect();
        let w21: Vec<_> = g.neighbors_weighted(2).collect();
        assert_eq!(w12, vec![(2, 10)]);
        assert_eq!(w21, vec![(1, 10)]);
    }

    #[test]
    fn weighted_build() {
        let g = CsrGraph::from_undirected_weighted(3, &[(0, 1, 7), (1, 2, 3)], true);
        assert!(g.is_weighted());
        let n1: Vec<_> = g.neighbors_weighted(1).collect();
        assert_eq!(n1, vec![(0, 7), (2, 3)]);
    }

    #[test]
    fn edge_balanced_boundaries_cover_and_stay_monotone() {
        crate::testutil::check(40, |rng| {
            let n = rng.range(1, 60);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            for k in [1usize, 2, 5, 9] {
                let mut prev = 0usize;
                if g.edge_balanced_boundary(0, n, 0, k) != 0 {
                    return Err("boundary 0 must be the range start".into());
                }
                if g.edge_balanced_boundary(0, n, k, k) != n {
                    return Err("boundary k must be the range end".into());
                }
                for i in 0..=k {
                    let b = g.edge_balanced_boundary(0, n, i, k);
                    if b < prev || b > n {
                        return Err(format!("non-monotone boundary {b} at i={i} k={k}"));
                    }
                    prev = b;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn edge_balanced_narrows_chunks_around_hubs() {
        // Star graph: vertex 0 holds half of all directed edges, so the
        // first of two balanced chunks must stop well before n/2.
        let n = 64u32;
        let edges: Vec<(u32, u32)> = (1..n).map(|v| (0, v)).collect();
        let g = CsrGraph::from_undirected_edges(n as usize, &edges);
        let mid = g.edge_balanced_boundary(0, n as usize, 1, 2);
        // Uniform splitting would put the boundary at 32; edge work
        // (63 hub edges + the per-vertex unit) pulls it down to ~17.
        assert!(
            mid < n as usize / 2,
            "hub chunk must be narrower than uniform, got boundary {mid} of {n}"
        );
    }

    #[test]
    fn balanced_boundary_prefix_properties() {
        // Quadratic weights: later items heavier, boundaries must lean
        // left; plus coverage/monotonicity over the whole range.
        let n = 100usize;
        let mut cum = vec![0u64];
        for i in 0..n {
            cum.push(cum[i] + 1 + (i as u64) * (i as u64));
        }
        for k in [1usize, 3, 7] {
            assert_eq!(balanced_boundary(&cum, 0, n, 0, k), 0);
            assert_eq!(balanced_boundary(&cum, 0, n, k, k), n);
            let mut prev = 0;
            for i in 0..=k {
                let b = balanced_boundary(&cum, 0, n, i, k);
                assert!(b >= prev && b <= n, "i={i} k={k} b={b}");
                prev = b;
            }
        }
        // Half the quadratic mass sits past ~n/2^(1/3) ≈ 79.
        let half = balanced_boundary(&cum, 0, n, 1, 2);
        assert!(half > n / 2, "quadratic weights must push the midpoint right, got {half}");
        // Zero-weight degenerate: everything lands in the last chunk,
        // but boundaries stay ordered and in range.
        let flat = vec![0u64; n + 1];
        for i in 0..=4 {
            let b = balanced_boundary(&flat, 0, n, i, 4);
            assert!(b <= n);
        }
    }

    #[test]
    fn degree_prefix_reuses_buffer_and_counts_degrees() {
        let g = diamond();
        let mut buf = vec![99u64; 8]; // stale content must be discarded
        g.degree_prefix_into(&[1, 0, 3], &mut buf);
        // Degrees: 1 → 3, 0 → 2, 3 → 2; +1 each.
        assert_eq!(buf, vec![0, 4, 7, 10]);
        g.degree_prefix_into(&[], &mut buf);
        assert_eq!(buf, vec![0]);
    }

    #[test]
    fn cumulative_wedge_work_is_monotone_and_counts_wedges() {
        let g = diamond();
        let cum = g.cumulative_wedge_work();
        assert_eq!(cum.len(), g.num_vertices() + 1);
        assert_eq!(cum[0], 0);
        assert!(cum.windows(2).all(|w| w[0] < w[1]), "strictly increasing: {cum:?}");
        // An edgeless graph still accrues one unit per vertex.
        let empty = CsrGraph::from_undirected_edges(5, &[]);
        assert_eq!(empty.cumulative_wedge_work(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn symmetry_property() {
        crate::testutil::check(50, |rng| {
            let n = rng.range(1, 40);
            let m = rng.range(0, 80);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            for u in 0..n as u32 {
                for &v in g.neighbors(u) {
                    if !g.neighbors(v).contains(&u) {
                        return Err(format!("asymmetric edge {u}->{v}"));
                    }
                }
                if !g.neighbors(u).windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("unsorted/duplicate neighbors of {u}"));
                }
            }
            Ok(())
        });
    }
}
