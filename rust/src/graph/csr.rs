//! Compressed sparse row graph — the GAP benchmark data structure.

use crate::probe::Probe;

/// Logical probe-address bases for the CSR arrays (see `probe` docs).
pub const OFFSETS_BASE: u64 = 0x4000_0000;
pub const TARGETS_BASE: u64 = 0x4100_0000;
pub const WEIGHTS_BASE: u64 = 0x4200_0000;

/// An undirected graph in CSR form with optional integer edge weights.
///
/// Neighbor lists are sorted (GAP does the same), which triangle
/// counting relies on for merge-based intersection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsrGraph {
    /// `offsets[v]..offsets[v+1]` indexes `targets` for vertex `v`.
    offsets: Vec<u32>,
    /// Concatenated sorted neighbor lists.
    targets: Vec<u32>,
    /// Per-directed-edge weights, parallel to `targets` (empty if unweighted).
    weights: Vec<u32>,
}

impl CsrGraph {
    /// Build from an undirected edge list; self-loops and duplicate edges
    /// are removed, each remaining edge appears in both endpoint lists.
    pub fn from_undirected_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        Self::from_undirected_weighted(n, &edges.iter().map(|&(u, v)| (u, v, 1)).collect::<Vec<_>>(), false)
    }

    /// Weighted variant; `keep_weights=false` drops the weight array.
    pub fn from_undirected_weighted(
        n: usize,
        edges: &[(u32, u32, u32)],
        keep_weights: bool,
    ) -> Self {
        assert!(n <= u32::MAX as usize);
        // Symmetrize, drop self loops, dedup (keeping first weight).
        let mut dir: Vec<(u32, u32, u32)> = Vec::with_capacity(edges.len() * 2);
        for &(u, v, w) in edges {
            assert!((u as usize) < n && (v as usize) < n, "edge out of range");
            if u != v {
                dir.push((u, v, w));
                dir.push((v, u, w));
            }
        }
        // Sort including the weight so dedup deterministically keeps the
        // *minimum* weight per directed pair — both directions of an
        // undirected edge then agree (duplicate R-MAT samples can carry
        // different weights; keeping an arbitrary one per direction would
        // make the graph silently asymmetric).
        dir.sort_unstable();
        dir.dedup_by_key(|e| (e.0, e.1));

        let mut offsets = vec![0u32; n + 1];
        for &(u, _, _) in &dir {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let targets: Vec<u32> = dir.iter().map(|&(_, v, _)| v).collect();
        let weights = if keep_weights {
            dir.iter().map(|&(_, _, w)| w).collect()
        } else {
            Vec::new()
        };
        CsrGraph { offsets, targets, weights }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len() / 2
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Sorted neighbors of `v`.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let (s, e) = (self.offsets[v as usize] as usize, self.offsets[v as usize + 1] as usize);
        &self.targets[s..e]
    }

    /// Neighbors with weights; panics if the graph is unweighted.
    #[inline]
    pub fn neighbors_weighted(&self, v: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let (s, e) = (self.offsets[v as usize] as usize, self.offsets[v as usize + 1] as usize);
        self.targets[s..e].iter().copied().zip(self.weights[s..e].iter().copied())
    }

    /// Whether a weight array is present (edge-free graphs built with
    /// `keep_weights` count as weighted).
    #[inline]
    pub fn is_weighted(&self) -> bool {
        self.weights.len() == self.targets.len()
    }

    /// Probe helper: record the loads for scanning `v`'s neighbor list
    /// (offset lookup + one load per target cache line).
    #[inline]
    pub fn probe_scan<P: Probe>(&self, v: u32, probe: &mut P) {
        probe.load(OFFSETS_BASE + v as u64 * 4);
        let (s, e) = (self.offsets[v as usize] as u64, self.offsets[v as usize + 1] as u64);
        let mut line = u64::MAX;
        for i in s..e {
            let l = TARGETS_BASE + (i * 4 & !63);
            if l != line {
                line = l;
                probe.load(l);
            }
        }
    }

    /// Probe helper: loads for the weighted scan (targets + weights lines).
    #[inline]
    pub fn probe_scan_weighted<P: Probe>(&self, v: u32, probe: &mut P) {
        self.probe_scan(v, probe);
        let (s, e) = (self.offsets[v as usize] as u64, self.offsets[v as usize + 1] as u64);
        let mut line = u64::MAX;
        for i in s..e {
            let l = WEIGHTS_BASE + (i * 4 & !63);
            if l != line {
                line = l;
                probe.load(l);
            }
        }
    }

    /// Total directed-edge count (2x undirected).
    #[inline]
    pub fn num_directed_edges(&self) -> usize {
        self.targets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> CsrGraph {
        // 0-1, 0-2, 1-2, 1-3, 2-3
        CsrGraph::from_undirected_edges(4, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn build_symmetric_sorted() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2, 3]);
        assert_eq!(g.neighbors(3), &[1, 2]);
        assert_eq!(g.degree(1), 3);
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
    }

    #[test]
    fn duplicate_edges_keep_min_weight_symmetrically() {
        let g = CsrGraph::from_undirected_weighted(
            3,
            &[(1, 2, 50), (2, 1, 10), (1, 2, 30)],
            true,
        );
        let w12: Vec<_> = g.neighbors_weighted(1).collect();
        let w21: Vec<_> = g.neighbors_weighted(2).collect();
        assert_eq!(w12, vec![(2, 10)]);
        assert_eq!(w21, vec![(1, 10)]);
    }

    #[test]
    fn weighted_build() {
        let g = CsrGraph::from_undirected_weighted(3, &[(0, 1, 7), (1, 2, 3)], true);
        assert!(g.is_weighted());
        let n1: Vec<_> = g.neighbors_weighted(1).collect();
        assert_eq!(n1, vec![(0, 7), (2, 3)]);
    }

    #[test]
    fn symmetry_property() {
        crate::testutil::check(50, |rng| {
            let n = rng.range(1, 40);
            let m = rng.range(0, 80);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            for u in 0..n as u32 {
                for &v in g.neighbors(u) {
                    if !g.neighbors(v).contains(&u) {
                        return Err(format!("asymmetric edge {u}->{v}"));
                    }
                }
                if !g.neighbors(u).windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("unsorted/duplicate neighbors of {u}"));
                }
            }
            Ok(())
        });
    }
}
