//! Connected components — Shiloach-Vishkin (the paper's CC variant,
//! §IV-A: "we use the implementation based on Shiloach-Vishkin algorithm,
//! since it shows better performance on fine-grained input graphs").
//!
//! Serial SV iterates hook (edge-based pointer jumping) and compress
//! phases until no label changes; on the 32-node input a task runs in
//! ~0.4 µs — the finest kernel after BFS.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

use crate::probe::Probe;
use crate::relic::{ExecutionPlan, Grain, Par};

use super::CsrGraph;

const COMP_BASE: u64 = 0x5200_0000;

/// Minimum vertices per fork-join chunk in the parallel variant.
const PAR_GRAIN: usize = 16;

/// Shiloach-Vishkin connected components; returns per-vertex component
/// labels where each label is the minimum vertex id in the component.
pub fn shiloach_vishkin<P: Probe>(g: &CsrGraph, probe: &mut P) -> Vec<u32> {
    let n = g.num_vertices();
    let mut comp: Vec<u32> = (0..n as u32).collect();
    for v in 0..n as u32 {
        probe.store(COMP_BASE + v as u64 * 4);
    }

    let mut changed = true;
    while changed {
        changed = false;
        probe.branch(true);
        // Hook phase: for every edge (u, v), point the larger label's
        // root at the smaller label.
        for u in 0..n as u32 {
            g.probe_scan(u, probe);
            for &v in g.neighbors(u) {
                let (cu, cv) = (comp[u as usize], comp[v as usize]);
                // comp[u] streams (u is the sequential scan index);
                // comp[v] is indexed by the edge target — a chase.
                probe.load(COMP_BASE + u as u64 * 4);
                probe.load_dep(COMP_BASE + v as u64 * 4);
                probe.compute(2);
                probe.branch(false);
                if cu < cv && cv == comp[cv as usize] {
                    probe.load_dep(COMP_BASE + cv as u64 * 4);
                    comp[cv as usize] = cu;
                    probe.store(COMP_BASE + cv as u64 * 4);
                    changed = true;
                }
            }
        }
        // Compress phase: pointer jumping until every vertex points at a root.
        for v in 0..n as u32 {
            probe.branch(true);
            while comp[v as usize] != comp[comp[v as usize] as usize] {
                // Pointer jumping: the definition of a dependent load.
                probe.load_dep(COMP_BASE + comp[v as usize] as u64 * 4);
                comp[v as usize] = comp[comp[v as usize] as usize];
                probe.store(COMP_BASE + v as u64 * 4);
                probe.branch(false);
            }
        }
    }
    comp
}

/// [`shiloach_vishkin`] with the hook and compress sweeps split across
/// the SMT pair.
///
/// Hooking becomes a *monotone* atomic label minimization
/// (`fetch_min`), so concurrent hooks can only lower labels toward the
/// component minimum; compression is per-vertex pointer jumping over
/// atomic loads. Intermediate label states may differ from the serial
/// schedule, but the fixpoint is unique — every vertex ends at its
/// component's minimum id (labels start at the vertex id, only ever
/// decrease, and never leave the component), so the returned labels are
/// identical to the serial kernel's.
///
/// The hook sweep's per-vertex cost is the degree, so under
/// `Schedule::EdgeBalanced` its chunks bisect the CSR offsets; the
/// compress sweep is ~O(1) per vertex and keeps uniform chunks.
pub fn shiloach_vishkin_par(g: &CsrGraph, par: &Par) -> Vec<u32> {
    shiloach_vishkin_grain(g, par, PAR_GRAIN)
}

/// [`shiloach_vishkin_par`] under an [`ExecutionPlan`]: the plan picks
/// serial vs pair, the schedule, and the grain (0 defers to this
/// kernel's default). Labels stay identical for every plan.
pub fn shiloach_vishkin_plan(g: &CsrGraph, par: &Par, plan: &ExecutionPlan) -> Vec<u32> {
    shiloach_vishkin_grain(g, &plan.apply(par), plan.grain_or(PAR_GRAIN))
}

fn shiloach_vishkin_grain(g: &CsrGraph, par: &Par, grain: usize) -> Vec<u32> {
    let n = g.num_vertices();
    let comp: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
    let changed = AtomicBool::new(true);
    let hook_bound = |i: usize, k: usize| g.edge_balanced_boundary(0, n, i, k);
    while changed.swap(false, Ordering::Relaxed) {
        // Hook sweep: for every edge (u, v) with comp[u] < comp[v], pull
        // the label of vertex `comp[v]` down toward comp[u]. The scope
        // barrier after the sweep publishes all writes to the next phase.
        par.for_each_index(0..n, Grain::Bounded(grain, &hook_bound), |u| {
            let cu = comp[u].load(Ordering::Relaxed);
            for &v in g.neighbors(u as u32) {
                let cv = comp[v as usize].load(Ordering::Relaxed);
                if cu < cv && comp[cv as usize].fetch_min(cu, Ordering::Relaxed) > cu {
                    changed.store(true, Ordering::Relaxed);
                }
            }
        });
        // Compress sweep: pointer jumping. Labels decrease monotonically
        // (comp[x] <= x always), so the per-vertex loop terminates even
        // while other chunks are jumping concurrently.
        par.for_each_index(0..n, grain, |v| loop {
            let c = comp[v].load(Ordering::Relaxed);
            let cc = comp[c as usize].load(Ordering::Relaxed);
            if c == cc {
                break;
            }
            comp[v].store(cc, Ordering::Relaxed);
        });
    }
    comp.into_iter().map(AtomicU32::into_inner).collect()
}

/// Benchmark checksum: sum of labels.
pub fn checksum(comp: &[u32]) -> u64 {
    comp.iter().map(|&c| c as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{oracle, CsrGraph};
    use crate::probe::NoProbe;

    #[test]
    fn two_components() {
        let g = CsrGraph::from_undirected_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let c = shiloach_vishkin(&g, &mut NoProbe);
        assert_eq!(c, vec![0, 0, 0, 3, 3]);
    }

    #[test]
    fn isolated_vertices_are_own_components() {
        let g = CsrGraph::from_undirected_edges(3, &[]);
        assert_eq!(shiloach_vishkin(&g, &mut NoProbe), vec![0, 1, 2]);
    }

    #[test]
    fn parallel_matches_serial_labels() {
        use crate::relic::{Relic, Schedule};
        let relic = Relic::new();
        crate::testutil::check(30, |rng| {
            let n = rng.range(1, 96);
            let m = rng.range(0, 2 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let serial = shiloach_vishkin(&g, &mut NoProbe);
            for par in [
                Par::Serial,
                Par::Relic(&relic),
                Par::Relic(&relic).with_schedule(Schedule::Dynamic),
                Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced),
            ] {
                let got = shiloach_vishkin_par(&g, &par);
                if got != serial {
                    return Err(format!(
                        "cc {}/serial diverge: {got:?} vs {serial:?}",
                        par.schedule().name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matches_union_find_oracle() {
        crate::testutil::check(60, |rng| {
            let n = rng.range(1, 64);
            let m = rng.range(0, 2 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let got = shiloach_vishkin(&g, &mut NoProbe);
            let want = oracle::components_min_label(&g);
            if got != want {
                return Err(format!("cc mismatch: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }
}
