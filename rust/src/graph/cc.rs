//! Connected components — Shiloach-Vishkin (the paper's CC variant,
//! §IV-A: "we use the implementation based on Shiloach-Vishkin algorithm,
//! since it shows better performance on fine-grained input graphs").
//!
//! Serial SV iterates hook (edge-based pointer jumping) and compress
//! phases until no label changes; on the 32-node input a task runs in
//! ~0.4 µs — the finest kernel after BFS.

use crate::probe::Probe;

use super::CsrGraph;

const COMP_BASE: u64 = 0x5200_0000;

/// Shiloach-Vishkin connected components; returns per-vertex component
/// labels where each label is the minimum vertex id in the component.
pub fn shiloach_vishkin<P: Probe>(g: &CsrGraph, probe: &mut P) -> Vec<u32> {
    let n = g.num_vertices();
    let mut comp: Vec<u32> = (0..n as u32).collect();
    for v in 0..n as u32 {
        probe.store(COMP_BASE + v as u64 * 4);
    }

    let mut changed = true;
    while changed {
        changed = false;
        probe.branch(true);
        // Hook phase: for every edge (u, v), point the larger label's
        // root at the smaller label.
        for u in 0..n as u32 {
            g.probe_scan(u, probe);
            for &v in g.neighbors(u) {
                let (cu, cv) = (comp[u as usize], comp[v as usize]);
                // comp[u] streams (u is the sequential scan index);
                // comp[v] is indexed by the edge target — a chase.
                probe.load(COMP_BASE + u as u64 * 4);
                probe.load_dep(COMP_BASE + v as u64 * 4);
                probe.compute(2);
                probe.branch(false);
                if cu < cv && cv == comp[cv as usize] {
                    probe.load_dep(COMP_BASE + cv as u64 * 4);
                    comp[cv as usize] = cu;
                    probe.store(COMP_BASE + cv as u64 * 4);
                    changed = true;
                }
            }
        }
        // Compress phase: pointer jumping until every vertex points at a root.
        for v in 0..n as u32 {
            probe.branch(true);
            while comp[v as usize] != comp[comp[v as usize] as usize] {
                // Pointer jumping: the definition of a dependent load.
                probe.load_dep(COMP_BASE + comp[v as usize] as u64 * 4);
                comp[v as usize] = comp[comp[v as usize] as usize];
                probe.store(COMP_BASE + v as u64 * 4);
                probe.branch(false);
            }
        }
    }
    comp
}

/// Benchmark checksum: sum of labels.
pub fn checksum(comp: &[u32]) -> u64 {
    comp.iter().map(|&c| c as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{oracle, CsrGraph};
    use crate::probe::NoProbe;

    #[test]
    fn two_components() {
        let g = CsrGraph::from_undirected_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let c = shiloach_vishkin(&g, &mut NoProbe);
        assert_eq!(c, vec![0, 0, 0, 3, 3]);
    }

    #[test]
    fn isolated_vertices_are_own_components() {
        let g = CsrGraph::from_undirected_edges(3, &[]);
        assert_eq!(shiloach_vishkin(&g, &mut NoProbe), vec![0, 1, 2]);
    }

    #[test]
    fn matches_union_find_oracle() {
        crate::testutil::check(60, |rng| {
            let n = rng.range(1, 64);
            let m = rng.range(0, 2 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let got = shiloach_vishkin(&g, &mut NoProbe);
            let want = oracle::components_min_label(&g);
            if got != want {
                return Err(format!("cc mismatch: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }
}
