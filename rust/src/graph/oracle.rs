//! Naive reference implementations used only by tests — deliberately
//! written with *different* algorithms than the optimized kernels so
//! agreement is meaningful (Dijkstra vs delta-stepping, union-find vs
//! Shiloach-Vishkin, dense matrix PR vs CSR pull, pair-BFS BC vs
//! Brandes, brute-force TC vs merge intersection).

use std::collections::{BinaryHeap, VecDeque};

use super::CsrGraph;

/// BFS depths via an explicit deque (vs the kernel's vec-cursor queue).
pub fn bfs_depths(g: &CsrGraph, source: u32) -> Vec<u32> {
    let n = g.num_vertices();
    let mut depth = vec![u32::MAX; n];
    let mut q = VecDeque::new();
    depth[source as usize] = 0;
    q.push_back(source);
    while let Some(u) = q.pop_front() {
        for &v in g.neighbors(u) {
            if depth[v as usize] == u32::MAX {
                depth[v as usize] = depth[u as usize] + 1;
                q.push_back(v);
            }
        }
    }
    depth
}

/// Union-find with path halving; labels normalized to min vertex id.
pub fn components_min_label(g: &CsrGraph) -> Vec<u32> {
    let n = g.num_vertices();
    let mut parent: Vec<u32> = (0..n as u32).collect();

    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }

    for u in 0..n as u32 {
        for &v in g.neighbors(u) {
            let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
            if ru != rv {
                let (lo, hi) = if ru < rv { (ru, rv) } else { (rv, ru) };
                parent[hi as usize] = lo;
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

/// Dijkstra with a binary heap.
pub fn dijkstra(g: &CsrGraph, source: u32) -> Vec<u32> {
    let n = g.num_vertices();
    let mut dist = vec![u32::MAX; n];
    let mut heap = BinaryHeap::new();
    dist[source as usize] = 0;
    heap.push(std::cmp::Reverse((0u32, source)));
    while let Some(std::cmp::Reverse((d, u))) = heap.pop() {
        if d > dist[u as usize] {
            continue;
        }
        for (v, w) in g.neighbors_weighted(u) {
            let nd = d.saturating_add(w);
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(std::cmp::Reverse((nd, v)));
            }
        }
    }
    dist
}

/// PageRank by dense transition-matrix power iteration (no tolerance
/// early-exit; pass the same iteration count to the kernel and disable
/// its tolerance to compare).
pub fn pagerank_dense(g: &CsrGraph, iters: u32) -> Vec<f64> {
    let n = g.num_vertices();
    let d = super::pr::DAMPING;
    let mut r = vec![1.0 / n as f64; n];
    for _ in 0..iters {
        let mut next = vec![(1.0 - d) / n as f64; n];
        for u in 0..n as u32 {
            let deg = g.degree(u);
            if deg == 0 {
                continue;
            }
            let share = d * r[u as usize] / deg as f64;
            for &v in g.neighbors(u) {
                next[v as usize] += share;
            }
        }
        r = next;
    }
    r
}

/// Brute-force triangle count: test every vertex triple.
pub fn triangles_brute(g: &CsrGraph) -> u64 {
    let n = g.num_vertices() as u32;
    let mut count = 0;
    for a in 0..n {
        for b in (a + 1)..n {
            if !g.neighbors(a).contains(&b) {
                continue;
            }
            for c in (b + 1)..n {
                if g.neighbors(a).contains(&c) && g.neighbors(b).contains(&c) {
                    count += 1;
                }
            }
        }
    }
    count
}

/// Brute-force betweenness: enumerate all shortest paths per pair via
/// BFS path counting from each endpoint.
pub fn betweenness_brute(g: &CsrGraph) -> Vec<f64> {
    let n = g.num_vertices();
    let mut bc = vec![0.0; n];
    // sigma[s][v]: number of shortest s->v paths; depth via bfs_depths.
    let depths: Vec<Vec<u32>> = (0..n as u32).map(|s| bfs_depths(g, s)).collect();
    let sigmas: Vec<Vec<f64>> = (0..n as u32)
        .map(|s| {
            let mut sigma = vec![0.0; n];
            sigma[s as usize] = 1.0;
            // Relax in increasing depth order.
            let mut order: Vec<u32> = (0..n as u32).collect();
            order.sort_by_key(|&v| depths[s as usize][v as usize]);
            for &v in &order {
                let dv = depths[s as usize][v as usize];
                if dv == u32::MAX || dv == 0 {
                    continue;
                }
                sigma[v as usize] = g
                    .neighbors(v)
                    .iter()
                    .filter(|&&p| depths[s as usize][p as usize] == dv - 1)
                    .map(|&p| sigma[p as usize])
                    .sum();
            }
            sigma
        })
        .collect();

    for s in 0..n {
        for t in 0..n {
            if s == t || depths[s][t] == u32::MAX {
                continue;
            }
            let total = sigmas[s][t];
            if total == 0.0 {
                continue;
            }
            for v in 0..n {
                if v == s || v == t {
                    continue;
                }
                let dv = depths[s][v];
                if dv == u32::MAX || dv >= depths[s][t] || depths[t][v] == u32::MAX {
                    continue;
                }
                if dv + depths[t][v] == depths[s][t] {
                    bc[v] += sigmas[s][v] * sigmas[t][v] / total;
                }
            }
        }
    }
    // Each unordered pair counted twice above; GAP halves undirected BC.
    for b in &mut bc {
        *b /= 2.0;
    }
    bc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_self_consistency_on_diamond() {
        let g = CsrGraph::from_undirected_weighted(
            4,
            &[(0, 1, 1), (0, 2, 1), (1, 2, 1), (1, 3, 1), (2, 3, 1)],
            true,
        );
        assert_eq!(bfs_depths(&g, 0), vec![0, 1, 1, 2]);
        assert_eq!(components_min_label(&g), vec![0, 0, 0, 0]);
        assert_eq!(dijkstra(&g, 0), vec![0, 1, 1, 2]);
        assert_eq!(triangles_brute(&g), 2);
        // Unit-weight Dijkstra equals BFS depth.
        assert_eq!(dijkstra(&g, 3), bfs_depths(&g, 3));
    }
}
