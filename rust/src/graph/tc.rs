//! Triangle counting (GAP `tc`): sorted-adjacency merge intersection
//! with the standard rank ordering so each triangle is counted once.
//!
//! A task on the paper's input runs in ~1.3 µs.

use crate::probe::{NoProbe, Probe};
use crate::relic::{ExecutionPlan, Grain, Par, Schedule};

use super::csr::{balanced_boundary, TARGETS_BASE};
use super::CsrGraph;

/// Minimum vertices per fork-join chunk. Small, because per-vertex
/// triangle work is highly skewed (hub vertices dominate) and smaller
/// chunks give the main thread's help-claiming more to rebalance.
const PAR_GRAIN: usize = 4;

/// Count triangles: for each u, for each neighbor v > u, count common
/// neighbors w > v (merge over the sorted lists).
pub fn triangle_count<P: Probe>(g: &CsrGraph, probe: &mut P) -> u64 {
    let n = g.num_vertices() as u32;
    let mut total = 0u64;
    for u in 0..n {
        g.probe_scan(u, probe);
        for &v in g.neighbors(u) {
            probe.branch(false);
            if v <= u {
                continue;
            }
            total += intersect_above(g.neighbors(u), g.neighbors(v), v, probe);
        }
    }
    total
}

/// Count elements > `lo` present in both sorted lists (merge walk).
fn intersect_above<P: Probe>(a: &[u32], b: &[u32], lo: u32, probe: &mut P) -> u64 {
    let (mut i, mut j, mut count) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        probe.load(TARGETS_BASE + i as u64 * 4);
        probe.load(TARGETS_BASE + 0x8000 + j as u64 * 4);
        probe.compute(2);
        probe.branch(false);
        if a[i] <= lo {
            i += 1;
        } else if b[j] <= lo {
            j += 1;
        } else if a[i] < b[j] {
            i += 1;
        } else if a[i] > b[j] {
            j += 1;
        } else {
            count += 1;
            i += 1;
            j += 1;
        }
    }
    count
}

/// [`triangle_count`] with the per-vertex outer loop split across the
/// SMT pair: each chunk counts its vertices' triangles independently
/// and the partials are summed in ascending chunk order — an exact
/// integer reduction, so the count is identical to serial for any
/// chunking and any [`Schedule`].
///
/// Triangle work is the most skewed of the GAP kernels (a hub's
/// intersections walk its neighbors' lists too), so under
/// `Schedule::EdgeBalanced` the reduce grain derives from *cumulative
/// wedge counts* ([`CsrGraph::cumulative_wedge_work`]) instead of
/// vertex counts — the one allocation this costs happens once per
/// call, outside the scope hot path.
pub fn triangle_count_par(g: &CsrGraph, par: &Par) -> u64 {
    triangle_count_grain(g, par, PAR_GRAIN)
}

/// [`triangle_count_par`] under an [`ExecutionPlan`]: the plan picks
/// serial vs pair, the schedule, and the grain (0 defers to this
/// kernel's default). The count stays identical for every plan.
pub fn triangle_count_plan(g: &CsrGraph, par: &Par, plan: &ExecutionPlan) -> u64 {
    triangle_count_grain(g, &plan.apply(par), plan.grain_or(PAR_GRAIN))
}

fn triangle_count_grain(g: &CsrGraph, par: &Par, grain: usize) -> u64 {
    // Graphs that fit one grain take the serial fast path and never
    // read the wedge prefix — skip building it for them. Callers that
    // count on the same graph repeatedly can amortize the scan through
    // [`triangle_count_par_with_wedges`].
    let wedges = if par.schedule() == Schedule::EdgeBalanced && g.num_vertices() > grain {
        g.cumulative_wedge_work()
    } else {
        Vec::new()
    };
    triangle_count_wedges_grain(g, par, &wedges, grain)
}

/// [`triangle_count_par`] with a precomputed
/// [`CsrGraph::cumulative_wedge_work`] prefix, so repeated counts on
/// one graph pay the O(V+E) wedge scan once instead of per call. The
/// prefix is only read under `Schedule::EdgeBalanced` (pass `&[]`
/// otherwise).
pub fn triangle_count_par_with_wedges(g: &CsrGraph, par: &Par, wedges: &[u64]) -> u64 {
    triangle_count_wedges_grain(g, par, wedges, PAR_GRAIN)
}

fn triangle_count_wedges_grain(g: &CsrGraph, par: &Par, wedges: &[u64], grain: usize) -> u64 {
    let n = g.num_vertices();
    let bound = |i: usize, k: usize| balanced_boundary(wedges, 0, n, i, k);
    par.reduce(
        0..n,
        Grain::Bounded(grain, &bound),
        0u64,
        |u| {
            let u = u as u32;
            let mut count = 0u64;
            for &v in g.neighbors(u) {
                if v <= u {
                    continue;
                }
                count += intersect_above(g.neighbors(u), g.neighbors(v), v, &mut NoProbe);
            }
            count
        },
        |a, b| a + b,
    )
}

/// Benchmark checksum (identity; the count is already a scalar).
pub fn checksum(count: u64) -> u64 {
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{oracle, CsrGraph};
    use crate::probe::NoProbe;

    #[test]
    fn k4_has_four_triangles() {
        let g = CsrGraph::from_undirected_edges(
            4,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],
        );
        assert_eq!(triangle_count(&g, &mut NoProbe), 4);
    }

    #[test]
    fn trees_have_none() {
        let g = CsrGraph::from_undirected_edges(5, &[(0, 1), (0, 2), (2, 3), (2, 4)]);
        assert_eq!(triangle_count(&g, &mut NoProbe), 0);
    }

    #[test]
    fn parallel_matches_serial_count() {
        use crate::relic::Relic;
        let relic = Relic::new();
        crate::testutil::check(30, |rng| {
            let n = rng.range(1, 64);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let serial = triangle_count(&g, &mut NoProbe);
            for par in [
                Par::Serial,
                Par::Relic(&relic),
                Par::Relic(&relic).with_schedule(Schedule::Dynamic),
                Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced),
            ] {
                if triangle_count_par(&g, &par) != serial {
                    return Err(format!(
                        "tc {}/serial diverge on n={n} m={m}",
                        par.schedule().name()
                    ));
                }
            }
            // The amortizing variant must agree with the one-shot one.
            let wedges = g.cumulative_wedge_work();
            let eb = Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced);
            if triangle_count_par_with_wedges(&g, &eb, &wedges) != serial {
                return Err(format!("tc precomputed-wedges diverge on n={n} m={m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_brute_force() {
        crate::testutil::check(60, |rng| {
            let n = rng.range(1, 40);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let got = triangle_count(&g, &mut NoProbe);
            let want = oracle::triangles_brute(&g);
            if got != want {
                return Err(format!("tc mismatch: {got} vs {want}"));
            }
            Ok(())
        });
    }
}
