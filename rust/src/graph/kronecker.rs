//! GAP-style Kronecker (R-MAT) graph generator.
//!
//! The paper's input (§IV-A) is "a generated Kronecker graph with 32
//! nodes and 157 undirected edges for a degree of 4": scale 5
//! (2^5 = 32 vertices), edge factor 4, i.e. GAP's `-g 5 -k 4`
//! generator, which draws `edge_factor * n` directed edge samples from
//! the R-MAT distribution (A=0.57, B=0.19, C=0.19, D=0.05), then
//! symmetrizes and deduplicates. The seed below is chosen so the
//! resulting graph has exactly the paper's 157 undirected edges.

use crate::testutil::Rng;

use super::CsrGraph;

/// R-MAT quadrant probabilities used by GAP / Graph500.
#[derive(Debug, Clone, Copy)]
pub struct KroneckerParams {
    /// log2 of the vertex count.
    pub scale: u32,
    /// Directed edge samples per vertex.
    pub edge_factor: u32,
    /// Quadrant probabilities (a + b + c <= 1; d is the remainder).
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// PRNG seed.
    pub seed: u64,
    /// Generate uniform integer weights in `[1, 255]` (GAP's SSSP input).
    pub weighted: bool,
}

impl KroneckerParams {
    /// GAP defaults for a given scale/edge-factor.
    pub fn gap(scale: u32, edge_factor: u32, seed: u64) -> Self {
        KroneckerParams {
            scale,
            edge_factor,
            a: 0.57,
            b: 0.19,
            c: 0.19,
            seed,
            weighted: true,
        }
    }
}

/// Generate a Kronecker graph per `params`.
pub fn kronecker_graph(params: &KroneckerParams) -> CsrGraph {
    let n = 1usize << params.scale;
    let m = n * params.edge_factor as usize;
    let mut rng = Rng::new(params.seed);
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut u, mut v) = (0u32, 0u32);
        for _ in 0..params.scale {
            u <<= 1;
            v <<= 1;
            let r = rng.f64();
            if r < params.a {
                // top-left: no bits set
            } else if r < params.a + params.b {
                v |= 1;
            } else if r < params.a + params.b + params.c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        let w = 1 + rng.below(255) as u32;
        edges.push((u, v, w));
    }
    // GAP permutes vertex labels so degree doesn't correlate with id.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    for e in &mut edges {
        e.0 = perm[e.0 as usize];
        e.1 = perm[e.1 as usize];
    }
    CsrGraph::from_undirected_weighted(n, &edges, params.weighted)
}

/// Seed reproducing the paper's exact input size (see `paper_graph`).
pub const PAPER_SEED: u64 = 1;

/// Edge factor reproducing the paper's 157 undirected edges at scale 5.
///
/// Note: the paper says "157 undirected edges for a degree of 4", but
/// drawing only 4·n = 128 R-MAT samples can never produce 157 distinct
/// undirected edges; GAP's *default* edge factor 16 (512 draws over 32
/// vertices, then symmetrize + dedup) lands exactly on 157 — so the
/// paper's input is evidently the GAP default generator and we match
/// its reported node/edge counts exactly (DESIGN.md §2).
pub const PAPER_EDGE_FACTOR: u32 = 16;

/// The paper's benchmark input graph (§IV-A): Kronecker, 32 nodes,
/// 157 undirected edges, weighted.
pub fn paper_graph() -> CsrGraph {
    let g = kronecker_graph(&KroneckerParams::gap(5, PAPER_EDGE_FACTOR, PAPER_SEED));
    debug_assert_eq!(g.num_vertices(), 32);
    debug_assert_eq!(g.num_edges(), 157);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_graph_matches_paper_input() {
        let g = paper_graph();
        assert_eq!(g.num_vertices(), 32);
        assert_eq!(g.num_edges(), 157, "seed must reproduce the paper's 157 edges");
        assert!(g.is_weighted());
    }

    #[test]
    fn generator_is_deterministic() {
        let p = KroneckerParams::gap(6, 8, 42);
        assert_eq!(kronecker_graph(&p), kronecker_graph(&p));
    }

    #[test]
    fn scale_controls_vertex_count() {
        for scale in [3u32, 5, 8] {
            let g = kronecker_graph(&KroneckerParams::gap(scale, 4, 1));
            assert_eq!(g.num_vertices(), 1 << scale);
        }
    }

    #[test]
    fn rmat_skew_produces_hubs() {
        // R-MAT graphs are power-law-ish: max degree far above average.
        let g = kronecker_graph(&KroneckerParams::gap(10, 8, 7));
        let n = g.num_vertices();
        let avg = g.num_directed_edges() as f64 / n as f64;
        let max = (0..n as u32).map(|v| g.degree(v)).max().unwrap() as f64;
        assert!(max > 4.0 * avg, "max {max} avg {avg}");
    }

    #[test]
    fn weights_in_gap_range() {
        let g = kronecker_graph(&KroneckerParams::gap(6, 4, 3));
        for v in 0..g.num_vertices() as u32 {
            for (_, w) in g.neighbors_weighted(v) {
                assert!((1..=255).contains(&w));
            }
        }
    }
}
