//! GAP-style Kronecker (R-MAT) graph generator.
//!
//! The paper's input (§IV-A) is "a generated Kronecker graph with 32
//! nodes and 157 undirected edges for a degree of 4": scale 5
//! (2^5 = 32 vertices), edge factor 4, i.e. GAP's `-g 5 -k 4`
//! generator, which draws `edge_factor * n` directed edge samples from
//! the R-MAT distribution (A=0.57, B=0.19, C=0.19, D=0.05), then
//! symmetrizes and deduplicates. The seed below is chosen so the
//! resulting graph has exactly the paper's 157 undirected edges.

use crate::relic::Par;
use crate::testutil::Rng;

use super::CsrGraph;

/// R-MAT quadrant probabilities used by GAP / Graph500.
#[derive(Debug, Clone, Copy)]
pub struct KroneckerParams {
    /// log2 of the vertex count.
    pub scale: u32,
    /// Directed edge samples per vertex.
    pub edge_factor: u32,
    /// Quadrant probabilities (a + b + c <= 1; d is the remainder).
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// PRNG seed.
    pub seed: u64,
    /// Generate uniform integer weights in `[1, 255]` (GAP's SSSP input).
    pub weighted: bool,
}

impl KroneckerParams {
    /// GAP defaults for a given scale/edge-factor.
    pub fn gap(scale: u32, edge_factor: u32, seed: u64) -> Self {
        KroneckerParams {
            scale,
            edge_factor,
            a: 0.57,
            b: 0.19,
            c: 0.19,
            seed,
            weighted: true,
        }
    }
}

/// Minimum edge samples per parallel chunk. Each chunk pays one RNG
/// jump-ahead (~10⁵ bit ops, see [`Rng::jumped`]) to find its place in
/// the serial stream, so chunks must hold enough edges (scale+1 draws
/// each) to amortize it.
const PAR_GRAIN: usize = 16_384;

/// Draw one R-MAT edge sample: `scale` quadrant picks plus a weight —
/// exactly `scale + 1` RNG draws, which is what makes the stream
/// position of any edge index computable for [`kronecker_graph_par`].
#[inline]
fn sample_edge(params: &KroneckerParams, rng: &mut Rng) -> (u32, u32, u32) {
    let (mut u, mut v) = (0u32, 0u32);
    for _ in 0..params.scale {
        u <<= 1;
        v <<= 1;
        let r = rng.f64();
        if r < params.a {
            // top-left: no bits set
        } else if r < params.a + params.b {
            v |= 1;
        } else if r < params.a + params.b + params.c {
            u |= 1;
        } else {
            u |= 1;
            v |= 1;
        }
    }
    let w = 1 + rng.below(255) as u32;
    (u, v, w)
}

/// Generate a Kronecker graph per `params` (serial).
pub fn kronecker_graph(params: &KroneckerParams) -> CsrGraph {
    kronecker_graph_par(params, &Par::Serial)
}

/// [`kronecker_graph`] with edge sampling fork-joined over the SMT pair.
///
/// Every edge consumes exactly `scale + 1` RNG draws, so a chunk
/// starting at edge index `i` seeds its private generator
/// deterministically from the index — [`Rng::jumped`] fast-forwards the
/// base seed by `i * (scale + 1)` draws. Each chunk therefore
/// reproduces its exact slice of the serial stream and the edge list is
/// **bit-identical to the serial generator's** regardless of how the
/// range is split (`Par::Serial` is literally the one-chunk case). The
/// label permutation that follows is O(n) and stays on the main thread.
pub fn kronecker_graph_par(params: &KroneckerParams, par: &Par) -> CsrGraph {
    let n = 1usize << params.scale;
    let m = n * params.edge_factor as usize;
    let draws_per_edge = params.scale as u64 + 1;
    let base = Rng::new(params.seed);
    let mut chunks = par.chunk_map(0..m, PAR_GRAIN, |sub| {
        let mut rng = base.jumped(sub.start as u64 * draws_per_edge);
        let mut out = Vec::with_capacity(sub.len());
        for _ in sub {
            out.push(sample_edge(params, &mut rng));
        }
        out
    });
    let mut edges: Vec<(u32, u32, u32)> = if chunks.len() == 1 {
        // Single chunk (serial mode or a sub-grain range): take the
        // buffer as-is instead of copying m edges into a second Vec.
        chunks.pop().expect("one chunk")
    } else {
        let mut edges = Vec::with_capacity(m);
        for c in chunks {
            edges.extend(c);
        }
        edges
    };
    // GAP permutes vertex labels so degree doesn't correlate with id;
    // resume the serial stream right where edge sampling left it.
    let mut rng = base.jumped(m as u64 * draws_per_edge);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    for e in &mut edges {
        e.0 = perm[e.0 as usize];
        e.1 = perm[e.1 as usize];
    }
    CsrGraph::from_undirected_weighted(n, &edges, params.weighted)
}

/// Seed reproducing the paper's exact input size (see `paper_graph`).
pub const PAPER_SEED: u64 = 1;

/// Edge factor reproducing the paper's 157 undirected edges at scale 5.
///
/// Note: the paper says "157 undirected edges for a degree of 4", but
/// drawing only 4·n = 128 R-MAT samples can never produce 157 distinct
/// undirected edges; GAP's *default* edge factor 16 (512 draws over 32
/// vertices, then symmetrize + dedup) lands exactly on 157 — so the
/// paper's input is evidently the GAP default generator and we match
/// its reported node/edge counts exactly (DESIGN.md §2).
pub const PAPER_EDGE_FACTOR: u32 = 16;

/// The paper's benchmark input graph (§IV-A): Kronecker, 32 nodes,
/// 157 undirected edges, weighted.
pub fn paper_graph() -> CsrGraph {
    let g = kronecker_graph(&KroneckerParams::gap(5, PAPER_EDGE_FACTOR, PAPER_SEED));
    debug_assert_eq!(g.num_vertices(), 32);
    debug_assert_eq!(g.num_edges(), 157);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_graph_matches_paper_input() {
        let g = paper_graph();
        assert_eq!(g.num_vertices(), 32);
        assert_eq!(g.num_edges(), 157, "seed must reproduce the paper's 157 edges");
        assert!(g.is_weighted());
    }

    #[test]
    fn generator_is_deterministic() {
        let p = KroneckerParams::gap(6, 8, 42);
        assert_eq!(kronecker_graph(&p), kronecker_graph(&p));
    }

    #[test]
    fn parallel_generation_bit_identical_to_serial() {
        let relic = crate::relic::Relic::new();
        // Scale 12 × edge factor 16 = 65536 samples: enough to split
        // into several assistant chunks above PAR_GRAIN; scale 5 is the
        // single-chunk (sub-grain) degenerate case.
        for (scale, ef, seed) in [(5u32, 16u32, PAPER_SEED), (12, 16, 7)] {
            let p = KroneckerParams::gap(scale, ef, seed);
            let serial = kronecker_graph(&p);
            let parallel = kronecker_graph_par(&p, &Par::Relic(&relic));
            assert_eq!(serial, parallel, "scale {scale} ef {ef} seed {seed}");
        }
    }

    #[test]
    fn scale_controls_vertex_count() {
        for scale in [3u32, 5, 8] {
            let g = kronecker_graph(&KroneckerParams::gap(scale, 4, 1));
            assert_eq!(g.num_vertices(), 1 << scale);
        }
    }

    #[test]
    fn rmat_skew_produces_hubs() {
        // R-MAT graphs are power-law-ish: max degree far above average.
        let g = kronecker_graph(&KroneckerParams::gap(10, 8, 7));
        let n = g.num_vertices();
        let avg = g.num_directed_edges() as f64 / n as f64;
        let max = (0..n as u32).map(|v| g.degree(v)).max().unwrap() as f64;
        assert!(max > 4.0 * avg, "max {max} avg {avg}");
    }

    #[test]
    fn weights_in_gap_range() {
        let g = kronecker_graph(&KroneckerParams::gap(6, 4, 3));
        for v in 0..g.num_vertices() as u32 {
            for (_, w) in g.neighbors_weighted(v) {
                assert!((1..=255).contains(&w));
            }
        }
    }
}
