//! Betweenness centrality — Brandes' algorithm (GAP `bc`).
//!
//! GAP's BC approximates by running Brandes from a small sample of
//! sources; the paper's 1.1 µs task granularity on the 32-node input
//! corresponds to a single-source pass, so [`brandes_single_source`] is
//! the benchmark task and [`brandes`] the full exact variant.

use crate::probe::Probe;

use super::CsrGraph;

const SIGMA_BASE: u64 = 0x5700_0000;
const DEPTH_BASE: u64 = 0x5800_0000;
const DELTA_BASE: u64 = 0x5900_0000;
const STACK_BASE: u64 = 0x5A00_0000;

/// One Brandes forward/backward pass; returns the dependency scores
/// accumulated from `source` (unnormalized).
pub fn brandes_single_source<P: Probe>(
    g: &CsrGraph,
    source: u32,
    probe: &mut P,
) -> Vec<f64> {
    let n = g.num_vertices();
    let mut sigma = vec![0.0f64; n]; // shortest-path counts
    let mut depth = vec![i32::MAX; n];
    let mut order = Vec::with_capacity(n); // BFS visit order (stack)
    sigma[source as usize] = 1.0;
    depth[source as usize] = 0;
    order.push(source);
    probe.store(SIGMA_BASE + source as u64 * 8);
    probe.store(DEPTH_BASE + source as u64 * 4);

    // Forward BFS accumulating path counts.
    let mut head = 0;
    while head < order.len() {
        let u = order[head];
        head += 1;
        probe.load_dep(STACK_BASE + head as u64 * 4);
        probe.branch(true);
        let (du, su) = (depth[u as usize], sigma[u as usize]);
        probe.load_dep(DEPTH_BASE + u as u64 * 4);
        probe.load(SIGMA_BASE + u as u64 * 8);
        g.probe_scan(u, probe);
        for &v in g.neighbors(u) {
            probe.load_dep(DEPTH_BASE + v as u64 * 4);
            probe.branch(false);
            probe.compute(2);
            if depth[v as usize] == i32::MAX {
                depth[v as usize] = du + 1;
                order.push(v);
                probe.store(DEPTH_BASE + v as u64 * 4);
                probe.store(STACK_BASE + order.len() as u64 * 4);
            }
            if depth[v as usize] == du + 1 {
                sigma[v as usize] += su;
                probe.store(SIGMA_BASE + v as u64 * 8);
                probe.compute_fp(1);
            }
        }
    }

    // Backward dependency accumulation in reverse BFS order.
    let mut delta = vec![0.0f64; n];
    for &w in order.iter().rev() {
        probe.load(STACK_BASE + w as u64 * 4);
        let (dw, sw, deltw) = (depth[w as usize], sigma[w as usize], delta[w as usize]);
        probe.load(DELTA_BASE + w as u64 * 8);
        g.probe_scan(w, probe);
        for &v in g.neighbors(w) {
            probe.load(DEPTH_BASE + v as u64 * 4);
            probe.branch(false);
            // v is a predecessor of w on shortest paths.
            if depth[v as usize] == dw - 1 {
                let c = sigma[v as usize] / sw * (1.0 + deltw);
                delta[v as usize] += c;
                probe.load(SIGMA_BASE + v as u64 * 8);
                probe.store(DELTA_BASE + v as u64 * 8);
                probe.compute_fp(4); // div + mul + adds, dependent
            }
        }
    }
    delta[source as usize] = 0.0;
    delta
}

/// Exact BC: sum single-source dependencies over all sources; halved for
/// undirected graphs (GAP convention).
pub fn brandes<P: Probe>(g: &CsrGraph, probe: &mut P) -> Vec<f64> {
    let n = g.num_vertices();
    let mut bc = vec![0.0f64; n];
    for s in 0..n as u32 {
        let dep = brandes_single_source(g, s, probe);
        for (b, d) in bc.iter_mut().zip(&dep) {
            *b += d;
        }
    }
    for b in &mut bc {
        *b /= 2.0;
    }
    bc
}

/// Benchmark checksum: quantized dependency sum.
pub fn checksum(scores: &[f64]) -> u64 {
    scores.iter().map(|s| (s * 1e6) as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{oracle, CsrGraph};
    use crate::probe::NoProbe;

    #[test]
    fn path_center_has_highest_bc() {
        // 0-1-2: vertex 1 lies on the only 0..2 path.
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1), (1, 2)]);
        let bc = brandes(&g, &mut NoProbe);
        assert_eq!(bc, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn cycle_is_uniform() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let bc = brandes(&g, &mut NoProbe);
        for v in &bc {
            assert!((v - 0.5).abs() < 1e-12, "{bc:?}");
        }
    }

    #[test]
    fn matches_brute_force_oracle() {
        crate::testutil::check(30, |rng| {
            let n = rng.range(2, 24);
            let m = rng.range(1, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let got = brandes(&g, &mut NoProbe);
            let want = oracle::betweenness_brute(&g);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                crate::testutil::close(*a, *b, 1e-9)
                    .map_err(|e| format!("bc[{i}]: {e}"))?;
            }
            Ok(())
        });
    }
}
