//! Betweenness centrality — Brandes' algorithm (GAP `bc`).
//!
//! GAP's BC approximates by running Brandes from a small sample of
//! sources; the paper's 1.1 µs task granularity on the 32-node input
//! corresponds to a single-source pass, so [`brandes_single_source`] is
//! the benchmark task and [`brandes`] the full exact variant.

use crate::probe::Probe;
use crate::relic::{ExecutionPlan, Grain, Par, Schedule};

use super::csr::balanced_boundary;
use super::CsrGraph;

/// Minimum per-level vertices per fork-join chunk in the parallel
/// variant.
const PAR_GRAIN: usize = 8;

const SIGMA_BASE: u64 = 0x5700_0000;
const DEPTH_BASE: u64 = 0x5800_0000;
const DELTA_BASE: u64 = 0x5900_0000;
const STACK_BASE: u64 = 0x5A00_0000;

/// One Brandes forward/backward pass; returns the dependency scores
/// accumulated from `source` (unnormalized).
pub fn brandes_single_source<P: Probe>(
    g: &CsrGraph,
    source: u32,
    probe: &mut P,
) -> Vec<f64> {
    let n = g.num_vertices();
    let mut sigma = vec![0.0f64; n]; // shortest-path counts
    let mut depth = vec![i32::MAX; n];
    let mut order = Vec::with_capacity(n); // BFS visit order (stack)
    sigma[source as usize] = 1.0;
    depth[source as usize] = 0;
    order.push(source);
    probe.store(SIGMA_BASE + source as u64 * 8);
    probe.store(DEPTH_BASE + source as u64 * 4);

    // Forward BFS accumulating path counts.
    let mut head = 0;
    while head < order.len() {
        let u = order[head];
        head += 1;
        probe.load_dep(STACK_BASE + head as u64 * 4);
        probe.branch(true);
        let (du, su) = (depth[u as usize], sigma[u as usize]);
        probe.load_dep(DEPTH_BASE + u as u64 * 4);
        probe.load(SIGMA_BASE + u as u64 * 8);
        g.probe_scan(u, probe);
        for &v in g.neighbors(u) {
            probe.load_dep(DEPTH_BASE + v as u64 * 4);
            probe.branch(false);
            probe.compute(2);
            if depth[v as usize] == i32::MAX {
                depth[v as usize] = du + 1;
                order.push(v);
                probe.store(DEPTH_BASE + v as u64 * 4);
                probe.store(STACK_BASE + order.len() as u64 * 4);
            }
            if depth[v as usize] == du + 1 {
                sigma[v as usize] += su;
                probe.store(SIGMA_BASE + v as u64 * 8);
                probe.compute_fp(1);
            }
        }
    }

    // Backward dependency accumulation in reverse BFS order.
    let mut delta = vec![0.0f64; n];
    for &w in order.iter().rev() {
        probe.load(STACK_BASE + w as u64 * 4);
        let (dw, sw, deltw) = (depth[w as usize], sigma[w as usize], delta[w as usize]);
        probe.load(DELTA_BASE + w as u64 * 8);
        g.probe_scan(w, probe);
        for &v in g.neighbors(w) {
            probe.load(DEPTH_BASE + v as u64 * 4);
            probe.branch(false);
            // v is a predecessor of w on shortest paths.
            if depth[v as usize] == dw - 1 {
                let c = sigma[v as usize] / sw * (1.0 + deltw);
                delta[v as usize] += c;
                probe.load(SIGMA_BASE + v as u64 * 8);
                probe.store(DELTA_BASE + v as u64 * 8);
                probe.compute_fp(4); // div + mul + adds, dependent
            }
        }
    }
    delta[source as usize] = 0.0;
    delta
}

/// [`brandes_single_source`] with the path-count (sigma) accumulation
/// split across the SMT pair.
///
/// Structure chosen so the result is **bitwise-identical** to the
/// serial kernel:
/// * the BFS visit order is recomputed serially (it is the serial
///   kernel's contract and feeds the backward pass);
/// * sigma is *pulled* per level in parallel — each vertex sums its
///   level-(d-1) predecessors' counts in neighbor order. Path counts
///   are integers in `f64`, so the sum is exact and order-independent,
///   matching the serial push-based accumulation bit for bit;
/// * the backward dependency pass runs serially in the identical
///   reverse visit order — its divisions are *not* order-independent,
///   and reassociating them could flip quantized checksums.
///
/// Under [`Schedule::EdgeBalanced`] each level's pull chunks are
/// balanced by the level vertices' degrees (a per-level prefix over one
/// reused buffer), so a hub on the level no longer strands its whole
/// neighbor scan in one chunk.
pub fn brandes_single_source_par(g: &CsrGraph, source: u32, par: &Par) -> Vec<f64> {
    brandes_single_source_grain(g, source, par, PAR_GRAIN)
}

/// [`brandes_single_source_par`] under an [`ExecutionPlan`]: the plan
/// picks serial vs pair, the schedule, and the grain (0 defers to this
/// kernel's default). Scores stay bitwise-identical for every plan.
pub fn brandes_single_source_plan(
    g: &CsrGraph,
    source: u32,
    par: &Par,
    plan: &ExecutionPlan,
) -> Vec<f64> {
    brandes_single_source_grain(g, source, &plan.apply(par), plan.grain_or(PAR_GRAIN))
}

fn brandes_single_source_grain(g: &CsrGraph, source: u32, par: &Par, grain: usize) -> Vec<f64> {
    let n = g.num_vertices();
    let edge_balanced = par.schedule() == Schedule::EdgeBalanced;
    let mut level_work: Vec<u64> = Vec::new();
    let mut depth = vec![i32::MAX; n];
    let mut order = Vec::with_capacity(n);
    depth[source as usize] = 0;
    order.push(source);

    // Forward BFS (serial): depth + visit order, no sigma yet.
    let mut head = 0;
    while head < order.len() {
        let u = order[head];
        head += 1;
        let du = depth[u as usize];
        for &v in g.neighbors(u) {
            if depth[v as usize] == i32::MAX {
                depth[v as usize] = du + 1;
                order.push(v);
            }
        }
    }

    // Path counts per level, pulled in parallel from the level above.
    let mut sigma = vec![0.0f64; n];
    sigma[source as usize] = 1.0;
    let mut vals = vec![0.0f64; n];
    let mut lvl_start = 0;
    while lvl_start < order.len() {
        let d = depth[order[lvl_start] as usize];
        let mut lvl_end = lvl_start + 1;
        while lvl_end < order.len() && depth[order[lvl_end] as usize] == d {
            lvl_end += 1;
        }
        if d > 0 {
            let lvl = &order[lvl_start..lvl_end];
            // Levels that fit one grain take the serial fast path and
            // never read the prefix — skip building it for them.
            if edge_balanced && lvl.len() > grain {
                g.degree_prefix_into(lvl, &mut level_work);
            }
            {
                let (sigma, depth) = (&sigma, &depth);
                let level_work = &level_work;
                let bound = |i: usize, k: usize| balanced_boundary(level_work, 0, lvl.len(), i, k);
                par.map_into(&mut vals[..lvl.len()], Grain::Bounded(grain, &bound), |j| {
                    let mut s = 0.0;
                    for &u in g.neighbors(lvl[j]) {
                        if depth[u as usize] == d - 1 {
                            s += sigma[u as usize];
                        }
                    }
                    s
                });
            }
            for (j, &v) in lvl.iter().enumerate() {
                sigma[v as usize] = vals[j];
            }
        }
        lvl_start = lvl_end;
    }

    // Backward dependency accumulation: serial, the serial kernel's
    // exact floating-point schedule.
    let mut delta = vec![0.0f64; n];
    for &w in order.iter().rev() {
        let (dw, sw, deltw) = (depth[w as usize], sigma[w as usize], delta[w as usize]);
        for &v in g.neighbors(w) {
            if depth[v as usize] == dw - 1 {
                delta[v as usize] += sigma[v as usize] / sw * (1.0 + deltw);
            }
        }
    }
    delta[source as usize] = 0.0;
    delta
}

/// Exact BC: sum single-source dependencies over all sources; halved for
/// undirected graphs (GAP convention).
pub fn brandes<P: Probe>(g: &CsrGraph, probe: &mut P) -> Vec<f64> {
    let n = g.num_vertices();
    let mut bc = vec![0.0f64; n];
    for s in 0..n as u32 {
        let dep = brandes_single_source(g, s, probe);
        for (b, d) in bc.iter_mut().zip(&dep) {
            *b += d;
        }
    }
    for b in &mut bc {
        *b /= 2.0;
    }
    bc
}

/// Benchmark checksum: quantized dependency sum.
pub fn checksum(scores: &[f64]) -> u64 {
    scores.iter().map(|s| (s * 1e6) as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{oracle, CsrGraph};
    use crate::probe::NoProbe;

    #[test]
    fn path_center_has_highest_bc() {
        // 0-1-2: vertex 1 lies on the only 0..2 path.
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1), (1, 2)]);
        let bc = brandes(&g, &mut NoProbe);
        assert_eq!(bc, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn cycle_is_uniform() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let bc = brandes(&g, &mut NoProbe);
        for v in &bc {
            assert!((v - 0.5).abs() < 1e-12, "{bc:?}");
        }
    }

    #[test]
    fn parallel_matches_serial_on_paper_graph_bitwise() {
        use crate::graph::kronecker::paper_graph;
        use crate::relic::Relic;
        let g = paper_graph();
        let relic = Relic::new();
        for source in [0u32, 5, 17, 31] {
            let serial = brandes_single_source(&g, source, &mut NoProbe);
            for par in [
                Par::Serial,
                Par::Relic(&relic),
                Par::Relic(&relic).with_schedule(Schedule::Dynamic),
                Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced),
            ] {
                let got = brandes_single_source_par(&g, source, &par);
                assert_eq!(
                    got,
                    serial,
                    "bc {}/serial diverge from {source}",
                    par.schedule().name()
                );
            }
        }
    }

    #[test]
    fn parallel_matches_serial_on_random_graphs() {
        use crate::relic::Relic;
        let relic = Relic::new();
        crate::testutil::check(25, |rng| {
            let n = rng.range(2, 48);
            let m = rng.range(1, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let src = rng.below(n as u64) as u32;
            let serial = brandes_single_source(&g, src, &mut NoProbe);
            let got = brandes_single_source_par(&g, src, &Par::Relic(&relic));
            for (a, b) in got.iter().zip(&serial) {
                // Exact in practice (integer sigma); tolerance guards
                // only pathological path-count overflow past 2^53.
                crate::testutil::close(*a, *b, 1e-12)?;
            }
            Ok(())
        });
    }

    #[test]
    fn matches_brute_force_oracle() {
        crate::testutil::check(30, |rng| {
            let n = rng.range(2, 24);
            let m = rng.range(1, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let got = brandes(&g, &mut NoProbe);
            let want = oracle::betweenness_brute(&g);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                crate::testutil::close(*a, *b, 1e-9)
                    .map_err(|e| format!("bc[{i}]: {e}"))?;
            }
            Ok(())
        });
    }
}
