//! Graph file I/O: the edge-list formats GAP and the SNAP datasets use,
//! so the library works on real graphs, not only generated ones.
//!
//! * `.el` — whitespace-separated `u v` per line (GAP's text format);
//! * `.wel` — `u v w` weighted edge list;
//! * `#`/`%`-prefixed comment lines are skipped (SNAP headers);
//! * vertices may be arbitrary non-contiguous ids — they are densified
//!   in first-appearance order and the mapping is returned.

use std::io::{BufReader, Read, Write};
use std::path::Path;

use super::CsrGraph;

/// Parse error for graph files.
#[derive(Debug)]
pub struct LoadError {
    pub line: usize,
    pub reason: String,
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "graph parse error on line {}: {}", self.line, self.reason)
    }
}

impl std::error::Error for LoadError {}

/// A loaded graph plus the original vertex ids (dense id -> original).
#[derive(Debug)]
pub struct LoadedGraph {
    pub graph: CsrGraph,
    pub original_ids: Vec<u64>,
}

/// Load an (optionally weighted) edge list from text.
pub fn parse_edge_list(text: &str) -> Result<LoadedGraph, LoadError> {
    let mut ids: std::collections::HashMap<u64, u32> = std::collections::HashMap::new();
    let mut original = Vec::new();
    let mut edges: Vec<(u32, u32, u32)> = Vec::new();
    let mut weighted = false;

    for (lno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let err = |reason: &str| LoadError { line: lno + 1, reason: reason.into() };
        let u: u64 = parts
            .next()
            .ok_or_else(|| err("missing source"))?
            .parse()
            .map_err(|_| err("bad source id"))?;
        let v: u64 = parts
            .next()
            .ok_or_else(|| err("missing target"))?
            .parse()
            .map_err(|_| err("bad target id"))?;
        let w: u32 = match parts.next() {
            Some(tok) => {
                weighted = true;
                tok.parse().map_err(|_| err("bad weight"))?
            }
            None => 1,
        };
        if parts.next().is_some() {
            return Err(err("trailing tokens"));
        }
        let mut dense = |id: u64| -> u32 {
            *ids.entry(id).or_insert_with(|| {
                original.push(id);
                (original.len() - 1) as u32
            })
        };
        let (du, dv) = (dense(u), dense(v));
        edges.push((du, dv, w));
    }
    let n = original.len();
    Ok(LoadedGraph {
        graph: CsrGraph::from_undirected_weighted(n, &edges, weighted),
        original_ids: original,
    })
}

/// Load from a file path.
pub fn load_edge_list(path: &Path) -> anyhow::Result<LoadedGraph> {
    let mut text = String::new();
    BufReader::new(std::fs::File::open(path)?).read_to_string(&mut text)?;
    Ok(parse_edge_list(&text)?)
}

/// Write a graph as a (weighted) edge list; each undirected edge once.
pub fn write_edge_list<W: Write>(g: &CsrGraph, mut out: W) -> std::io::Result<()> {
    for u in 0..g.num_vertices() as u32 {
        if g.is_weighted() {
            for (v, w) in g.neighbors_weighted(u) {
                if u <= v {
                    writeln!(out, "{u} {v} {w}")?;
                }
            }
        } else {
            for &v in g.neighbors(u) {
                if u <= v {
                    writeln!(out, "{u} {v}")?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_and_weights() {
        let lg = parse_edge_list(
            "# SNAP header\n% another comment\n0 1 5\n1 2 3\n\n2 0 9\n",
        )
        .unwrap();
        assert_eq!(lg.graph.num_vertices(), 3);
        assert_eq!(lg.graph.num_edges(), 3);
        assert!(lg.graph.is_weighted());
        let n0: Vec<_> = lg.graph.neighbors_weighted(0).collect();
        assert_eq!(n0, vec![(1, 5), (2, 9)]);
    }

    #[test]
    fn densifies_sparse_ids() {
        let lg = parse_edge_list("1000000 5\n5 70\n").unwrap();
        assert_eq!(lg.graph.num_vertices(), 3);
        assert_eq!(lg.original_ids, vec![1_000_000, 5, 70]);
        // 1000000->0, 5->1, 70->2; edges (0,1) and (1,2).
        assert_eq!(lg.graph.neighbors(1), &[0, 2]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_edge_list("0 1\nbroken\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse_edge_list("0 1 2 3\n").unwrap_err();
        assert_eq!(err.reason, "trailing tokens");
    }

    #[test]
    fn roundtrip_through_text() {
        let g = crate::graph::kronecker::paper_graph();
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let lg = parse_edge_list(std::str::from_utf8(&buf).unwrap()).unwrap();
        // Isolated vertices never appear in an edge list (the paper
        // graph has one), so only non-isolated vertices round-trip.
        let non_isolated =
            (0..g.num_vertices() as u32).filter(|&v| g.degree(v) > 0).count();
        assert_eq!(lg.graph.num_vertices(), non_isolated);
        assert_eq!(lg.graph.num_edges(), g.num_edges());
        // Same degrees under the recorded id mapping.
        for v in 0..lg.graph.num_vertices() as u32 {
            let orig = lg.original_ids[v as usize] as u32;
            assert_eq!(lg.graph.degree(v), g.degree(orig));
        }
    }

    #[test]
    fn unweighted_lists_stay_unweighted() {
        let lg = parse_edge_list("0 1\n1 2\n").unwrap();
        assert!(!lg.graph.is_weighted());
        assert_eq!(lg.graph.num_edges(), 2);
    }
}
