//! Breadth-first search (GAP `bfs`, serial queue-based top-down).
//!
//! The paper's BFS task on the 32-node Kronecker input runs in 0.5 µs —
//! the finest-grained kernel in the suite and the only one *no* baseline
//! framework manages to parallelize profitably (Fig. 1).

use std::sync::atomic::{AtomicU32, Ordering};

use crate::probe::Probe;
use crate::relic::{ExecutionPlan, Grain, Par, Schedule};

use super::csr::balanced_boundary;
use super::CsrGraph;

/// Probe-address base of the depth array.
const DEPTH_BASE: u64 = 0x5000_0000;
/// Probe-address base of the worklist.
const QUEUE_BASE: u64 = 0x5100_0000;

/// Minimum frontier entries per fork-join chunk in [`bfs_par`].
const PAR_GRAIN: usize = 16;

/// BFS from `source`; returns per-vertex depth, `u32::MAX` if unreachable.
pub fn bfs<P: Probe>(g: &CsrGraph, source: u32, probe: &mut P) -> Vec<u32> {
    let n = g.num_vertices();
    let mut depth = vec![u32::MAX; n];
    let mut queue = Vec::with_capacity(n);
    depth[source as usize] = 0;
    queue.push(source);
    probe.store(DEPTH_BASE + source as u64 * 4);
    probe.store(QUEUE_BASE);

    let mut head = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        probe.load_dep(QUEUE_BASE + head as u64 * 4);
        probe.branch(true);
        let du = depth[u as usize];
        probe.load_dep(DEPTH_BASE + u as u64 * 4);
        g.probe_scan(u, probe);
        for &v in g.neighbors(u) {
            probe.load_dep(DEPTH_BASE + v as u64 * 4);
            probe.branch(false); // visited check is data-dependent
            probe.compute(2);
            if depth[v as usize] == u32::MAX {
                depth[v as usize] = du + 1;
                queue.push(v);
                probe.store(DEPTH_BASE + v as u64 * 4);
                probe.store(QUEUE_BASE + queue.len() as u64 * 4);
            }
        }
    }
    depth
}

/// Level-synchronous BFS with frontier expansion split across the SMT
/// pair. Each chunk of the current frontier relaxes its vertices'
/// neighbors, claiming unvisited vertices with a depth CAS; per-chunk
/// next-frontier buffers are concatenated in chunk order.
///
/// The depth of a vertex is its BFS level — unique regardless of which
/// chunk's CAS claims it — so the returned depths are **identical** to
/// the serial queue BFS for any scheduling (only the intermediate
/// frontier *order* may differ, which the result does not depend on).
/// Under [`Schedule::EdgeBalanced`] frontier chunks are balanced by
/// their vertices' degrees (a per-level prefix over one reused buffer)
/// so a hub on a multi-chunk frontier no longer serializes the level.
/// (Frontiers that fit a single grain still take the tiny-range serial
/// fast path — chunk *count* comes from the vertex count, so a lone
/// hub on a tiny frontier is not split; the fast path matters more on
/// the many near-empty levels real BFS runs see.)
pub fn bfs_par(g: &CsrGraph, source: u32, par: &Par) -> Vec<u32> {
    bfs_grain(g, source, par, PAR_GRAIN)
}

/// [`bfs_par`] under an [`ExecutionPlan`]: the plan picks serial vs
/// pair, the schedule, and the grain (0 defers to this kernel's
/// default). Depths stay identical for every plan.
pub fn bfs_plan(g: &CsrGraph, source: u32, par: &Par, plan: &ExecutionPlan) -> Vec<u32> {
    bfs_grain(g, source, &plan.apply(par), plan.grain_or(PAR_GRAIN))
}

fn bfs_grain(g: &CsrGraph, source: u32, par: &Par, grain: usize) -> Vec<u32> {
    let n = g.num_vertices();
    if n == 0 {
        return Vec::new();
    }
    let depth: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    depth[source as usize].store(0, Ordering::Relaxed);
    let edge_balanced = par.schedule() == Schedule::EdgeBalanced;
    let mut frontier_work: Vec<u64> = Vec::new();
    let mut frontier = vec![source];
    let mut level = 0u32;
    while !frontier.is_empty() {
        let next_level = level + 1;
        let f = &frontier;
        // Frontiers that fit one grain take the serial fast path and
        // never read the prefix — skip building it for them.
        if edge_balanced && f.len() > grain {
            g.degree_prefix_into(f, &mut frontier_work);
        }
        let frontier_work = &frontier_work;
        let bound = |i: usize, k: usize| balanced_boundary(frontier_work, 0, f.len(), i, k);
        let parts: Vec<Vec<u32>> = par.chunk_map(
            0..f.len(),
            Grain::Bounded(grain, &bound),
            |sub| {
                let mut local = Vec::new();
                for i in sub {
                    for &v in g.neighbors(f[i]) {
                        // Claim unvisited neighbors; exactly one chunk
                        // wins the CAS.
                        if depth[v as usize]
                            .compare_exchange(
                                u32::MAX,
                                next_level,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            )
                            .is_ok()
                        {
                            local.push(v);
                        }
                    }
                }
                local
            },
        );
        frontier = parts.into_iter().flatten().collect();
        level = next_level;
    }
    depth.into_iter().map(AtomicU32::into_inner).collect()
}

/// Work checksum used by the benchmark harness (sum of finite depths),
/// preventing dead-code elimination of the kernel.
pub fn checksum(depth: &[u32]) -> u64 {
    depth.iter().filter(|&&d| d != u32::MAX).map(|&d| d as u64).sum()
}

/// Direction-optimizing BFS (Beamer et al., the algorithm GAP's `bfs`
/// actually ships): top-down frontier expansion switches to bottom-up
/// parent search when the frontier's outgoing-edge count exceeds
/// `alpha`-th of the unexplored edges, and back when the frontier
/// shrinks below 1/`beta` of the vertices. On the paper's 32-node input
/// the heuristic rarely switches (tiny frontiers), which is why the
/// serial queue BFS is the benchmark task; this variant is the
/// general-purpose API for larger graphs.
pub fn bfs_direction_optimizing<P: Probe>(
    g: &CsrGraph,
    source: u32,
    probe: &mut P,
) -> Vec<u32> {
    const ALPHA: u64 = 14;
    const BETA: u64 = 24;
    let n = g.num_vertices();
    let mut depth = vec![u32::MAX; n];
    depth[source as usize] = 0;
    probe.store(DEPTH_BASE + source as u64 * 4);
    let mut frontier: Vec<u32> = vec![source];
    let mut level = 0u32;
    let mut edges_left: u64 = g.num_directed_edges() as u64;

    while !frontier.is_empty() {
        let frontier_edges: u64 = frontier.iter().map(|&v| g.degree(v) as u64).sum();
        let bottom_up = frontier_edges * ALPHA > edges_left
            && (frontier.len() as u64) * BETA > n as u64;
        let mut next = Vec::new();
        if bottom_up {
            // Bottom-up: every unvisited vertex scans its neighbors for
            // a parent on the current level.
            for v in 0..n as u32 {
                probe.load(DEPTH_BASE + v as u64 * 4);
                probe.branch(false);
                if depth[v as usize] != u32::MAX {
                    continue;
                }
                g.probe_scan(v, probe);
                for &u in g.neighbors(v) {
                    probe.load_dep(DEPTH_BASE + u as u64 * 4);
                    probe.branch(false);
                    if depth[u as usize] == level {
                        depth[v as usize] = level + 1;
                        probe.store(DEPTH_BASE + v as u64 * 4);
                        next.push(v);
                        break;
                    }
                }
            }
        } else {
            for &u in &frontier {
                g.probe_scan(u, probe);
                for &v in g.neighbors(u) {
                    probe.load_dep(DEPTH_BASE + v as u64 * 4);
                    probe.branch(false);
                    probe.compute(2);
                    if depth[v as usize] == u32::MAX {
                        depth[v as usize] = level + 1;
                        probe.store(DEPTH_BASE + v as u64 * 4);
                        next.push(v);
                    }
                }
            }
        }
        edges_left = edges_left.saturating_sub(frontier_edges);
        frontier = next;
        level += 1;
    }
    depth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{kronecker::paper_graph, oracle, CsrGraph};
    use crate::probe::NoProbe;

    #[test]
    fn path_graph_depths() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(bfs(&g, 0, &mut NoProbe), vec![0, 1, 2, 3]);
        assert_eq!(bfs(&g, 3, &mut NoProbe), vec![3, 2, 1, 0]);
    }

    #[test]
    fn unreachable_is_max() {
        let g = CsrGraph::from_undirected_edges(3, &[(0, 1)]);
        assert_eq!(bfs(&g, 0, &mut NoProbe), vec![0, 1, u32::MAX]);
    }

    #[test]
    fn matches_oracle_on_random_graphs() {
        crate::testutil::check(60, |rng| {
            let n = rng.range(1, 64);
            let m = rng.range(0, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let src = rng.below(n as u64) as u32;
            let got = bfs(&g, src, &mut NoProbe);
            let want = oracle::bfs_depths(&g, src);
            if got != want {
                return Err(format!("bfs mismatch from {src}: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_matches_serial_depths() {
        use crate::relic::Relic;
        let relic = Relic::new();
        crate::testutil::check(30, |rng| {
            let n = rng.range(1, 128);
            let m = rng.range(0, 4 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let src = rng.below(n as u64) as u32;
            let serial = bfs(&g, src, &mut NoProbe);
            for par in [
                Par::Serial,
                Par::Relic(&relic),
                Par::Relic(&relic).with_schedule(Schedule::Dynamic),
                Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced),
            ] {
                if bfs_par(&g, src, &par) != serial {
                    return Err(format!(
                        "bfs {}/serial diverge from {src}",
                        par.schedule().name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn direction_optimizing_matches_queue_bfs() {
        crate::testutil::check(40, |rng| {
            let n = rng.range(1, 200);
            let m = rng.range(0, 6 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let src = rng.below(n as u64) as u32;
            let a = bfs(&g, src, &mut NoProbe);
            let b = bfs_direction_optimizing(&g, src, &mut NoProbe);
            if a != b {
                return Err(format!("DO-BFS mismatch from {src}"));
            }
            Ok(())
        });
    }

    #[test]
    fn direction_optimizing_switches_bottom_up_on_dense_graphs() {
        // A dense graph with a huge first frontier must trigger the
        // bottom-up phase and still produce correct depths.
        let n = 64u32;
        let edges: Vec<(u32, u32)> = (0..n)
            .flat_map(|u| ((u + 1)..n).map(move |v| (u, v)))
            .collect();
        let g = CsrGraph::from_undirected_edges(n as usize, &edges);
        let d = bfs_direction_optimizing(&g, 0, &mut NoProbe);
        assert_eq!(d[0], 0);
        assert!(d[1..].iter().all(|&x| x == 1));
    }

    #[test]
    fn paper_graph_reaches_most_vertices() {
        let g = paper_graph();
        let d = bfs(&g, 0, &mut NoProbe);
        let reached = d.iter().filter(|&&x| x != u32::MAX).count();
        assert!(reached > 16, "Kronecker giant component expected, got {reached}");
    }
}
