//! PageRank (GAP `pr`, pull-based power iteration).
//!
//! GAP's reference PageRank: damping 0.85, iterate until the L1 delta
//! drops below a tolerance or an iteration cap is reached. On the paper's
//! 32-node input a task takes 4.3 µs — the second-coarsest kernel.

use crate::probe::Probe;
use crate::relic::{ExecutionPlan, Grain, Par};

use super::CsrGraph;

/// Minimum vertices per fork-join chunk (a chunk of 16 pulls is a few
/// hundred ns on GAP-like degree distributions — well above Relic's
/// submit cost).
const PAR_GRAIN: usize = 16;

const SCORE_BASE: u64 = 0x5300_0000;
const OUT_BASE: u64 = 0x5400_0000;

/// GAP defaults.
pub const DAMPING: f64 = 0.85;
pub const TOLERANCE: f64 = 1e-4;
pub const MAX_ITERS: u32 = 20;

/// Pull-based PageRank; returns per-vertex scores summing to ~1.
pub fn pagerank<P: Probe>(
    g: &CsrGraph,
    max_iters: u32,
    tolerance: f64,
    probe: &mut P,
) -> Vec<f64> {
    let n = g.num_vertices();
    if n == 0 {
        return Vec::new();
    }
    let base = (1.0 - DAMPING) / n as f64;
    let mut scores = vec![1.0 / n as f64; n];
    let mut outgoing = vec![0.0f64; n];

    for _ in 0..max_iters {
        probe.branch(true);
        let mut error = 0.0;
        // Scatter contributions (degree-normalized score).
        for v in 0..n {
            let deg = g.degree(v as u32);
            probe.load(SCORE_BASE + v as u64 * 8);
            probe.compute(1);
            probe.compute_fp(3); // fp divide (pipelined but latent)
            outgoing[v] = if deg > 0 { scores[v] / deg as f64 } else { 0.0 };
            probe.store(OUT_BASE + v as u64 * 8);
        }
        // Pull phase: sum neighbor contributions.
        for u in 0..n as u32 {
            let mut incoming = 0.0;
            g.probe_scan(u, probe);
            for &v in g.neighbors(u) {
                probe.load(OUT_BASE + v as u64 * 8);
                probe.compute_fp(1); // running-sum dependency chain
                incoming += outgoing[v as usize];
            }
            let new = base + DAMPING * incoming;
            probe.compute_fp(4); // fma + abs + error accumulation
            error += (new - scores[u as usize]).abs();
            scores[u as usize] = new;
            probe.store(SCORE_BASE + u as u64 * 8);
        }
        probe.branch(false);
        if error < tolerance {
            break;
        }
    }
    scores
}

/// [`pagerank`] with the scatter and pull loops split across the SMT
/// pair (`Par::Relic`) — the paper's fine-grained scenario moved inside
/// one request.
///
/// Produces **bitwise-identical** scores to the serial kernel under
/// every [`crate::relic::Schedule`]: the per-vertex neighbor sums run
/// in the same order (chunking only partitions the outer loop), the
/// pull phase writes a separate buffer (so the parallel version is the
/// same Jacobi step the serial kernel computes — in-place updates never
/// feed the same iteration), and the convergence error is accumulated
/// serially in vertex order so no floating-point addition is
/// reassociated. Under `Schedule::EdgeBalanced` the pull loop's chunk
/// boundaries bisect the CSR offsets so each chunk pulls ~the same
/// number of edges — the scatter loop is O(1) per vertex and keeps
/// uniform chunks.
pub fn pagerank_par(g: &CsrGraph, max_iters: u32, tolerance: f64, par: &Par) -> Vec<f64> {
    pagerank_grain(g, max_iters, tolerance, par, PAR_GRAIN)
}

/// [`pagerank_par`] under an [`ExecutionPlan`]: the plan picks serial
/// vs pair, the schedule, and the grain (0 defers to this kernel's
/// default). Scores stay bitwise-identical for every plan.
pub fn pagerank_plan(
    g: &CsrGraph,
    max_iters: u32,
    tolerance: f64,
    par: &Par,
    plan: &ExecutionPlan,
) -> Vec<f64> {
    pagerank_grain(g, max_iters, tolerance, &plan.apply(par), plan.grain_or(PAR_GRAIN))
}

fn pagerank_grain(
    g: &CsrGraph,
    max_iters: u32,
    tolerance: f64,
    par: &Par,
    grain: usize,
) -> Vec<f64> {
    let n = g.num_vertices();
    if n == 0 {
        return Vec::new();
    }
    let base = (1.0 - DAMPING) / n as f64;
    let mut scores = vec![1.0 / n as f64; n];
    let mut next = vec![0.0f64; n];
    let mut outgoing = vec![0.0f64; n];
    let pull_bound = |i: usize, k: usize| g.edge_balanced_boundary(0, n, i, k);

    for _ in 0..max_iters {
        // Scatter contributions (disjoint writes per vertex).
        {
            let scores = &scores;
            par.map_into(&mut outgoing, grain, |v| {
                let deg = g.degree(v as u32);
                if deg > 0 {
                    scores[v] / deg as f64
                } else {
                    0.0
                }
            });
        }
        // Pull phase into the next buffer (disjoint writes per vertex);
        // per-vertex cost is the degree, so the edge-balanced schedule
        // bisects the offsets array instead of counting vertices.
        {
            let outgoing = &outgoing;
            par.map_into(&mut next, Grain::Bounded(grain, &pull_bound), |u| {
                let mut incoming = 0.0;
                for &v in g.neighbors(u as u32) {
                    incoming += outgoing[v as usize];
                }
                base + DAMPING * incoming
            });
        }
        // Convergence error: serial, in vertex order — the identical
        // float-add sequence as the serial kernel's accumulation.
        let mut error = 0.0;
        for u in 0..n {
            error += (next[u] - scores[u]).abs();
        }
        std::mem::swap(&mut scores, &mut next);
        if error < tolerance {
            break;
        }
    }
    scores
}

/// Benchmark checksum: quantized score sum.
pub fn checksum(scores: &[f64]) -> u64 {
    scores.iter().map(|s| (s * 1e9) as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{oracle, CsrGraph};
    use crate::probe::NoProbe;

    #[test]
    fn scores_sum_to_one_on_connected_graph() {
        let g = CsrGraph::from_undirected_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let s = pagerank(&g, MAX_ITERS, TOLERANCE, &mut NoProbe);
        let sum: f64 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum={sum}");
    }

    #[test]
    fn ring_is_uniform() {
        let g = CsrGraph::from_undirected_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]);
        let s = pagerank(&g, 50, 1e-10, &mut NoProbe);
        for v in &s {
            assert!((v - 0.2).abs() < 1e-6);
        }
    }

    #[test]
    fn hub_scores_higher() {
        // Star: center 0 should outrank the leaves.
        let g = CsrGraph::from_undirected_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        let s = pagerank(&g, MAX_ITERS, TOLERANCE, &mut NoProbe);
        assert!(s[0] > s[1] && s[0] > s[4]);
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        use crate::relic::{Relic, Schedule};
        let relic = Relic::new();
        crate::testutil::check(20, |rng| {
            let n = rng.range(1, 80);
            let m = rng.range(0, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let serial = pagerank(&g, MAX_ITERS, TOLERANCE, &mut NoProbe);
            for par in [
                Par::Serial,
                Par::Relic(&relic),
                Par::Relic(&relic).with_schedule(Schedule::Dynamic),
                Par::Relic(&relic).with_schedule(Schedule::EdgeBalanced),
            ] {
                let got = pagerank_par(&g, MAX_ITERS, TOLERANCE, &par);
                if got != serial {
                    return Err(format!(
                        "pr {}/serial diverge on n={n} m={m}",
                        par.schedule().name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matches_dense_oracle() {
        crate::testutil::check(40, |rng| {
            let n = rng.range(2, 40);
            let m = rng.range(1, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
                .collect();
            let g = CsrGraph::from_undirected_edges(n, &edges);
            let got = pagerank(&g, 30, 0.0, &mut NoProbe);
            let want = oracle::pagerank_dense(&g, 30);
            for (a, b) in got.iter().zip(&want) {
                crate::testutil::close(*a, *b, 1e-9)?;
            }
            Ok(())
        });
    }
}
