//! GAP Benchmark Suite substrate: CSR graphs, the Kronecker generator,
//! and serial high-performance ports of the six GAP kernels the paper
//! benchmarks (§IV-A): betweenness centrality (BC), breadth-first search
//! (BFS), connected components via Shiloach-Vishkin (CC), PageRank (PR),
//! single-source shortest paths via delta-stepping (SSSP), and triangle
//! counting (TC).
//!
//! Every kernel is written once, generic over a [`crate::probe::Probe`]:
//! the zero-cost [`crate::probe::NoProbe`] instantiation is the native
//! kernel used for wall-clock benchmarks and the public API; the
//! simulator's `TraceProbe` instantiation replays the identical
//! algorithm on the modeled SMT core.
//!
//! ```
//! use relic_smt::graph::{kronecker, bfs};
//! use relic_smt::probe::NoProbe;
//! let g = kronecker::paper_graph();
//! let depth = bfs::bfs(&g, 0, &mut NoProbe);
//! assert_eq!(depth[0], 0);
//! ```

pub mod bc;
pub mod bfs;
pub mod cc;
pub mod csr;
pub mod dense;
pub mod incremental;
pub mod io;
pub mod kronecker;
pub mod oracle;
pub mod pr;
pub mod sssp;
pub mod tc;

pub use csr::{balanced_boundary, CsrGraph};
pub use incremental::{DeltaCsr, DynamicBfs, IncrementalAnalytics, IncrementalCc};
pub use kronecker::{kronecker_graph, kronecker_graph_par, paper_graph, KroneckerParams};
