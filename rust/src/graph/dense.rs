//! Dense matrix exports for the PJRT offload path.
//!
//! The L2 JAX models (`python/compile/model.py`) consume dense f32
//! matrices; these builders produce row-major buffers matching its
//! conventions exactly:
//!
//! * [`adjacency`] — symmetric {0,1} with zero diagonal (`a`).
//! * [`weights_inf`] — edge weights, `+inf` off-edge, zero diagonal (`w`).
//! * [`w0`] — {0, inf}: 0 on edges and diagonal (`w0`, CC label prop).
//! * [`transition`] — `m[i][j] = a[j][i] / degree(j)` (PageRank pull).
//! * [`one_hot`] — source vector.

use super::CsrGraph;

/// Row-major (n, n) {0,1} adjacency.
pub fn adjacency(g: &CsrGraph) -> Vec<f32> {
    let n = g.num_vertices();
    let mut a = vec![0.0f32; n * n];
    for u in 0..n as u32 {
        for &v in g.neighbors(u) {
            a[u as usize * n + v as usize] = 1.0;
        }
    }
    a
}

/// Row-major (n, n) weight matrix with `inf` where no edge, 0 diagonal.
pub fn weights_inf(g: &CsrGraph) -> Vec<f32> {
    let n = g.num_vertices();
    let mut w = vec![f32::INFINITY; n * n];
    for i in 0..n {
        w[i * n + i] = 0.0;
    }
    for u in 0..n as u32 {
        for (v, wt) in g.neighbors_weighted(u) {
            w[u as usize * n + v as usize] = wt as f32;
        }
    }
    w
}

/// Row-major (n, n) {0, inf} matrix: 0 on edges and the diagonal.
pub fn w0(g: &CsrGraph) -> Vec<f32> {
    let n = g.num_vertices();
    let mut w = vec![f32::INFINITY; n * n];
    for i in 0..n {
        w[i * n + i] = 0.0;
    }
    for u in 0..n as u32 {
        for &v in g.neighbors(u) {
            w[u as usize * n + v as usize] = 0.0;
        }
    }
    w
}

/// PageRank pull transition matrix: `m[i][j] = a[j][i] / deg(j)`
/// (column-normalized adjacency, transposed into gather form).
pub fn transition(g: &CsrGraph) -> Vec<f32> {
    let n = g.num_vertices();
    let mut m = vec![0.0f32; n * n];
    for j in 0..n as u32 {
        let deg = g.degree(j) as f32;
        if deg == 0.0 {
            continue;
        }
        for &i in g.neighbors(j) {
            m[i as usize * n + j as usize] = 1.0 / deg;
        }
    }
    m
}

/// One-hot f32 vector of length `n`.
pub fn one_hot(n: usize, idx: u32) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    v[idx as usize] = 1.0;
    v
}

/// Uniform initial PageRank distribution.
pub fn uniform(n: usize) -> Vec<f32> {
    vec![1.0 / n as f32; n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CsrGraph;

    fn tri() -> CsrGraph {
        CsrGraph::from_undirected_weighted(3, &[(0, 1, 5), (1, 2, 7)], true)
    }

    #[test]
    fn adjacency_symmetric() {
        let a = adjacency(&tri());
        assert_eq!(a, vec![0., 1., 0., 1., 0., 1., 0., 1., 0.]);
    }

    #[test]
    fn weights_match_graph() {
        let w = weights_inf(&tri());
        assert_eq!(w[0 * 3 + 1], 5.0);
        assert_eq!(w[1 * 3 + 2], 7.0);
        assert_eq!(w[2 * 3 + 1], 7.0);
        assert!(w[0 * 3 + 2].is_infinite());
        assert_eq!(w[1 * 3 + 1], 0.0);
    }

    #[test]
    fn transition_columns_sum_to_one() {
        let g = tri();
        let m = transition(&g);
        let n = 3;
        for j in 0..n {
            let s: f32 = (0..n).map(|i| m[i * n + j]).sum();
            assert!((s - 1.0).abs() < 1e-6, "column {j} sums to {s}");
        }
    }

    #[test]
    fn w0_diagonal_and_edges_zero() {
        let w = w0(&tri());
        assert_eq!(w[0], 0.0);
        assert_eq!(w[1], 0.0);
        assert!(w[2].is_infinite());
    }
}
