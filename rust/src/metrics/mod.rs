//! Lightweight metrics: counters, gauges, and streaming histograms with
//! percentile queries — used by the coordinator service and the
//! benchmark harness (latency/throughput reporting in the E2E example).

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter (thread-safe).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed histogram for latencies in nanoseconds.
///
/// 64 buckets of power-of-two widths cover 1 ns … ~18 s; recording is a
/// single atomic increment, percentile queries interpolate within the
/// matched bucket. Accuracy (<~3% relative error per bucket) is ample
/// for p50/p99 reporting.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (e.g. nanoseconds).
    pub fn record(&self, value: u64) {
        let idx = (64 - value.max(1).leading_zeros() as usize).min(63);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate percentile (`q` in [0, 1]).
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                // Interpolate inside [2^(idx-1), 2^idx).
                let lo = if idx == 0 { 0 } else { 1u64 << (idx - 1) };
                let hi = if idx >= 63 { u64::MAX } else { 1u64 << idx };
                let frac = (target - seen) as f64 / c as f64;
                // Clamp: interpolation may overshoot the true maximum.
                return (lo + ((hi - lo) as f64 * frac) as u64).min(self.max());
            }
            seen += c;
        }
        self.max()
    }

    /// Fold another histogram into this one (bucket-wise addition) —
    /// how the pool aggregates shard-local latency histograms into one
    /// service-level view. Concurrent recording on `other` may be
    /// partially visible (relaxed snapshot), which is fine for
    /// reporting.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let c = theirs.load(Ordering::Relaxed);
            if c > 0 {
                mine.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// p50/p90/p99/max snapshot, formatted for logs.
    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.0}{unit} p50={}{unit} p90={}{unit} p99={}{unit} max={}{unit}",
            self.count(),
            self.mean(),
            self.percentile(0.50),
            self.percentile(0.90),
            self.percentile(0.99),
            self.max(),
        )
    }
}

/// Admission-control counters: every request the engine's front door
/// turned away or delayed, plus how much slack deadlined requests
/// arrived with. Shed and parked events are engine-side (recorded at
/// admission); deadline misses are shard-side (recorded at completion)
/// — [`AdmissionMetrics::merge_from`] folds both into one service view.
#[derive(Debug, Default)]
pub struct AdmissionMetrics {
    /// Requests refused by the shed policy (counted per
    /// [`crate::coordinator::ShedReason`] below; never silent).
    pub shed_requests: Counter,
    /// Shed because the deadline had already expired at admission.
    pub shed_past_deadline: Counter,
    /// Shed because remaining slack was below the estimated wait.
    pub shed_slack_exhausted: Counter,
    /// Shed by the load-factor overload threshold.
    pub shed_overload: Counter,
    /// Accepted requests that completed after their deadline.
    pub deadline_misses: Counter,
    /// Submissions that parked on a shard's drain signal (full channel)
    /// before being accepted.
    pub parked_submits: Counter,
    /// Non-blocking submissions bounced with `QueueFull`.
    pub queue_full_rejections: Counter,
    /// Slack remaining at admission (ns) for accepted deadlined
    /// requests — the input distribution deadline-aware routing works
    /// with.
    pub slack_at_admission: Histogram,
}

impl AdmissionMetrics {
    /// Fold another instance into this one (same merge semantics as
    /// [`Histogram::merge_from`]).
    pub fn merge_from(&self, other: &AdmissionMetrics) {
        self.shed_requests.add(other.shed_requests.get());
        self.shed_past_deadline.add(other.shed_past_deadline.get());
        self.shed_slack_exhausted.add(other.shed_slack_exhausted.get());
        self.shed_overload.add(other.shed_overload.get());
        self.deadline_misses.add(other.deadline_misses.get());
        self.parked_submits.add(other.parked_submits.get());
        self.queue_full_rejections.add(other.queue_full_rejections.get());
        self.slack_at_admission.merge_from(&other.slack_at_admission);
    }

    /// One-line report (`shed=... parked=... misses=...` plus the slack
    /// distribution when any deadlined request was admitted).
    pub fn summary(&self) -> String {
        let mut out = format!(
            "shed={} (past-deadline={} slack={} overload={}) parked={} \
             queue-full={} deadline-misses={}",
            self.shed_requests.get(),
            self.shed_past_deadline.get(),
            self.shed_slack_exhausted.get(),
            self.shed_overload.get(),
            self.parked_submits.get(),
            self.queue_full_rejections.get(),
            self.deadline_misses.get(),
        );
        if self.slack_at_admission.count() > 0 {
            out += &format!("; slack {}", self.slack_at_admission.summary("ns"));
        }
        out
    }
}

/// Wall-clock stopwatch recording into a [`Histogram`] on drop.
pub struct Timer<'a> {
    hist: &'a Histogram,
    start: std::time::Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a Histogram) -> Self {
        Timer { hist, start: std::time::Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed().as_nanos() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v * 100);
        }
        assert_eq!(h.count(), 1000);
        let (p50, p90, p99) = (h.percentile(0.5), h.percentile(0.9), h.percentile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(p99 <= h.max());
        // Log-bucketed: p50 of uniform 100..100_000 is within its 2x bucket.
        assert!((25_000..100_000).contains(&p50), "p50={p50}");
    }

    #[test]
    fn merge_combines_shard_histograms() {
        let (a, b) = (Histogram::new(), Histogram::new());
        for v in 1..=100u64 {
            a.record(v);
        }
        for v in 1000..=1100u64 {
            b.record(v);
        }
        let agg = Histogram::new();
        agg.merge_from(&a);
        agg.merge_from(&b);
        assert_eq!(agg.count(), a.count() + b.count());
        assert_eq!(agg.max(), 1100);
        let expected_mean = (a.mean() * a.count() as f64 + b.mean() * b.count() as f64)
            / agg.count() as f64;
        assert!((agg.mean() - expected_mean).abs() < 1e-9);
        assert!(agg.percentile(0.99) >= agg.percentile(0.5));
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn admission_metrics_merge_and_summary() {
        let a = AdmissionMetrics::default();
        a.shed_requests.add(3);
        a.shed_past_deadline.add(2);
        a.shed_slack_exhausted.inc();
        a.parked_submits.add(5);
        a.slack_at_admission.record(1000);
        let b = AdmissionMetrics::default();
        b.deadline_misses.add(4);
        b.queue_full_rejections.add(7);
        let agg = AdmissionMetrics::default();
        agg.merge_from(&a);
        agg.merge_from(&b);
        assert_eq!(agg.shed_requests.get(), 3);
        assert_eq!(agg.shed_past_deadline.get(), 2);
        assert_eq!(agg.shed_slack_exhausted.get(), 1);
        assert_eq!(agg.deadline_misses.get(), 4);
        assert_eq!(agg.parked_submits.get(), 5);
        assert_eq!(agg.queue_full_rejections.get(), 7);
        assert_eq!(agg.slack_at_admission.count(), 1);
        let s = agg.summary();
        assert!(s.contains("shed=3"));
        assert!(s.contains("deadline-misses=4"));
        assert!(s.contains("slack "), "slack histogram line present: {s}");
    }

    #[test]
    fn timer_records() {
        let h = Histogram::new();
        {
            let _t = Timer::start(&h);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn property_percentile_monotone_in_q() {
        crate::testutil::check(20, |rng| {
            let h = Histogram::new();
            for _ in 0..500 {
                h.record(rng.below(1_000_000) + 1);
            }
            let mut last = 0;
            for q in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 1.0] {
                let p = h.percentile(q);
                if p < last {
                    return Err(format!("percentile not monotone at q={q}"));
                }
                last = p;
            }
            Ok(())
        });
    }
}
