//! Lightweight metrics: counters, gauges, streaming histograms with
//! percentile queries, and the online service-time estimator — used by
//! the coordinator service and the benchmark harness
//! (latency/throughput reporting in the E2E example).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Monotonic counter (thread-safe).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed histogram for latencies in nanoseconds.
///
/// 64 buckets of power-of-two widths cover 1 ns … ~18 s; recording is a
/// single atomic increment, percentile queries interpolate within the
/// matched bucket. Accuracy (<~3% relative error per bucket) is ample
/// for p50/p99 reporting.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (e.g. nanoseconds).
    pub fn record(&self, value: u64) {
        let idx = (64 - value.max(1).leading_zeros() as usize).min(63);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate percentile (`q` in [0, 1]).
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                // Interpolate inside [2^(idx-1), 2^idx).
                let lo = if idx == 0 { 0 } else { 1u64 << (idx - 1) };
                let hi = if idx >= 63 { u64::MAX } else { 1u64 << idx };
                let frac = (target - seen) as f64 / c as f64;
                // Clamp: interpolation may overshoot the true maximum.
                return (lo + ((hi - lo) as f64 * frac) as u64).min(self.max());
            }
            seen += c;
        }
        self.max()
    }

    /// Fold another histogram into this one (bucket-wise addition) —
    /// how the pool aggregates shard-local latency histograms into one
    /// service-level view. Concurrent recording on `other` may be
    /// partially visible (relaxed snapshot), which is fine for
    /// reporting.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let c = theirs.load(Ordering::Relaxed);
            if c > 0 {
                mine.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// p50/p90/p99/max snapshot, formatted for logs.
    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.0}{unit} p50={}{unit} p90={}{unit} p99={}{unit} max={}{unit}",
            self.count(),
            self.mean(),
            self.percentile(0.50),
            self.percentile(0.90),
            self.percentile(0.99),
            self.max(),
        )
    }
}

/// Number of service classes the estimator tracks. The coordinator
/// maps each [`crate::coordinator::GraphKernel`] to one class
/// (`GraphKernel::class()`), so this matches the kernel count — pinned
/// by a test in `coordinator`.
pub const SERVICE_CLASSES: usize = 6;

/// Fixed-point fractional bits of the EMA state and the alpha weight.
const FP_SHIFT: u32 = 16;

/// Largest sample the estimator accepts, in ns (~19.5 h). Keeps the
/// Q48.16 fixed-point arithmetic below comfortably inside `u64`.
const MAX_SAMPLE_NS: u64 = 1 << 46;

/// Per-class online service-time estimator: a fixed-point exponential
/// moving average of completion latencies, one lane per service class
/// (the coordinator's kernel kinds).
///
/// This is what turns the engine's `service_estimate_ns` from a static
/// config knob into a *measured* quantity: each pool shard owns one
/// estimator (inside its [`crate::coordinator::ServiceMetrics`]),
/// [`crate::coordinator::ServiceMetrics::record_completion`] feeds it
/// one sample per finished request from the shard thread, and the
/// router reads [`estimate_ns`](Self::estimate_ns) on every admission
/// without allocating.
///
/// Concurrency: single-writer, multi-reader. Each shard's estimator is
/// only ever written from that shard's thread (one `record` per
/// completion), while the engine's admission thread reads it
/// concurrently — so plain relaxed atomic loads/stores are sufficient
/// and every operation is wait-free. Readers may observe an estimate
/// that lags the newest sample by one update; routing is advisory, so
/// that is harmless.
///
/// Determinism: `alpha == 0` (the default) disables measurement
/// entirely — `record` is a no-op and [`estimate_ns`](Self::estimate_ns)
/// returns the configured floor, i.e. exactly the static
/// `service_estimate_ns` behavior of PR 4 (and `floor == 0` keeps the
/// router's least-loaded degeneracy).
#[derive(Debug)]
pub struct ServiceEstimator {
    /// EMA weight of a new sample, in Q0.16 fixed point (0 ..= 65536).
    alpha_fp: AtomicU32,
    /// Lower bound (and pre-measurement seed) of every estimate, in ns
    /// — the old static `service_estimate_ns` knob.
    floor_ns: AtomicU64,
    /// Per-class EMA state in Q48.16 fixed point (ns × 2^16).
    ema_fp: [AtomicU64; SERVICE_CLASSES],
    /// Per-class sample counts (first sample snaps the EMA to it).
    samples: [AtomicU64; SERVICE_CLASSES],
}

impl Default for ServiceEstimator {
    fn default() -> Self {
        ServiceEstimator {
            alpha_fp: AtomicU32::new(0),
            floor_ns: AtomicU64::new(0),
            ema_fp: std::array::from_fn(|_| AtomicU64::new(0)),
            samples: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl ServiceEstimator {
    /// Set the EMA weight (`alpha`, clamped to `[0, 1]`; 0 disables
    /// measurement) and the floor/seed in ns, and seed every class's
    /// EMA with the floor. The engine calls this once per shard at
    /// build time, before any sample is recorded.
    pub fn configure(&self, alpha: f64, floor_ns: u64) {
        let alpha_fp = (alpha.clamp(0.0, 1.0) * (1u64 << FP_SHIFT) as f64).round() as u32;
        self.alpha_fp.store(alpha_fp, Ordering::Relaxed);
        let floor_ns = floor_ns.min(MAX_SAMPLE_NS);
        self.floor_ns.store(floor_ns, Ordering::Relaxed);
        for ema in &self.ema_fp {
            ema.store(floor_ns << FP_SHIFT, Ordering::Relaxed);
        }
    }

    /// True when a non-zero alpha was configured (samples move the
    /// estimate); false means the estimator is a pass-through for the
    /// static floor.
    pub fn is_measuring(&self) -> bool {
        self.alpha_fp.load(Ordering::Relaxed) > 0
    }

    /// The configured EMA weight as a float (for reports).
    pub fn alpha(&self) -> f64 {
        self.alpha_fp.load(Ordering::Relaxed) as f64 / (1u64 << FP_SHIFT) as f64
    }

    /// The configured floor/seed in ns.
    pub fn floor_ns(&self) -> u64 {
        self.floor_ns.load(Ordering::Relaxed)
    }

    /// Record one completion latency for `class`. No-op when alpha is 0
    /// or `class` is out of range. The first sample of a class replaces
    /// the seed outright (a measurement beats a guess); later samples
    /// move the EMA by `alpha × (sample − ema)` in fixed point.
    pub fn record(&self, class: usize, latency_ns: u64) {
        let alpha = self.alpha_fp.load(Ordering::Relaxed) as u64;
        if alpha == 0 || class >= SERVICE_CLASSES {
            return;
        }
        let sample_fp = latency_ns.min(MAX_SAMPLE_NS) << FP_SHIFT;
        // Single-writer: the count is also only advanced from here.
        let seen = self.samples[class].fetch_add(1, Ordering::Relaxed);
        if seen == 0 {
            self.ema_fp[class].store(sample_fp, Ordering::Relaxed);
            return;
        }
        let old = self.ema_fp[class].load(Ordering::Relaxed) as i128;
        let delta = ((sample_fp as i128 - old) * alpha as i128) >> FP_SHIFT;
        let new = (old + delta).max(0) as u64;
        self.ema_fp[class].store(new, Ordering::Relaxed);
    }

    /// Current estimate for `class` in ns: the EMA, never below the
    /// configured floor. An out-of-range class reads as the floor.
    pub fn estimate_ns(&self, class: usize) -> u64 {
        let floor = self.floor_ns.load(Ordering::Relaxed);
        if class >= SERVICE_CLASSES {
            return floor;
        }
        (self.ema_fp[class].load(Ordering::Relaxed) >> FP_SHIFT).max(floor)
    }

    /// Samples recorded for `class`.
    pub fn samples(&self, class: usize) -> u64 {
        if class >= SERVICE_CLASSES {
            return 0;
        }
        self.samples[class].load(Ordering::Relaxed)
    }

    /// Sample-weighted mean estimate across every measured class, in ns
    /// (the one-number "how expensive is a request here" readout used
    /// by reports and the admission sweep's EMA-convergence column).
    /// Falls back to the floor when nothing was measured yet.
    pub fn mean_estimate_ns(&self) -> u64 {
        let mut weighted: u128 = 0;
        let mut total: u128 = 0;
        for class in 0..SERVICE_CLASSES {
            let n = self.samples[class].load(Ordering::Relaxed) as u128;
            if n > 0 {
                weighted += self.estimate_ns(class) as u128 * n;
                total += n;
            }
        }
        if total == 0 {
            self.floor_ns.load(Ordering::Relaxed)
        } else {
            (weighted / total) as u64
        }
    }

    /// Fold another estimator into this one for reporting: per class,
    /// the merged EMA is the sample-weighted mean; alpha and floor take
    /// the max (aggregates are read-only views, never recorded into).
    pub fn merge_from(&self, other: &ServiceEstimator) {
        self.alpha_fp.fetch_max(other.alpha_fp.load(Ordering::Relaxed), Ordering::Relaxed);
        self.floor_ns.fetch_max(other.floor_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        for class in 0..SERVICE_CLASSES {
            let n_other = other.samples[class].load(Ordering::Relaxed);
            if n_other == 0 {
                continue;
            }
            let n_mine = self.samples[class].load(Ordering::Relaxed);
            let e_other = other.ema_fp[class].load(Ordering::Relaxed);
            let merged = if n_mine == 0 {
                e_other
            } else {
                let e_mine = self.ema_fp[class].load(Ordering::Relaxed);
                ((e_mine as u128 * n_mine as u128 + e_other as u128 * n_other as u128)
                    / (n_mine as u128 + n_other as u128)) as u64
            };
            self.ema_fp[class].store(merged, Ordering::Relaxed);
            self.samples[class].store(n_mine + n_other, Ordering::Relaxed);
        }
    }
}

/// Admission-control counters: every request the engine's front door
/// turned away or delayed, plus how much slack deadlined requests
/// arrived with. Shed and parked events are engine-side (recorded at
/// admission); deadline misses are shard-side (recorded at completion)
/// — [`AdmissionMetrics::merge_from`] folds both into one service view.
#[derive(Debug, Default)]
pub struct AdmissionMetrics {
    /// Requests refused by the shed policy (counted per
    /// [`crate::coordinator::ShedReason`] below; never silent).
    pub shed_requests: Counter,
    /// Shed because the deadline had already expired at admission.
    pub shed_past_deadline: Counter,
    /// Shed because remaining slack was below the estimated wait.
    pub shed_slack_exhausted: Counter,
    /// Shed by the load-factor overload threshold.
    pub shed_overload: Counter,
    /// Accepted requests that completed after their deadline.
    pub deadline_misses: Counter,
    /// Submissions that parked on a shard's drain signal (full channel)
    /// before being accepted.
    pub parked_submits: Counter,
    /// Non-blocking submissions bounced with `QueueFull`.
    pub queue_full_rejections: Counter,
    /// Shard batches whose EDF processing order differed from FIFO
    /// (recorded by the coordinator when `edf` is enabled).
    pub edf_reorders: Counter,
    /// Deadlined requests that EDF promoted ahead of their FIFO slot
    /// *and* that then completed on time — an upper bound on misses the
    /// reordering prevented (the FIFO counterfactual is not replayed).
    pub deadline_misses_avoided: Counter,
    /// Slack remaining at admission (ns) for accepted deadlined
    /// requests — the input distribution deadline-aware routing works
    /// with.
    pub slack_at_admission: Histogram,
}

impl AdmissionMetrics {
    /// Fold another instance into this one (same merge semantics as
    /// [`Histogram::merge_from`]).
    pub fn merge_from(&self, other: &AdmissionMetrics) {
        self.shed_requests.add(other.shed_requests.get());
        self.shed_past_deadline.add(other.shed_past_deadline.get());
        self.shed_slack_exhausted.add(other.shed_slack_exhausted.get());
        self.shed_overload.add(other.shed_overload.get());
        self.deadline_misses.add(other.deadline_misses.get());
        self.parked_submits.add(other.parked_submits.get());
        self.queue_full_rejections.add(other.queue_full_rejections.get());
        self.edf_reorders.add(other.edf_reorders.get());
        self.deadline_misses_avoided.add(other.deadline_misses_avoided.get());
        self.slack_at_admission.merge_from(&other.slack_at_admission);
    }

    /// One-line report (`shed=... parked=... misses=...` plus the slack
    /// distribution when any deadlined request was admitted).
    pub fn summary(&self) -> String {
        let mut out = format!(
            "shed={} (past-deadline={} slack={} overload={}) parked={} \
             queue-full={} deadline-misses={}",
            self.shed_requests.get(),
            self.shed_past_deadline.get(),
            self.shed_slack_exhausted.get(),
            self.shed_overload.get(),
            self.parked_submits.get(),
            self.queue_full_rejections.get(),
            self.deadline_misses.get(),
        );
        if self.edf_reorders.get() > 0 {
            out += &format!(
                "; edf reorders={} misses-avoided={}",
                self.edf_reorders.get(),
                self.deadline_misses_avoided.get(),
            );
        }
        if self.slack_at_admission.count() > 0 {
            out += &format!("; slack {}", self.slack_at_admission.summary("ns"));
        }
        out
    }
}

/// Fault-isolation counters: everything the containment, supervision,
/// and degradation layers did. Panics-caught is shard-side (recorded by
/// the coordinator's containment wrapper); the rest is engine-side
/// (recorded when the supervisor's verdicts are applied) —
/// [`FaultMetrics::merge_from`] folds both into one service view. In a
/// healthy run every counter is zero and [`FaultMetrics::is_quiet`]
/// keeps reports free of fault noise.
#[derive(Debug, Default)]
pub struct FaultMetrics {
    /// Kernel panics caught and converted into typed failure responses.
    pub panics_caught: Counter,
    /// Dead shard threads respawned by the supervisor.
    pub shard_restarts: Counter,
    /// Queued-but-unprocessed requests stolen off quarantined shards
    /// and re-routed to healthy ones.
    pub redirected_requests: Counter,
    /// Watchdog classifications that put a shard into quarantine.
    pub watchdog_trips: Counter,
    /// Requests executed inline (serial) because no healthy shard was
    /// available.
    pub degraded_requests: Counter,
    /// Responses synthesized because the original never arrived.
    pub responses_lost: Counter,
    /// Time shards spent quarantined before release (ns).
    pub quarantine_ns: Histogram,
}

impl FaultMetrics {
    /// Fold another instance into this one (same merge semantics as
    /// [`Histogram::merge_from`]).
    pub fn merge_from(&self, other: &FaultMetrics) {
        self.panics_caught.add(other.panics_caught.get());
        self.shard_restarts.add(other.shard_restarts.get());
        self.redirected_requests.add(other.redirected_requests.get());
        self.watchdog_trips.add(other.watchdog_trips.get());
        self.degraded_requests.add(other.degraded_requests.get());
        self.responses_lost.add(other.responses_lost.get());
        self.quarantine_ns.merge_from(&other.quarantine_ns);
    }

    /// True when nothing fault-related happened (the healthy-run
    /// degenerate case) — reports stay silent then.
    pub fn is_quiet(&self) -> bool {
        self.panics_caught.get() == 0
            && self.shard_restarts.get() == 0
            && self.redirected_requests.get() == 0
            && self.watchdog_trips.get() == 0
            && self.degraded_requests.get() == 0
            && self.responses_lost.get() == 0
            && self.quarantine_ns.count() == 0
    }

    /// One-line report of the recovery activity.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "panics-caught={} restarts={} redirected={} watchdog-trips={} \
             degraded={} responses-lost={}",
            self.panics_caught.get(),
            self.shard_restarts.get(),
            self.redirected_requests.get(),
            self.watchdog_trips.get(),
            self.degraded_requests.get(),
            self.responses_lost.get(),
        );
        if self.quarantine_ns.count() > 0 {
            out += &format!("; quarantine {}", self.quarantine_ns.summary("ns"));
        }
        out
    }
}

/// At-least-once replay bookkeeping: what the opt-in reliability layer
/// did with failed responses. Every replayed request either eventually
/// completes (`replay_successes`), runs out of attempts (`gave_up`), or
/// is shed because its deadline passed before a retry could help
/// (`replay_sheds`). `replays` counts *attempts* (a request retried
/// twice counts twice), so once a drain settles the per-request books
/// balance as `replay_successes + replay_sheds + gave_up` resolved
/// requests with `replays >=` that sum, terminal failures equal
/// `gave_up + replay_sheds`, and the engine's `submitted = completed +
/// shed + failed_terminal` balance still holds exactly.
/// With `replay = false` (the default) every counter stays zero and
/// [`ReliabilityMetrics::is_quiet`] keeps reports free of replay noise.
#[derive(Debug, Default)]
pub struct ReliabilityMetrics {
    /// Failed responses absorbed and re-submitted (attempt count, not
    /// request count — a request retried twice counts twice).
    pub replays: Counter,
    /// Requests that completed successfully after at least one replay.
    pub replay_successes: Counter,
    /// Replay candidates shed because their deadline had already
    /// passed when the failure came back.
    pub replay_sheds: Counter,
    /// Requests whose replay budget ran out; the final typed failure
    /// was surfaced to the caller.
    pub gave_up: Counter,
}

impl ReliabilityMetrics {
    /// Fold another instance into this one.
    pub fn merge_from(&self, other: &ReliabilityMetrics) {
        self.replays.add(other.replays.get());
        self.replay_successes.add(other.replay_successes.get());
        self.replay_sheds.add(other.replay_sheds.get());
        self.gave_up.add(other.gave_up.get());
    }

    /// True when no replay activity happened (replay off, or on but
    /// never needed) — reports stay silent then.
    pub fn is_quiet(&self) -> bool {
        self.replays.get() == 0
            && self.replay_successes.get() == 0
            && self.replay_sheds.get() == 0
            && self.gave_up.get() == 0
    }

    /// One-line report of the replay activity.
    pub fn summary(&self) -> String {
        format!(
            "replays={} successes={} sheds={} gave-up={}",
            self.replays.get(),
            self.replay_successes.get(),
            self.replay_sheds.get(),
            self.gave_up.get(),
        )
    }
}

/// Wall-clock stopwatch recording into a [`Histogram`] on drop.
pub struct Timer<'a> {
    hist: &'a Histogram,
    start: std::time::Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a Histogram) -> Self {
        Timer { hist, start: std::time::Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed().as_nanos() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v * 100);
        }
        assert_eq!(h.count(), 1000);
        let (p50, p90, p99) = (h.percentile(0.5), h.percentile(0.9), h.percentile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(p99 <= h.max());
        // Log-bucketed: p50 of uniform 100..100_000 is within its 2x bucket.
        assert!((25_000..100_000).contains(&p50), "p50={p50}");
    }

    #[test]
    fn merge_combines_shard_histograms() {
        let (a, b) = (Histogram::new(), Histogram::new());
        for v in 1..=100u64 {
            a.record(v);
        }
        for v in 1000..=1100u64 {
            b.record(v);
        }
        let agg = Histogram::new();
        agg.merge_from(&a);
        agg.merge_from(&b);
        assert_eq!(agg.count(), a.count() + b.count());
        assert_eq!(agg.max(), 1100);
        let expected_mean = (a.mean() * a.count() as f64 + b.mean() * b.count() as f64)
            / agg.count() as f64;
        assert!((agg.mean() - expected_mean).abs() < 1e-9);
        assert!(agg.percentile(0.99) >= agg.percentile(0.5));
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn admission_metrics_merge_and_summary() {
        let a = AdmissionMetrics::default();
        a.shed_requests.add(3);
        a.shed_past_deadline.add(2);
        a.shed_slack_exhausted.inc();
        a.parked_submits.add(5);
        a.slack_at_admission.record(1000);
        let b = AdmissionMetrics::default();
        b.deadline_misses.add(4);
        b.queue_full_rejections.add(7);
        let agg = AdmissionMetrics::default();
        agg.merge_from(&a);
        agg.merge_from(&b);
        assert_eq!(agg.shed_requests.get(), 3);
        assert_eq!(agg.shed_past_deadline.get(), 2);
        assert_eq!(agg.shed_slack_exhausted.get(), 1);
        assert_eq!(agg.deadline_misses.get(), 4);
        assert_eq!(agg.parked_submits.get(), 5);
        assert_eq!(agg.queue_full_rejections.get(), 7);
        assert_eq!(agg.slack_at_admission.count(), 1);
        let s = agg.summary();
        assert!(s.contains("shed=3"));
        assert!(s.contains("deadline-misses=4"));
        assert!(s.contains("slack "), "slack histogram line present: {s}");
    }

    #[test]
    fn estimator_default_is_inert_static_passthrough() {
        let e = ServiceEstimator::default();
        assert!(!e.is_measuring());
        assert_eq!(e.estimate_ns(0), 0);
        e.record(0, 10_000);
        assert_eq!(e.samples(0), 0, "alpha 0: record is a no-op");
        assert_eq!(e.estimate_ns(0), 0, "zero estimate keeps least-loaded routing");
        // A floor without an alpha reproduces the static knob exactly.
        e.configure(0.0, 7_500);
        e.record(2, 1_000_000);
        assert_eq!(e.estimate_ns(2), 7_500);
        assert_eq!(e.mean_estimate_ns(), 7_500);
        assert!(!e.is_measuring());
    }

    #[test]
    fn estimator_first_sample_snaps_then_ema_converges() {
        let e = ServiceEstimator::default();
        e.configure(0.5, 2_000);
        assert!(e.is_measuring());
        assert!((e.alpha() - 0.5).abs() < 1e-6);
        assert_eq!(e.estimate_ns(3), 2_000, "seeded with the floor before any sample");
        e.record(3, 4_000);
        assert_eq!(e.estimate_ns(3), 4_000, "first sample replaces the seed");
        // Constant 10 µs service time: alpha 0.5 halves the error each
        // sample, so 20 samples land within a nanosecond.
        for _ in 0..20 {
            e.record(3, 10_000);
        }
        let est = e.estimate_ns(3);
        assert!((9_999..=10_001).contains(&est), "est={est}");
        assert_eq!(e.samples(3), 21);
        // Other classes stay at the seed; estimates never sink below
        // the floor.
        assert_eq!(e.estimate_ns(0), 2_000);
        for _ in 0..30 {
            e.record(3, 100);
        }
        assert_eq!(e.estimate_ns(3), 2_000, "floor bounds the readout from below");
    }

    #[test]
    fn estimator_merge_weights_by_samples() {
        let (a, b) = (ServiceEstimator::default(), ServiceEstimator::default());
        a.configure(1.0, 0);
        b.configure(1.0, 0);
        // alpha = 1: the EMA is just the last sample.
        a.record(0, 1_000);
        b.record(0, 4_000);
        b.record(0, 4_000);
        b.record(0, 4_000);
        let agg = ServiceEstimator::default();
        agg.merge_from(&a);
        agg.merge_from(&b);
        assert_eq!(agg.samples(0), 4);
        // Weighted mean (1×1000 + 3×4000) / 4 = 3250.
        let est = agg.estimate_ns(0);
        assert!((3_249..=3_251).contains(&est), "est={est}");
        assert_eq!(agg.mean_estimate_ns(), est);
    }

    #[test]
    fn estimator_handles_extreme_inputs() {
        let e = ServiceEstimator::default();
        e.configure(2.0, u64::MAX); // both clamp
        assert!((e.alpha() - 1.0).abs() < 1e-6);
        e.record(1, u64::MAX);
        assert!(e.estimate_ns(1) >= e.floor_ns());
        // Out-of-range classes neither panic nor record.
        e.record(SERVICE_CLASSES + 3, 10);
        assert_eq!(e.samples(SERVICE_CLASSES + 3), 0);
        assert_eq!(e.estimate_ns(SERVICE_CLASSES + 3), e.floor_ns());
    }

    #[test]
    fn admission_metrics_edf_counters_merge_and_render() {
        let a = AdmissionMetrics::default();
        a.edf_reorders.add(2);
        a.deadline_misses_avoided.inc();
        let agg = AdmissionMetrics::default();
        agg.merge_from(&a);
        assert_eq!(agg.edf_reorders.get(), 2);
        assert_eq!(agg.deadline_misses_avoided.get(), 1);
        let s = agg.summary();
        assert!(s.contains("edf reorders=2"), "{s}");
        assert!(s.contains("misses-avoided=1"), "{s}");
        // Without reorders the summary stays quiet about EDF.
        assert!(!AdmissionMetrics::default().summary().contains("edf"), "quiet by default");
    }

    #[test]
    fn fault_metrics_merge_quietness_and_summary() {
        let quiet = FaultMetrics::default();
        assert!(quiet.is_quiet());
        let a = FaultMetrics::default();
        a.panics_caught.add(2);
        a.shard_restarts.inc();
        a.quarantine_ns.record(5_000);
        let b = FaultMetrics::default();
        b.redirected_requests.add(4);
        b.watchdog_trips.inc();
        b.degraded_requests.add(3);
        b.responses_lost.inc();
        let agg = FaultMetrics::default();
        agg.merge_from(&a);
        agg.merge_from(&b);
        assert!(!agg.is_quiet());
        assert_eq!(agg.panics_caught.get(), 2);
        assert_eq!(agg.shard_restarts.get(), 1);
        assert_eq!(agg.redirected_requests.get(), 4);
        assert_eq!(agg.watchdog_trips.get(), 1);
        assert_eq!(agg.degraded_requests.get(), 3);
        assert_eq!(agg.responses_lost.get(), 1);
        assert_eq!(agg.quarantine_ns.count(), 1);
        let s = agg.summary();
        assert!(s.contains("panics-caught=2"), "{s}");
        assert!(s.contains("restarts=1"), "{s}");
        assert!(s.contains("quarantine "), "{s}");
    }

    #[test]
    fn timer_records() {
        let h = Histogram::new();
        {
            let _t = Timer::start(&h);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn property_percentile_monotone_in_q() {
        crate::testutil::check(20, |rng| {
            let h = Histogram::new();
            for _ in 0..500 {
                h.record(rng.below(1_000_000) + 1);
            }
            let mut last = 0;
            for q in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 1.0] {
                let p = h.percentile(q);
                if p < last {
                    return Err(format!("percentile not monotone at q={q}"));
                }
                last = p;
            }
            Ok(())
        });
    }
}
