//! A fast DOM JSON parser — the RapidJSON substitute (DESIGN.md §2).
//!
//! The paper's JSON benchmark parses the json.org "widget" sample with
//! RapidJSON, a ~1.1 µs task. This module provides the same workload
//! shape: a recursive-descent parser building a DOM in a single pass,
//! instrumented with [`crate::probe::Probe`] hooks so the identical code
//! path drives both wall-clock benchmarks and the SMT simulator.
//!
//! It doubles as the crate's utility JSON layer (PJRT artifact manifests,
//! figure emission) via [`Value`] accessors and [`emit`].
//!
//! ```
//! use relic_smt::json::parse;
//! let v = parse(br#"{"a": [1, 2.5, true, null, "x"]}"#).unwrap();
//! assert_eq!(v["a"][1].as_f64(), Some(2.5));
//! ```

mod emit;
mod parser;
mod value;

pub use emit::to_string;
pub use parser::{parse, parse_batch_par, parse_probed, Error};
pub use value::Value;

/// The json.org "widget" sample document used by the paper's JSON
/// parsing benchmark (§IV-B, reference [60]).
pub const WIDGET: &[u8] = br#"{"widget": {
    "debug": "on",
    "window": {
        "title": "Sample Konfabulator Widget",
        "name": "main_window",
        "width": 500,
        "height": 500
    },
    "image": {
        "src": "Images/Sun.png",
        "name": "sun1",
        "hOffset": 250,
        "vOffset": 250,
        "alignment": "center"
    },
    "text": {
        "data": "Click Here",
        "size": 36,
        "style": "bold",
        "name": "text1",
        "hOffset": 250,
        "vOffset": 100,
        "alignment": "center",
        "onMouseUp": "sun1.opacity = (sun1.opacity / 100) * 90;"
    }
}}"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widget_parses() {
        let v = parse(WIDGET).unwrap();
        assert_eq!(v["widget"]["window"]["width"].as_f64(), Some(500.0));
        assert_eq!(
            v["widget"]["image"]["src"].as_str(),
            Some("Images/Sun.png")
        );
        assert_eq!(
            v["widget"]["text"]["onMouseUp"].as_str(),
            Some("sun1.opacity = (sun1.opacity / 100) * 90;")
        );
    }

    #[test]
    fn widget_roundtrips() {
        let v = parse(WIDGET).unwrap();
        let s = to_string(&v);
        let v2 = parse(s.as_bytes()).unwrap();
        assert_eq!(v, v2);
    }
}
