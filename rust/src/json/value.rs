//! The JSON DOM.

use std::ops::Index;

/// A parsed JSON document node.
///
/// Objects preserve insertion order (like RapidJSON's DOM) and use a
/// flat `Vec` of pairs — faster than a hash map at the benchmark's
/// document sizes and deterministic for round-trip tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as u64 if it is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        match self {
            Value::Array(a) => a.get(idx),
            _ => None,
        }
    }

    /// Total node count (objects/arrays count themselves plus children);
    /// used as the parse benchmark's checksum so work cannot be elided.
    pub fn node_count(&self) -> usize {
        match self {
            Value::Array(a) => 1 + a.iter().map(Value::node_count).sum::<usize>(),
            Value::Object(o) => {
                1 + o.iter().map(|(_, v)| v.node_count()).sum::<usize>()
            }
            _ => 1,
        }
    }
}

static NULL: Value = Value::Null;

impl Index<&str> for Value {
    type Output = Value;
    /// Panics-free indexing: missing keys yield `Value::Null`.
    fn index(&self, key: &str) -> &Value {
        self.get(key).unwrap_or(&NULL)
    }
}

impl Index<usize> for Value {
    type Output = Value;
    fn index(&self, idx: usize) -> &Value {
        self.at(idx).unwrap_or(&NULL)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = Value::Object(vec![
            ("n".into(), Value::Number(3.0)),
            ("s".into(), Value::String("hi".into())),
            ("a".into(), Value::Array(vec![Value::Bool(true), Value::Null])),
        ]);
        assert_eq!(v["n"].as_u64(), Some(3));
        assert_eq!(v["s"].as_str(), Some("hi"));
        assert_eq!(v["a"][0].as_bool(), Some(true));
        assert_eq!(v["a"][1], Value::Null);
        assert_eq!(v["missing"], Value::Null);
        assert_eq!(v.node_count(), 6);
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Value::Number(1.5).as_u64(), None);
        assert_eq!(Value::Number(-2.0).as_u64(), None);
        assert_eq!(Value::Number(7.0).as_u64(), Some(7));
    }
}
