//! JSON serialization (used for figure data files and round-trip tests).

use super::Value;

/// Serialize a [`Value`] to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Object(members) => {
            out.push('{');
            for (i, (k, val)) in members.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // Shortest representation that round-trips through our parser.
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn emits_compact() {
        let v = parse(br#"{ "a" : [ 1 , "x\n" , null ] }"#).unwrap();
        assert_eq!(to_string(&v), r#"{"a":[1,"x\n",null]}"#);
    }

    fn random_value(rng: &mut Rng, depth: u32) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Number((rng.below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let len = rng.below(12) as usize;
                Value::String(
                    (0..len)
                        .map(|_| char::from(32 + rng.below(94) as u8))
                        .collect(),
                )
            }
            4 => Value::Array(
                (0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect(),
            ),
            _ => Value::Object(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn property_roundtrip() {
        crate::testutil::check(200, |rng| {
            let v = random_value(rng, 4);
            let s = to_string(&v);
            let v2 = parse(s.as_bytes())
                .map_err(|e| format!("reparse failed: {e} on {s}"))?;
            if v != v2 {
                return Err(format!("roundtrip mismatch: {s}"));
            }
            Ok(())
        });
    }
}
