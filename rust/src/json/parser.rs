//! Recursive-descent JSON parser with probe instrumentation.
//!
//! Design follows RapidJSON's fast path: byte-level dispatch, manual
//! number parsing, a single allocation per string/container. Probe hooks
//! fire at cache-line granularity on the input buffer plus per-node on
//! DOM construction, giving the SMT simulator a memory trace with the
//! same locality structure as the native parse.

use crate::probe::{NoProbe, Probe};

use super::Value;

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    /// Byte offset of the error in the input.
    pub offset: usize,
    /// Static description of what went wrong.
    pub reason: &'static str,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.reason)
    }
}

impl std::error::Error for Error {}

/// Parse a complete JSON document.
pub fn parse(input: &[u8]) -> Result<Value, Error> {
    parse_probed(input, &mut NoProbe)
}

/// Parse a batch of independent JSON documents, splitting the batch
/// across the SMT pair.
///
/// A single DOM parse is one long sequential dependence chain (every
/// byte's meaning depends on the parser state before it), so Relic
/// parallelizes at the *document* boundary — the same shape as the
/// paper's JSON benchmark, which runs two RapidJSON instances side by
/// side. Results come back in input order; each document's parse is
/// byte-for-byte the serial algorithm, so outputs are identical to
/// mapping [`parse`] over the batch.
pub fn parse_batch_par(docs: &[&[u8]], par: &crate::relic::Par) -> Vec<Result<Value, Error>> {
    par.chunk_map(0..docs.len(), 1, |sub| {
        sub.map(|i| parse(docs[i])).collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

/// Parse with probe instrumentation (the simulator's entry point).
pub fn parse_probed<P: Probe>(input: &[u8], probe: &mut P) -> Result<Value, Error> {
    let mut p = Parser { input, pos: 0, probe, line_seen: u64::MAX, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.input.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// Logical base address of the input buffer in probe address space.
const INPUT_BASE: u64 = 0x1000_0000;
/// Logical base address of the DOM arena in probe address space.
const DOM_BASE: u64 = 0x2000_0000;
/// Nesting limit (RapidJSON defaults to kParseDefaultFlags with
/// effectively unbounded depth; we bound to keep the parser stack-safe).
const MAX_DEPTH: u32 = 128;

struct Parser<'a, P: Probe> {
    input: &'a [u8],
    pos: usize,
    probe: &'a mut P,
    /// Last input cache line touched (dedup so the trace has one load
    /// per 64-byte line, matching real streaming access).
    line_seen: u64,
    depth: u32,
}

impl<'a, P: Probe> Parser<'a, P> {
    #[inline]
    fn err(&self, reason: &'static str) -> Error {
        Error { offset: self.pos, reason }
    }

    /// Current byte, with a probe load on new cache lines.
    #[inline]
    fn peek(&mut self) -> Option<u8> {
        let b = *self.input.get(self.pos)?;
        let line = INPUT_BASE + (self.pos as u64 & !63);
        if line != self.line_seen {
            self.line_seen = line;
            self.probe.load(line);
        }
        Some(b)
    }

    #[inline]
    fn bump(&mut self) {
        self.pos += 1;
    }

    #[inline]
    fn skip_ws(&mut self) {
        let mut skipped = 0u32;
        while let Some(b) = self.peek() {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.bump();
                skipped += 1;
            } else {
                break;
            }
        }
        if skipped > 0 {
            self.probe.compute(skipped); // byte-wise whitespace scan
        }
    }

    fn expect(&mut self, b: u8, reason: &'static str) -> Result<(), Error> {
        if self.peek() == Some(b) {
            self.bump();
            Ok(())
        } else {
            Err(self.err(reason))
        }
    }

    /// Record construction of one DOM node.
    #[inline]
    fn node(&mut self) {
        // One store per node into the logical DOM arena; sequential
        // placement mirrors an arena allocator. Linking into the parent
        // container chases the container pointer (dependent load).
        self.probe.load_dep(DOM_BASE + (self.pos as u64));
        self.probe.store(DOM_BASE + (self.pos as u64) * 2);
        self.probe.compute(10); // node init + type tag + parent link

    }

    fn value(&mut self) -> Result<Value, Error> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            self.depth -= 1;
            return Err(self.err("nesting too deep"));
        }
        let b = self.peek().ok_or_else(|| self.err("unexpected end"))?;
        self.probe.branch(false); // value-kind dispatch is data-dependent
        let v = match b {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => self.string().map(Value::String),
            b't' => self.lit(b"true", Value::Bool(true)),
            b'f' => self.lit(b"false", Value::Bool(false)),
            b'n' => self.lit(b"null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        };
        self.depth -= 1;
        v
    }

    fn lit(&mut self, text: &'static [u8], v: Value) -> Result<Value, Error> {
        for &c in text {
            if self.peek() != Some(c) {
                return Err(self.err("invalid literal"));
            }
            self.bump();
        }
        self.node();
        Ok(v)
    }

    fn object(&mut self) -> Result<Value, Error> {
        self.expect(b'{', "expected '{'")?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            self.node();
            return Ok(Value::Object(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':', "expected ':'")?;
            self.skip_ws();
            let val = self.value()?;
            members.push((key, val));
            self.probe.store(DOM_BASE + members.len() as u64 * 16);
            self.skip_ws();
            self.probe.branch(true); // loop continuation
            match self.peek() {
                Some(b',') => self.bump(),
                Some(b'}') => {
                    self.bump();
                    break;
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
        self.node();
        Ok(Value::Object(members))
    }

    fn array(&mut self) -> Result<Value, Error> {
        self.expect(b'[', "expected '['")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            self.node();
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            self.probe.branch(true);
            match self.peek() {
                Some(b',') => self.bump(),
                Some(b']') => {
                    self.bump();
                    break;
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
        self.node();
        Ok(Value::Array(items))
    }

    fn string(&mut self) -> Result<String, Error> {
        self.expect(b'"', "expected '\"'")?;
        let mut s = Vec::new();
        loop {
            let b = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.bump();
            match b {
                b'"' => break,
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.bump();
                    self.probe.branch(false);
                    match e {
                        b'"' => s.push(b'"'),
                        b'\\' => s.push(b'\\'),
                        b'/' => s.push(b'/'),
                        b'b' => s.push(8),
                        b'f' => s.push(12),
                        b'n' => s.push(b'\n'),
                        b'r' => s.push(b'\r'),
                        b't' => s.push(b'\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = char::from_u32(cp)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let mut buf = [0u8; 4];
                            s.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                0x00..=0x1F => return Err(self.err("control char in string")),
                _ => s.push(b),
            }
        }
        // Byte-wise scan/copy/escape-check cost (RapidJSON processes
        // strings byte-by-byte on this path).
        self.probe.compute((3 * s.len().max(1)) as u32);
        self.node();
        String::from_utf8(s).map_err(|_| self.err("invalid UTF-8"))
    }

    fn hex4(&mut self) -> Result<u32, Error> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("bad \\u escape"))?;
            self.bump();
            let d = match b {
                b'0'..=b'9' => b - b'0',
                b'a'..=b'f' => b - b'a' + 10,
                b'A'..=b'F' => b - b'A' + 10,
                _ => return Err(self.err("bad hex digit")),
            };
            cp = cp * 16 + d as u32;
        }
        Ok(cp)
    }

    fn number(&mut self) -> Result<Value, Error> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.bump();
        }
        // Integer part.
        let mut int: f64 = 0.0;
        let mut digits = 0;
        while let Some(b @ b'0'..=b'9') = self.peek() {
            int = int * 10.0 + (b - b'0') as f64;
            self.bump();
            digits += 1;
        }
        if digits == 0 {
            return Err(self.err("expected digit"));
        }
        // Fraction.
        let mut frac = 0.0;
        let mut scale = 0.1;
        if self.peek() == Some(b'.') {
            self.bump();
            let mut fdigits = 0;
            while let Some(b @ b'0'..=b'9') = self.peek() {
                frac += (b - b'0') as f64 * scale;
                scale *= 0.1;
                self.bump();
                fdigits += 1;
            }
            if fdigits == 0 {
                return Err(self.err("expected fraction digit"));
            }
        }
        // Exponent.
        let mut exp: i32 = 0;
        let mut exp_neg = false;
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.bump();
            match self.peek() {
                Some(b'+') => self.bump(),
                Some(b'-') => {
                    exp_neg = true;
                    self.bump();
                }
                _ => {}
            }
            let mut edigits = 0;
            while let Some(b @ b'0'..=b'9') = self.peek() {
                exp = exp.saturating_mul(10).saturating_add((b - b'0') as i32);
                self.bump();
                edigits += 1;
            }
            if edigits == 0 {
                return Err(self.err("expected exponent digit"));
            }
        }
        let mut v = int + frac;
        if self.input.get(start) == Some(&b'-') {
            v = -v;
        }
        if exp != 0 {
            let e = if exp_neg { -exp } else { exp };
            v *= 10f64.powi(e);
        }
        // Digit loop: mul-add chain per digit plus fp assembly.
        self.probe.compute((2 * (self.pos - start)) as u32);
        self.probe.compute_fp(3);
        self.node();
        Ok(Value::Number(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse(b"null").unwrap(), Value::Null);
        assert_eq!(parse(b"true").unwrap(), Value::Bool(true));
        assert_eq!(parse(b"false").unwrap(), Value::Bool(false));
        assert_eq!(parse(b"42").unwrap(), Value::Number(42.0));
        assert_eq!(parse(b"-3.25").unwrap(), Value::Number(-3.25));
        assert_eq!(parse(b"1e3").unwrap(), Value::Number(1000.0));
        assert_eq!(parse(b"2.5E-2").unwrap(), Value::Number(0.025));
        assert_eq!(parse(br#""hi""#).unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(br#""a\n\t\"\\A""#).unwrap(),
            Value::String("a\n\t\"\\A".into())
        );
        // UTF-8 passthrough ("é" as raw bytes) and \u escape.
        assert_eq!(
            parse(b"\"\xc3\xa9\"").unwrap(),
            Value::String("\u{e9}".into())
        );
        assert_eq!(
            parse(br#""\u00e9""#).unwrap(),
            Value::String("\u{e9}".into())
        );
    }

    #[test]
    fn containers() {
        let v = parse(b" [1, [2, 3], {\"k\": 4}] ").unwrap();
        assert_eq!(v[0].as_f64(), Some(1.0));
        assert_eq!(v[1][1].as_f64(), Some(3.0));
        assert_eq!(v[2]["k"].as_f64(), Some(4.0));
        assert_eq!(parse(b"{}").unwrap(), Value::Object(vec![]));
        assert_eq!(parse(b"[]").unwrap(), Value::Array(vec![]));
    }

    #[test]
    fn errors() {
        for bad in [
            &b"{"[..],
            b"[1,",
            b"\"unterminated",
            b"01x",
            b"tru",
            b"{\"k\" 1}",
            b"1 2",
            b"",
            b"[1,]2",
            b"\"\\q\"",
            b"1.",
            b"1e",
            b"\x01",
        ] {
            assert!(parse(bad).is_err(), "should fail: {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_bounded() {
        let deep: Vec<u8> = std::iter::repeat(b'[')
            .take(200)
            .chain(std::iter::repeat(b']').take(200))
            .collect();
        assert!(parse(&deep).is_err());
        let ok: Vec<u8> = std::iter::repeat(b'[')
            .take(100)
            .chain(std::iter::repeat(b']').take(100))
            .collect();
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn batch_parse_matches_serial_in_order() {
        use crate::relic::{Par, Relic};
        let relic = Relic::new();
        let docs: Vec<Vec<u8>> = (0..40)
            .map(|i| match i % 4 {
                0 => format!("{{\"k\": {i}}}").into_bytes(),
                1 => format!("[{i}, {i}, null]").into_bytes(),
                2 => b"not json".to_vec(),
                _ => crate::json::WIDGET.to_vec(),
            })
            .collect();
        let refs: Vec<&[u8]> = docs.iter().map(Vec::as_slice).collect();
        let serial: Vec<_> = refs.iter().map(|d| parse(d)).collect();
        for par in [Par::Serial, Par::Relic(&relic)] {
            let got = parse_batch_par(&refs, &par);
            assert_eq!(got, serial);
        }
    }

    #[test]
    fn probe_sees_input_lines() {
        struct L(Vec<u64>);
        impl Probe for L {
            fn load(&mut self, a: u64) {
                self.0.push(a);
            }
        }
        let mut p = L(Vec::new());
        let doc = vec![b' '; 200].into_iter().chain(b"1".iter().copied())
            .collect::<Vec<_>>();
        parse_probed(&doc, &mut p).unwrap();
        // 201 bytes = 4 cache lines of input (plus DOM-arena touches).
        let input_lines: Vec<u64> =
            p.0.iter().copied().filter(|a| *a < super::DOM_BASE).collect();
        assert_eq!(input_lines.len(), 4);
        assert!(input_lines.windows(2).all(|w| w[1] == w[0] + 64));
    }
}
